//! Regenerates Figure 13 and Table I: average job completion time and
//! JCT CDFs as the number of available servers per task group sweeps
//! p ∈ {4, 6, 8, 10, 12}, at α = 2 and 75% utilization.
//!
//! `cargo bench --bench fig13_table1_servers` (paper scale) or
//! `TAOS_BENCH_QUICK=1` for CI. Prints the exact row layout of Table I.
//! Cells fan out across all cores (`TAOS_BENCH_THREADS=N` to override).

use taos::sweep;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("TAOS_BENCH_QUICK").is_ok();
    let base = if quick {
        sweep::quick_base(42)
    } else {
        sweep::paper_base(42)
    };
    let opts = sweep::SweepOptions::from_env();
    let ps = [4usize, 6, 8, 10, 12];
    let t0 = std::time::Instant::now();
    let figure = sweep::fig_servers_opts(&base, &ps, &opts).expect("sweep failed");
    println!(
        "================ Fig 13 / Table I — #available servers ({:.1}s) ================",
        t0.elapsed().as_secs_f64()
    );
    println!("{}", figure.render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(
        "bench_results/fig13_table1.json",
        figure.to_json().to_string(),
    )
    .expect("write json");
    println!("wrote bench_results/fig13_table1.json");

    // Table I's qualitative shape: JCT decreases with p for every
    // algorithm; the reordered pair coincides and dominates.
    for policy in ["obta", "wf", "ocwf"] {
        let first = figure.cell(policy, 4.0).unwrap().mean_jct;
        let last = figure.cell(policy, 12.0).unwrap().mean_jct;
        println!(
            "check {policy}: JCT p=4 {first:.0} -> p=12 {last:.0} ({})",
            if last < first { "decreasing OK" } else { "NOT decreasing" }
        );
    }
    let o = figure.cell("ocwf", 8.0).unwrap().mean_jct;
    let a = figure.cell("ocwf-acc", 8.0).unwrap().mean_jct;
    println!("check ocwf == ocwf-acc at p=8: {}", (o - a).abs() < 1e-9);
}
