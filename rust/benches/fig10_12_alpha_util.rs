//! Regenerates Figures 10, 11 and 12: average job completion time,
//! per-arrival computation overhead, and JCT CDFs for all six algorithms
//! as the Zipf skew α sweeps 0 → 2, at 25% / 50% / 75% utilization.
//!
//! `cargo bench --bench fig10_12_alpha_util` (full paper scale) or with
//! `TAOS_BENCH_QUICK=1` / `-- --quick` for the scaled-down workload.
//! Cells fan out across all cores (override with `TAOS_BENCH_THREADS=N`;
//! results are bit-identical at any thread count). JSON series land in
//! `bench_results/`.

use taos::sweep;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("TAOS_BENCH_QUICK").is_ok();
    let base = if quick {
        sweep::quick_base(42)
    } else {
        sweep::paper_base(42)
    };
    let opts = sweep::SweepOptions::from_env();
    let alphas = [0.0, 0.5, 1.0, 1.5, 2.0];
    std::fs::create_dir_all("bench_results").ok();

    for (fig, util) in [("fig10", 0.25), ("fig11", 0.50), ("fig12", 0.75)] {
        let t0 = std::time::Instant::now();
        let figure =
            sweep::fig_alpha_util_opts(&base, util, &alphas, &opts).expect("sweep failed");
        println!(
            "\n================ {} (paper Fig {}) — {:.0}% utilization ({:.1}s) ================",
            figure.name,
            &fig[3..],
            util * 100.0,
            t0.elapsed().as_secs_f64()
        );
        println!("{}", figure.render());
        let path = format!("bench_results/{fig}.json");
        std::fs::write(&path, figure.to_json().to_string()).expect("write json");
        println!("wrote {path}");

        // The paper's qualitative checks for these figures.
        let last = *alphas.last().unwrap();
        let nlip = figure.cell("nlip", last).unwrap().mean_jct;
        let obta = figure.cell("obta", last).unwrap().mean_jct;
        let wf = figure.cell("wf", last).unwrap().mean_jct;
        let ocwf = figure.cell("ocwf", last).unwrap().mean_jct;
        let ocwf_acc = figure.cell("ocwf-acc", last).unwrap().mean_jct;
        println!(
            "checks @ alpha=2: OBTA~NLIP diff {:.1}%  |  WF/OBTA {:.2}x  |  OCWF/WF {:.2}x  |  OCWF==ACC: {}",
            100.0 * (obta - nlip).abs() / nlip.max(1.0),
            wf / obta.max(1.0),
            ocwf / wf.max(1.0),
            (ocwf - ocwf_acc).abs() < 1e-9,
        );
    }
}
