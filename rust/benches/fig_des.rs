//! DES fidelity-engine figure: the engine presets (`straggler`,
//! `multi-locality`, `multi-rack`, `multi-zone`) across all six
//! algorithms, plus an analytic-vs-DES wall-clock and agreement check on
//! the deterministic baseline.
//!
//! `cargo bench --bench fig_des` (paper scale) or `TAOS_BENCH_QUICK=1` /
//! `-- --quick` for CI scale. Cells fan out across all cores
//! (`TAOS_BENCH_THREADS=N` to override; results are bit-identical at any
//! thread count).

use taos::benchlib::TextTable;
use taos::des::service::EngineKind;
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;
use taos::sweep;
use taos::trace::scenarios::Scenario;
use taos::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("TAOS_BENCH_QUICK").is_ok();
    let base = if quick {
        sweep::quick_base(42)
    } else {
        sweep::paper_base(42)
    };

    // 1. Oracle agreement + engine wall-clock on the deterministic
    // baseline: the DES engine must reproduce the analytic JCT vector
    // bit for bit while we record its event-loop overhead.
    println!("== analytic vs deterministic DES (baseline workload) ==");
    let mut t = TextTable::new(&["policy", "analytic ms", "des ms", "agreement"]);
    let mut rows = Vec::new();
    for policy in SchedPolicy::ALL {
        let t0 = std::time::Instant::now();
        let analytic = run_experiment(&base, policy).expect("analytic run");
        let analytic_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut des_cfg = base.clone();
        des_cfg.sim.engine = EngineKind::Des;
        let t1 = std::time::Instant::now();
        let des = run_experiment(&des_cfg, policy).expect("des run");
        let des_ms = t1.elapsed().as_secs_f64() * 1e3;
        let agree = analytic.jcts == des.jcts && analytic.makespan == des.makespan;
        assert!(agree, "{}: deterministic DES diverged from analytic", policy.name());
        t.row(vec![
            policy.name().into(),
            format!("{analytic_ms:.1}"),
            format!("{des_ms:.1}"),
            "bit-identical".into(),
        ]);
        rows.push((policy.name(), analytic_ms, des_ms));
    }
    print!("{}", t.render());

    // 2. The engine presets, as full figures with p50/p99 columns: the
    // straggler tail must be visible in p99 long before it moves the
    // mean, and the locality penalty must cost FIFO more than the
    // reordering policies (which keep re-packing remaining work).
    let opts = sweep::SweepOptions::from_env();
    let mut preset_figs = Vec::new();
    for scenario in [
        Scenario::Straggler,
        Scenario::MultiLocality,
        Scenario::MultiRack,
        Scenario::MultiZone,
    ] {
        let mut cfg = base.clone();
        scenario.apply(&mut cfg);
        let t0 = std::time::Instant::now();
        let specs: Vec<sweep::CellSpec> = SchedPolicy::ALL
            .iter()
            .map(|&policy| sweep::CellSpec {
                cfg: cfg.clone(),
                policy,
                setting: 0.0,
                trial: 0,
            })
            .collect();
        let outcomes =
            sweep::run_specs(&specs, opts.effective_threads()).expect("preset sweep");
        println!(
            "\n== {} preset ({:.1}s, {} threads) ==",
            scenario.name(),
            t0.elapsed().as_secs_f64(),
            opts.effective_threads()
        );
        let mut tp = TextTable::new(&["policy", "mean JCT", "p50", "p99", "max", "tier hits"]);
        let mut cells = Vec::new();
        for (spec, out) in specs.iter().zip(&outcomes) {
            let s = out.jct_stats();
            let total: u64 = out.tier_tasks.iter().sum();
            let tiers = if total == 0 {
                "-".to_string()
            } else {
                out.tier_tasks
                    .iter()
                    .map(|&n| format!("{:.0}%", n as f64 * 100.0 / total as f64))
                    .collect::<Vec<_>>()
                    .join("/")
            };
            tp.row(vec![
                spec.policy.name().into(),
                format!("{:.0}", s.mean),
                format!("{:.0}", s.p50),
                format!("{:.0}", s.p99),
                format!("{:.0}", s.max),
                tiers,
            ]);
            cells.push((spec.policy.name(), s));
        }
        print!("{}", tp.render());
        preset_figs.push((scenario.name(), cells));
    }

    // JSON artifact next to the other figure benches.
    std::fs::create_dir_all("bench_results").ok();
    let json = Json::obj(vec![
        (
            "engine_overhead",
            Json::arr(rows.iter().map(|(name, a, d)| {
                Json::obj(vec![
                    ("policy", Json::str(*name)),
                    ("analytic_ms", Json::num(*a)),
                    ("des_ms", Json::num(*d)),
                ])
            })),
        ),
        (
            "presets",
            Json::arr(preset_figs.iter().map(|(name, cells)| {
                Json::obj(vec![
                    ("scenario", Json::str(*name)),
                    (
                        "cells",
                        Json::arr(cells.iter().map(|(policy, s)| {
                            Json::obj(vec![
                                ("policy", Json::str(*policy)),
                                ("mean_jct", Json::num(s.mean)),
                                ("p50_jct", Json::num(s.p50)),
                                ("p99_jct", Json::num(s.p99)),
                                ("max_jct", Json::num(s.max)),
                            ])
                        })),
                    ),
                ])
            })),
        ),
    ]);
    std::fs::write("bench_results/fig_des.json", json.to_string()).expect("write json");
    println!("\nwrote bench_results/fig_des.json");
}
