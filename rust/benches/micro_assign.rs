//! Micro-benchmarks of the hot paths: one assignment per algorithm on a
//! paper-shaped instance (M = 100 servers, K ≈ 5.5 groups, p ∈ [8, 12]
//! available servers, μ ∈ [3, 5]), plus the substrate primitives
//! (water-level search, Dinic feasibility probe, OCWF reorder round).
//!
//! These are the numbers the PERFORMANCE section of EXPERIMENTS.md
//! tracks. `cargo bench --bench micro_assign` (add `-- --quick` for CI).

use taos::assign::bounds::water_level;
use taos::assign::feasible::Oracle;
use taos::assign::{bounds, AssignPolicy, Assigner, Instance};
use taos::benchlib::{black_box, Bench};
use taos::job::TaskGroup;
use taos::sched::ocwf::{reorder_into, Outstanding, ReorderOutcome, ReorderWorkspace};
use taos::util::rng::Rng;

/// A paper-shaped instance: `k` groups over `m` servers.
fn paper_instance(rng: &mut Rng, m: usize, k: usize) -> (Vec<TaskGroup>, Vec<u64>, Vec<u64>) {
    let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(3, 5)).collect();
    let busy: Vec<u64> = (0..m).map(|_| rng.gen_range(50)).collect();
    let groups: Vec<TaskGroup> = (0..k)
        .map(|_| {
            let p = rng.gen_range_incl(8, 12) as usize;
            let anchor = rng.gen_range(m as u64) as usize;
            let servers: Vec<usize> = (0..p).map(|i| (anchor + i) % m).collect();
            TaskGroup::new(rng.gen_range_incl(20, 160), servers)
        })
        .collect();
    (groups, mu, busy)
}

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::seed_from(0xBE7C);
    let m = 100;

    // A stable set of instances to cycle through (avoids benchmarking a
    // single lucky shape).
    let instances: Vec<_> = (0..32).map(|_| paper_instance(&mut rng, m, 6)).collect();

    for policy in AssignPolicy::ALL {
        let mut assigner = policy.build(7);
        let mut i = 0;
        bench.run(&format!("assign/{}@M100_K6", policy.name()), || {
            let (groups, mu, busy) = &instances[i % instances.len()];
            i += 1;
            let inst = Instance { groups, mu, busy };
            black_box(assigner.assign(&inst))
        });
    }

    // Substrate: the water-level binary search (WF's inner loop).
    {
        let (groups, mu, busy) = &instances[0];
        let g = &groups[0];
        bench.run("substrate/water_level@p12", || {
            black_box(water_level(&g.servers, g.size, busy, mu))
        });
    }

    // Substrate: one feasibility probe (flow build + max-flow) at Φ⁺.
    {
        let (groups, mu, busy) = &instances[0];
        let inst = Instance { groups, mu, busy };
        let hi = bounds::phi_upper(&inst) + groups.len() as u64;
        bench.run("substrate/feasibility_probe", || {
            let mut oracle = Oracle::new(&inst);
            black_box(oracle.check(hi).is_some())
        });
    }

    // Substrate: one empty batch through the persistent executor — the
    // handoff latency that replaced a scoped-thread spawn per chunk.
    // (Legacy label kept so CI bench history lines up across commits.)
    {
        use taos::runtime::executor::Executor;
        let ex = Executor::global();
        for stripes in [2usize, 8] {
            bench.run(&format!("substrate/executor_handoff@{stripes}stripes"), || {
                ex.run_batch(stripes, &|s| {
                    black_box(s);
                });
                black_box(ex.epochs_dispatched())
            });
        }
        // The doorbell handoff probe: same shape, explicitly tracking the
        // per-worker doorbell path (idle-stack pop + one targeted unpark
        // per admitted helper, zero on a busy pool) that replaced the
        // condvar notify loop — the CI bench run records the before
        // (executor_handoff rows from the pre-doorbell artifact) / after
        // (these rows) story. The budget counters are folded into the
        // result so the admission bookkeeping is part of what's timed.
        for stripes in [2usize, 8] {
            bench.run(&format!("substrate/doorbell_handoff@{stripes}stripes"), || {
                ex.run_batch(stripes, &|s| {
                    black_box(s);
                });
                black_box(ex.helpers_woken_total() + ex.wakeups_trimmed_total())
            });
        }
    }

    // Substrate: the DES engine's pooled event core — a full
    // push-then-drain cycle at two depths (the steady-state shape: all
    // arrivals resident plus one completion per server), and a mixed
    // interleaved load. The heap reuses its backing storage, so the
    // steady-state cycle is allocation-free.
    {
        use taos::des::heap::{EventHeap, EventKind};
        let mut heap = EventHeap::new();
        for depth in [64usize, 1024] {
            bench.run(&format!("substrate/des_event_heap@cycle{depth}"), || {
                for i in 0..depth as u64 {
                    heap.push((i * 37) % 257, EventKind::Complete {
                        server: (i % 16) as usize,
                        token: i,
                    });
                }
                let mut last = 0;
                while let Some(e) = heap.pop() {
                    last = e.time;
                }
                black_box(last)
            });
        }
        bench.run("substrate/des_event_heap@interleaved256", || {
            let mut popped = 0u64;
            for i in 0..256u64 {
                heap.push((i * 13) % 97, EventKind::Arrival { job: i as usize });
                if i % 2 == 1 {
                    if let Some(e) = heap.pop() {
                        popped += e.time;
                    }
                }
            }
            while let Some(e) = heap.pop() {
                popped += e.time;
            }
            black_box(popped)
        });
    }

    // Substrate: the calendar-queue event core on the exact same loads —
    // the O(1)-amortized streaming-scale alternative whose pop order is
    // bit-identical to the heap (see `rust/tests/streaming_scale.rs`).
    {
        use taos::des::calendar::CalendarQueue;
        use taos::des::heap::EventKind;
        let mut cal = CalendarQueue::new();
        for depth in [64usize, 1024] {
            bench.run(&format!("substrate/des_calendar_queue@cycle{depth}"), || {
                for i in 0..depth as u64 {
                    cal.push((i * 37) % 257, EventKind::Complete {
                        server: (i % 16) as usize,
                        token: i,
                    });
                }
                let mut last = 0;
                while let Some(e) = cal.pop() {
                    last = e.time;
                }
                cal.clear();
                black_box(last)
            });
        }
        bench.run("substrate/des_calendar_queue@interleaved256", || {
            let mut popped = 0u64;
            for i in 0..256u64 {
                cal.push((i * 13) % 97, EventKind::Arrival { job: i as usize });
                if i % 2 == 1 {
                    if let Some(e) = cal.pop() {
                        popped += e.time;
                    }
                }
            }
            while let Some(e) = cal.pop() {
                popped += e.time;
            }
            cal.clear();
            black_box(popped)
        });
    }

    // Scheduler: one OCWF-ACC reorder round over 12 outstanding jobs.
    {
        let jobs: Vec<taos::job::Job> = (0..12)
            .map(|id| {
                let (groups, mu, _) = paper_instance(&mut rng, m, 6);
                taos::job::Job {
                    id,
                    arrival: id as u64,
                    groups,
                    mu,
                }
            })
            .collect();
        let outstanding: Vec<Outstanding> = jobs
            .iter()
            .map(|j| Outstanding {
                job: j,
                remaining: j.groups.iter().map(|g| g.size).collect(),
            })
            .collect();
        // Pooled workspace + outcome: the zero-alloc steady-state path the
        // simulator runs.
        let mut ws = ReorderWorkspace::default();
        let mut out = ReorderOutcome::default();
        bench.run("sched/ocwf_acc_reorder@12jobs", || {
            reorder_into(&outstanding, m, true, 1, &mut ws, &mut out);
            black_box(out.order.len())
        });
        bench.run("sched/ocwf_reorder@12jobs", || {
            reorder_into(&outstanding, m, false, 1, &mut ws, &mut out);
            black_box(out.order.len())
        });
        // Parallel reorder rounds (bit-identical; wall-clock only).
        for threads in [2, 0] {
            let label = if threads == 0 {
                "sched/ocwf_reorder@12jobs_allcores".to_string()
            } else {
                format!("sched/ocwf_reorder@12jobs_{threads}thr")
            };
            bench.run(&label, || {
                reorder_into(&outstanding, m, false, threads, &mut ws, &mut out);
                black_box(out.order.len())
            });
        }
        // Parallel ACC: adaptive speculation (chunk sized from the
        // observed early-exit depth) vs the old fixed 2×threads depth.
        for (label, chunk) in [
            ("sched/ocwf_acc_reorder@12jobs_2thr_adaptive", 0usize),
            ("sched/ocwf_acc_reorder@12jobs_2thr_fixed4", 4),
        ] {
            ws.set_spec_chunk(chunk);
            bench.run(label, || {
                reorder_into(&outstanding, m, true, 2, &mut ws, &mut out);
                black_box(out.order.len())
            });
        }
        ws.set_spec_chunk(0);

        // The small-outstanding-set regime the persistent pool targets:
        // per-round handoff cost dominates with only 4 candidates.
        let small: Vec<Outstanding> = outstanding.iter().take(4).cloned().collect();
        for threads in [1usize, 2] {
            bench.run(&format!("sched/ocwf_acc_reorder@4jobs_{threads}thr"), || {
                reorder_into(&small, m, true, threads, &mut ws, &mut out);
                black_box(out.order.len())
            });
        }
    }

    std::fs::create_dir_all("bench_results").ok();
    bench
        .write_json("bench_results/micro_assign.jsonl")
        .expect("write bench json");
    println!("\nwrote bench_results/micro_assign.jsonl");
}
