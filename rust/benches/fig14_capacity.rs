//! Regenerates Figure 14: average job completion time and JCT CDFs as
//! the computing capacity range sweeps (μ ∈ [mid−1, mid+1] for
//! mid ∈ {2..6}), at α = 2 and 75% utilization.
//!
//! `cargo bench --bench fig14_capacity` (paper scale) or
//! `TAOS_BENCH_QUICK=1` for CI.
//! Cells fan out across all cores (`TAOS_BENCH_THREADS=N` to override).

use taos::sweep;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("TAOS_BENCH_QUICK").is_ok();
    let base = if quick {
        sweep::quick_base(42)
    } else {
        sweep::paper_base(42)
    };
    let opts = sweep::SweepOptions::from_env();
    let mids = [2u64, 3, 4, 5, 6];
    let t0 = std::time::Instant::now();
    let figure = sweep::fig_capacity_opts(&base, &mids, &opts).expect("sweep failed");
    println!(
        "================ Fig 14 — computing capacity ({:.1}s) ================",
        t0.elapsed().as_secs_f64()
    );
    println!("{}", figure.render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig14.json", figure.to_json().to_string())
        .expect("write json");
    println!("wrote bench_results/fig14.json");

    // Fig 14's qualitative shape: higher capacity → lower JCT; relative
    // algorithm ordering stable.
    for policy in ["obta", "wf", "rd", "ocwf"] {
        let lo = figure.cell(policy, 2.0).unwrap().mean_jct;
        let hi = figure.cell(policy, 6.0).unwrap().mean_jct;
        println!(
            "check {policy}: JCT mu~2 {lo:.0} -> mu~6 {hi:.0} ({})",
            if hi < lo { "decreasing OK" } else { "NOT decreasing" }
        );
    }
}
