//! Scenario-catalog sweep: every named workload of
//! `taos::trace::scenarios` × all six algorithms, emitting the same
//! `Figure`/JSON artifacts as the paper figures.
//!
//! `cargo bench --bench fig_scenarios` (paper scale) or
//! `TAOS_BENCH_QUICK=1` / `-- --quick` for CI. Cells fan out across all
//! cores (`TAOS_BENCH_THREADS=N` to override; results are bit-identical
//! at any thread count).

use taos::sweep;
use taos::trace::scenarios::Scenario;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("TAOS_BENCH_QUICK").is_ok();
    let base = if quick {
        sweep::quick_base(42)
    } else {
        sweep::paper_base(42)
    };
    let opts = sweep::SweepOptions::from_env();

    let t0 = std::time::Instant::now();
    let figure = sweep::fig_scenarios(&base, &opts).expect("sweep failed");
    println!(
        "================ scenario catalog ({:.1}s, {} threads) ================",
        t0.elapsed().as_secs_f64(),
        opts.effective_threads()
    );
    println!("scenario legend:");
    for (i, sc) in Scenario::ALL.iter().enumerate() {
        println!("  {i} = {:<18} {}", sc.name(), sc.describe());
    }
    println!("{}", figure.render());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(
        "bench_results/fig_scenarios.json",
        figure.to_json().to_string(),
    )
    .expect("write json");
    println!("wrote bench_results/fig_scenarios.json");

    // Qualitative checks: reordering must keep its edge on every
    // scenario, and the skewed scenarios must stress FIFO WF harder than
    // the uniform baseline stresses it.
    let baseline = Scenario::ALL
        .iter()
        .position(|s| *s == Scenario::Alibaba)
        .unwrap() as f64;
    for (i, sc) in Scenario::ALL.iter().enumerate() {
        let wf = figure.cell("wf", i as f64).unwrap().mean_jct;
        let ocwf = figure.cell("ocwf-acc", i as f64).unwrap().mean_jct;
        println!(
            "check {:<18} wf {wf:.0} vs ocwf-acc {ocwf:.0} ({})",
            sc.name(),
            if ocwf <= wf * 1.05 { "reordering holds" } else { "REGRESSION?" }
        );
    }
    let wf_base = figure.cell("wf", baseline).unwrap().mean_jct;
    let hotspot = Scenario::ALL
        .iter()
        .position(|s| *s == Scenario::Hotspot)
        .unwrap() as f64;
    let wf_hot = figure.cell("wf", hotspot).unwrap().mean_jct;
    println!(
        "check hotspot stresses FIFO: baseline {wf_base:.0} vs hotspot {wf_hot:.0} ({})",
        if wf_hot > wf_base { "skew bites OK" } else { "unexpectedly mild" }
    );
}
