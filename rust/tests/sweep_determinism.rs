//! Determinism harness for the parallel sweep executor: the same
//! `ExperimentConfig` + seed must yield byte-identical `SimOutcome` JCT
//! vectors whether cells run serially or on 2 / 8 worker threads, and the
//! figure-level metrics must match bit for bit. (Wall-clock overhead is
//! the deliberate exception: it times real execution.)

use taos::config::ExperimentConfig;
use taos::sched::SchedPolicy;
use taos::sweep::{self, pool, CellSpec, SweepOptions};
use taos::trace::scenarios::Scenario;

fn tiny_base() -> ExperimentConfig {
    let mut cfg = sweep::quick_base(123);
    cfg.trace.jobs = 20;
    cfg.trace.total_tasks = 1_200;
    cfg.cluster.servers = 16;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    cfg
}

/// The flat cell list the determinism assertions run over: every policy ×
/// two placement skews × two scenarios.
fn specs() -> Vec<CellSpec> {
    let mut out = Vec::new();
    for (si, scenario) in [Scenario::Alibaba, Scenario::Hotspot].into_iter().enumerate() {
        for &alpha in &[0.0, 2.0] {
            let mut cfg = tiny_base();
            // Scenario first, explicit knob after (the production
            // precedence rule): both alphas really run, including
            // scatter placement at alpha 0 and 2.
            scenario.apply(&mut cfg);
            cfg.cluster.zipf_alpha = alpha;
            for policy in SchedPolicy::ALL {
                out.push(CellSpec {
                    cfg: cfg.clone(),
                    policy,
                    setting: si as f64,
                    trial: 0,
                });
            }
        }
    }
    out
}

#[test]
fn jct_vectors_bit_identical_serial_vs_parallel_thread_counts() {
    // Thread counts come from TAOS_TEST_THREADS (default 1,2,8) so the CI
    // matrix can pin one count per leg.
    let specs = specs();
    let serial = sweep::run_specs(&specs, 1).unwrap();
    assert_eq!(serial.len(), specs.len());
    for threads in pool::test_thread_counts() {
        let par = sweep::run_specs(&specs, threads).unwrap();
        assert_eq!(par.len(), serial.len());
        for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
            assert_eq!(
                a.jcts, b.jcts,
                "JCT vector diverged at cell {i} ({}) with {threads} threads",
                specs[i].policy.name()
            );
            assert_eq!(a.makespan, b.makespan, "cell {i}, {threads} threads");
            assert_eq!(a.wf_evals, b.wf_evals, "cell {i}, {threads} threads");
        }
    }
}

#[test]
fn combined_sweep_and_reorder_parallelism_bit_identical() {
    // The admission-budget tentpole: cells that themselves fan reorder
    // rounds out (`reorder_threads > 1`) running under a parallel sweep
    // must produce byte-identical JCTs and wf_evals to the fully serial
    // reference — nested fan-outs only borrow idle workers, and neither
    // the borrowing nor the trimming may touch the schedule.
    let reordered_specs = |reorder_threads: usize| -> Vec<CellSpec> {
        let mut out = Vec::new();
        for (si, scenario) in [Scenario::Alibaba, Scenario::Hotspot].into_iter().enumerate() {
            let mut cfg = tiny_base();
            scenario.apply(&mut cfg);
            cfg.sim.reorder_threads = reorder_threads;
            for acc in [false, true] {
                out.push(CellSpec {
                    cfg: cfg.clone(),
                    policy: SchedPolicy::ocwf(acc),
                    setting: si as f64,
                    trial: 0,
                });
            }
        }
        out
    };
    let serial = sweep::run_specs(&reordered_specs(1), 1).unwrap();
    for sweep_threads in pool::test_thread_counts() {
        for reorder_threads in [2usize, 4] {
            let par = sweep::run_specs(&reordered_specs(reorder_threads), sweep_threads).unwrap();
            for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                let tag = format!(
                    "cell {i}, sweep_threads={sweep_threads}, reorder_threads={reorder_threads}"
                );
                assert_eq!(a.jcts, b.jcts, "JCTs diverged: {tag}");
                assert_eq!(a.makespan, b.makespan, "makespan diverged: {tag}");
                assert_eq!(a.wf_evals, b.wf_evals, "wf_evals diverged: {tag}");
            }
        }
    }
}

#[test]
fn repeated_parallel_runs_identical() {
    // Parallelism must also be internally deterministic: two 8-thread
    // runs of the same specs agree with each other.
    let specs = specs();
    let a = sweep::run_specs(&specs, 8).unwrap();
    let b = sweep::run_specs(&specs, 8).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.jcts, y.jcts);
    }
}

#[test]
fn figure_metrics_bitwise_stable_across_thread_counts() {
    let base = tiny_base();
    let alphas = [0.0, 2.0];
    let reference =
        sweep::fig_alpha_util_opts(&base, 0.5, &alphas, &SweepOptions::default()).unwrap();
    for threads in pool::test_thread_counts() {
        let fig = sweep::fig_alpha_util_opts(
            &base,
            0.5,
            &alphas,
            &SweepOptions::default().with_threads(threads),
        )
        .unwrap();
        assert_eq!(fig.cells.len(), reference.cells.len());
        for (a, b) in reference.cells.iter().zip(&fig.cells) {
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.setting, b.setting);
            assert_eq!(
                a.mean_jct.to_bits(),
                b.mean_jct.to_bits(),
                "{} @ {}: {} vs {}",
                a.policy,
                a.setting,
                a.mean_jct,
                b.mean_jct
            );
            assert_eq!(a.cdf.len(), b.cdf.len());
            for (p, q) in a.cdf.iter().zip(&b.cdf) {
                assert_eq!(p.0.to_bits(), q.0.to_bits());
                assert_eq!(p.1.to_bits(), q.1.to_bits());
            }
        }
    }
}

#[test]
fn trials_partition_the_seed_space() {
    // Multi-trial sweeps must give each trial its own stream and stay
    // thread-count independent.
    let base = tiny_base();
    let opts2 = SweepOptions::default().with_trials(3).with_threads(2);
    let opts8 = SweepOptions::default().with_trials(3).with_threads(8);
    let a = sweep::fig_alpha_util_opts(&base, 0.5, &[1.0], &opts2).unwrap();
    let b = sweep::fig_alpha_util_opts(&base, 0.5, &[1.0], &opts8).unwrap();
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.mean_jct.to_bits(), y.mean_jct.to_bits(), "{}", x.policy);
    }
    // And a different trial really is a different experiment: trial seeds
    // diverge from the base seed.
    assert_ne!(sweep::trial_seed(123, 1), 123);
    assert_ne!(sweep::trial_seed(123, 1), sweep::trial_seed(123, 2));
}

#[test]
fn pool_map_is_order_preserving_under_contention() {
    // Many tiny tasks with skewed runtimes: completion order scrambles,
    // output order must not.
    let out = pool::parallel_map(257, 8, |i| {
        if i % 13 == 0 {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        i * 3 + 1
    });
    let expected: Vec<usize> = (0..257).map(|i| i * 3 + 1).collect();
    assert_eq!(out, expected);
}
