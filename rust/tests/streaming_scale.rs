//! Million-job streaming-scale oracles.
//!
//! Three differential contracts, each pinning a streaming-scale layer to
//! its exact materialized twin:
//!
//! 1. The calendar-queue event core must pop in the *bit-identical*
//!    `(time, class, lane, seq)` order of the binary heap — on random
//!    interleaved event streams and on whole DES runs for every policy ×
//!    scenario preset.
//! 2. [`JobStream`] must reproduce `materialize_jobs` job for job (ids,
//!    arrivals, groups, μ vectors) on every preset and through the
//!    windowed CSV reader, and streaming runs must reproduce the
//!    materialized engines' JCT vectors.
//! 3. The bounded-memory structures must actually be bounded: the
//!    calendar's allocation footprint stays O(live events) under
//!    hundreds of thousands of pushes, the CSV window stays below the
//!    job count, and [`StreamStats`] is a fixed-size value type whose
//!    exact fields (n, min, max, mean) match the sort-based summary.

use taos::config::ExperimentConfig;
use taos::des::calendar::{CalendarQueue, EventQueueKind};
use taos::des::heap::{EventHeap, EventKind};
use taos::des::service::EngineKind;
use taos::job::Job;
use taos::sched::SchedPolicy;
use taos::sim::stream::{run_stream_experiment, JobStream, StreamStats};
use taos::sim::{materialize_jobs, run_experiment};
use taos::sweep;
use taos::trace::csv::CsvWindowReader;
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;
use taos::util::stats::Summary;

fn tiny_cfg(scenario: Scenario) -> ExperimentConfig {
    let mut cfg = sweep::quick_base(0x57AE);
    cfg.trace.jobs = 18;
    cfg.trace.total_tasks = 900;
    cfg.cluster.servers = 14;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    scenario.apply(&mut cfg);
    cfg
}

fn assert_jobs_eq(a: &[Job], b: &[Job], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: job count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{ctx}");
        assert_eq!(x.arrival, y.arrival, "{ctx}: job {}", x.id);
        assert_eq!(x.groups, y.groups, "{ctx}: job {}", x.id);
        assert_eq!(x.mu, y.mu, "{ctx}: job {}", x.id);
    }
}

#[test]
fn calendar_matches_heap_pop_order_on_random_streams() {
    // Random interleaved push/pop bursts, with same-slot ties across
    // both event classes and lanes, plus occasional far-future pushes to
    // force wheel overflow and rebase. `seq` is queue-private, so the
    // observable contract is the popped `(time, kind)` sequence — which
    // also covers push-order stability, because both queues stamp the
    // same push sequence.
    let mut rng = Rng::seed_from(0xCA1E);
    let mut heap = EventHeap::new();
    let mut cal = CalendarQueue::new();
    let mut now = 0u64;
    for round in 0..2_000 {
        for _ in 0..(1 + rng.gen_range(6)) {
            let time = match rng.gen_range(10) {
                0 => now, // same-slot tie with whatever pops next
                1 => now + 1_000_000 + rng.gen_range(1_000_000), // overflow
                _ => now + rng.gen_range(4_096),
            };
            let kind = if rng.gen_range(2) == 0 {
                EventKind::Complete {
                    server: rng.gen_range(8) as usize,
                    token: rng.gen_range(4),
                }
            } else {
                EventKind::Arrival {
                    job: rng.gen_range(8) as usize,
                }
            };
            heap.push(time, kind);
            cal.push(time, kind);
        }
        for _ in 0..rng.gen_range(8) {
            let h = heap.pop();
            let c = cal.pop();
            match (h, c) {
                (None, None) => break,
                (Some(h), Some(c)) => {
                    assert_eq!(
                        (h.time, h.kind),
                        (c.time, c.kind),
                        "pop order diverged at round {round}"
                    );
                    assert!(h.time >= now, "time went backwards");
                    now = h.time;
                }
                (h, c) => panic!("length diverged at round {round}: {h:?} vs {c:?}"),
            }
        }
        assert_eq!(heap.len(), cal.len(), "round {round}");
    }
    // Drain the rest in lockstep.
    while let Some(h) = heap.pop() {
        let c = cal.pop().expect("calendar ran dry first");
        assert_eq!((h.time, h.kind), (c.time, c.kind), "drain order diverged");
    }
    assert!(cal.pop().is_none());
    assert!(cal.is_empty());
}

#[test]
fn calendar_runs_bit_identical_to_heap_on_every_preset_and_policy() {
    for scenario in Scenario::ALL {
        let mut cfg = tiny_cfg(scenario);
        cfg.sim.engine = EngineKind::Des;
        let mut cal_cfg = cfg.clone();
        cal_cfg.sim.event_queue = EventQueueKind::Calendar;
        assert_eq!(cfg.sim.event_queue, EventQueueKind::Heap);
        for policy in SchedPolicy::ALL {
            let heap = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            let cal = run_experiment(&cal_cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            assert_eq!(
                heap.jcts,
                cal.jcts,
                "{}/{}: calendar queue must reproduce the heap's JCT vector",
                scenario.name(),
                policy.name()
            );
            assert_eq!(heap.makespan, cal.makespan, "{}/{}", scenario.name(), policy.name());
            assert_eq!(heap.wf_evals, cal.wf_evals, "{}/{}", scenario.name(), policy.name());
            assert_eq!(
                heap.telemetry.events,
                cal.telemetry.events,
                "{}/{}: the processed event sequences must be identical",
                scenario.name(),
                policy.name()
            );
        }
    }
}

#[test]
fn job_stream_reproduces_materialize_jobs_on_every_preset() {
    for scenario in Scenario::ALL {
        let cfg = tiny_cfg(scenario);
        let all = materialize_jobs(&cfg).unwrap_or_else(|e| panic!("{}: {e}", scenario.name()));
        let streamed = JobStream::open(&cfg)
            .and_then(JobStream::collect_all)
            .unwrap_or_else(|e| panic!("{}: {e}", scenario.name()));
        assert_jobs_eq(&all, &streamed, scenario.name());
    }
}

#[test]
fn job_stream_reproduces_materialize_jobs_through_csv() {
    let dir = std::env::temp_dir().join("taos_streaming_scale_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    let mut tcfg = taos::config::TraceConfig::default();
    tcfg.jobs = 30;
    tcfg.total_tasks = 900;
    let trace = Scenario::Alibaba.synth(&tcfg, &mut Rng::seed_from(11));
    std::fs::write(&path, taos::trace::csv::to_batch_task_csv(&trace)).unwrap();
    let path = path.to_str().unwrap().to_string();

    let mut cfg = sweep::quick_base(0xC5F);
    cfg.trace.csv_path = Some(path.clone());
    let all = materialize_jobs(&cfg).unwrap();
    assert_eq!(all.len(), 30);
    let streamed = JobStream::open(&cfg).and_then(JobStream::collect_all).unwrap();
    assert_jobs_eq(&all, &streamed, "csv");

    // The windowed reader is genuinely windowed: with a lookahead of 1/8
    // of the trace span, early jobs retire before late ones open.
    let (mut wide, stats) = CsvWindowReader::open(&path, 1e18).unwrap();
    let mut n = 0;
    while wide.next_trace_job().unwrap().is_some() {
        n += 1;
    }
    assert_eq!(n, stats.jobs);
    assert_eq!(stats.jobs, 30);
    let (mut narrow, _) = CsvWindowReader::open(&path, (stats.raw_last / 8.0).max(1.0)).unwrap();
    let mut m = 0;
    while narrow.next_trace_job().unwrap().is_some() {
        m += 1;
    }
    assert_eq!(m, stats.jobs, "the bounded window must not drop jobs");
    assert!(
        narrow.peak_window() < stats.jobs,
        "peak window {} must stay below the job count {}",
        narrow.peak_window(),
        stats.jobs
    );

    // And the full streaming pipeline over the CSV matches the
    // materialized engines on both engine kinds.
    let policy = SchedPolicy::fifo(taos::assign::AssignPolicy::Wf);
    for engine in [EngineKind::Analytic, EngineKind::Des] {
        cfg.sim.engine = engine;
        let full = run_experiment(&cfg, policy).unwrap();
        let stream = run_stream_experiment(&cfg, policy).unwrap();
        assert_eq!(full.jcts, stream.jcts, "{}: csv streaming run", engine.name());
        assert_eq!(full.makespan, stream.makespan, "{}", engine.name());
        assert!(stream.telemetry.peak_window >= 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_runs_match_materialized_runs_on_unit_locality_presets() {
    for scenario in Scenario::ALL {
        let cfg = tiny_cfg(scenario);
        if cfg.sim.locality_penalty > 1.0 {
            // Outside the streaming scope (asserted below).
            continue;
        }
        // Jsq rides along as the baseline-panel representative: streaming
        // ingestion must reproduce the materialized run for the new
        // assigners too, not just the paper pair.
        for alg in [
            taos::assign::AssignPolicy::Wf,
            taos::assign::AssignPolicy::Rd,
            taos::assign::AssignPolicy::Jsq,
        ] {
            let policy = SchedPolicy::fifo(alg);
            let full = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), alg.name()));
            let stream = run_stream_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), alg.name()));
            assert_eq!(
                full.jcts,
                stream.jcts,
                "{}/{}: streaming run must reproduce the materialized JCT vector",
                scenario.name(),
                alg.name()
            );
            assert_eq!(full.makespan, stream.makespan, "{}/{}", scenario.name(), alg.name());
            assert_eq!(stream.jcts.len(), cfg.trace.jobs, "{}", scenario.name());
            if cfg.sim.engine == EngineKind::Des {
                assert!(stream.telemetry.events > 0, "{}", scenario.name());
                assert!(stream.telemetry.peak_events > 0, "{}", scenario.name());
                assert!(stream.telemetry.peak_window >= 1, "{}", scenario.name());
            } else {
                // Synthetic analytic streaming holds exactly one job.
                assert_eq!(stream.telemetry.peak_window, 1, "{}", scenario.name());
            }
        }
    }
    // All three layers composed: streaming ingestion + calendar core vs
    // the materialized heap run.
    let mut cfg = tiny_cfg(Scenario::Alibaba);
    cfg.sim.engine = EngineKind::Des;
    let policy = SchedPolicy::fifo(taos::assign::AssignPolicy::Wf);
    let heap_full = run_experiment(&cfg, policy).unwrap();
    cfg.sim.event_queue = EventQueueKind::Calendar;
    let cal_stream = run_stream_experiment(&cfg, policy).unwrap();
    assert_eq!(
        heap_full.jcts, cal_stream.jcts,
        "calendar-core streaming run must match the materialized heap run"
    );
    assert_eq!(heap_full.makespan, cal_stream.makespan);
}

#[test]
fn streaming_rejects_out_of_scope_configs() {
    let cfg = tiny_cfg(Scenario::Alibaba);
    let err = run_stream_experiment(&cfg, SchedPolicy::ocwf(false))
        .unwrap_err()
        .to_string();
    assert!(err.contains("FIFO"), "{err}");

    let mut cfg = tiny_cfg(Scenario::Alibaba);
    cfg.sim.engine = EngineKind::Des;
    cfg.sim.locality_penalty = 2.0;
    let err = run_stream_experiment(&cfg, SchedPolicy::fifo(taos::assign::AssignPolicy::Wf))
        .unwrap_err()
        .to_string();
    assert!(err.contains("locality_penalty"), "{err}");
}

#[test]
fn calendar_footprint_stays_bounded_under_streaming_churn() {
    // Hold the live population at 64 while half a million events cycle
    // through — with periodic million-slot jumps to force overflow and
    // rebase. Every backing allocation is O(live): the wheel has a fixed
    // 256 buckets and each Vec's capacity is bounded by the peak
    // simultaneous occupancy it ever saw (≤ 64, ≤ 128 after growth
    // doubling), so the frozen footprint sits orders of magnitude below
    // the 500k total pushes.
    let mut rng = Rng::seed_from(0xF00);
    let mut cq = CalendarQueue::new();
    for i in 0..64 {
        cq.push(rng.gen_range(1_000), EventKind::Arrival { job: i });
    }
    let mut pushed = 64usize;
    while pushed < 500_000 {
        let ev = cq.pop().expect("live population never empties");
        let step = if pushed % 977 == 0 {
            1_000_000
        } else {
            1 + rng.gen_range(4_096)
        };
        cq.push(
            ev.time + step,
            EventKind::Complete {
                server: pushed % 64,
                token: pushed as u64,
            },
        );
        pushed += 1;
    }
    assert_eq!(cq.len(), 64);
    let fp = cq.footprint();
    assert!(
        fp < 40_000,
        "footprint {fp} must stay O(live events), not O(total pushed)"
    );
    let mut prev = 0;
    while let Some(ev) = cq.pop() {
        assert!(ev.time >= prev, "drain left the time order");
        prev = ev.time;
    }
    assert!(cq.is_empty());
    assert_eq!(cq.len(), 0);
}

#[test]
fn stream_stats_is_fixed_size_and_exact_on_the_exact_fields() {
    // The sketch is a Copy value type: its size is frozen at compile
    // time no matter how many samples pass through.
    assert!(
        std::mem::size_of::<StreamStats>() <= 1024,
        "StreamStats must stay a small fixed-size value"
    );
    let cfg = tiny_cfg(Scenario::Alibaba);
    let out = run_experiment(&cfg, SchedPolicy::fifo(taos::assign::AssignPolicy::Wf)).unwrap();
    let s = StreamStats::from_jcts(&out.jcts);
    let xs: Vec<f64> = out.jcts.iter().map(|&x| x as f64).collect();
    let exact = Summary::from(&xs);
    assert_eq!(s.n() as usize, exact.n);
    assert_eq!(s.min(), exact.min, "min is tracked exactly");
    assert_eq!(s.max(), exact.max, "max is tracked exactly");
    assert!(
        (s.mean() - exact.mean).abs() <= 1e-9 * exact.mean.abs().max(1.0),
        "Welford mean {} vs exact {}",
        s.mean(),
        exact.mean
    );
    for (q, v) in [("p50", s.p50()), ("p90", s.p90()), ("p99", s.p99())] {
        assert!(
            (exact.min..=exact.max).contains(&v),
            "{q} sketch value {v} escaped the sample range [{}, {}]",
            exact.min,
            exact.max
        );
    }
}
