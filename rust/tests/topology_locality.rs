//! Integration tests for the hierarchical network-cost locality model:
//! the flat/unit-penalty differential against the analytic engine, the
//! tier hit-rate telemetry accounting, within-rack relabeling, and the
//! penalty monotonicity of a pinned job.
//!
//! The *strong* metamorphic invariant — the tier table commutes with any
//! within-rack server relabeling — is asserted at the topology layer
//! (`topology::tests`), where it is provable. End to end the assigners'
//! remainder placement follows server order, so only structural
//! invariants survive the trip through the scheduler; those are pinned
//! here.

use taos::assign::AssignPolicy;
use taos::config::{ExperimentConfig, SimConfig};
use taos::des::run_des;
use taos::des::service::EngineKind;
use taos::job::{Job, TaskGroup};
use taos::sched::SchedPolicy;
use taos::sim::{materialize_jobs, run_experiment};
use taos::topology::TopologyKind;
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;

fn tiny_cfg(scenario: Scenario) -> ExperimentConfig {
    let mut cfg = taos::sweep::quick_base(0x7090);
    cfg.trace.jobs = 16;
    cfg.trace.total_tasks = 800;
    cfg.cluster.servers = 16;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    scenario.apply(&mut cfg);
    cfg
}

#[test]
fn unit_penalty_des_is_flat_identical_for_every_topology() {
    // At penalty 1 every tier's rate weight is exactly 1.0 by
    // construction, so the hierarchy is inert: switching the topology
    // must not move a single completion time relative to the analytic
    // engine, on any workload preset.
    for scenario in Scenario::ALL {
        if scenario.has_engine_twist() {
            continue;
        }
        let cfg = tiny_cfg(scenario);
        for kind in TopologyKind::ALL {
            let mut des_cfg = cfg.clone();
            des_cfg.sim.engine = EngineKind::Des;
            des_cfg.sim.topology = kind;
            for policy in [
                SchedPolicy::fifo(AssignPolicy::Wf),
                SchedPolicy::ocwf(true),
            ] {
                let analytic = run_experiment(&cfg, policy)
                    .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
                let des = run_experiment(&des_cfg, policy)
                    .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
                assert_eq!(
                    analytic.jcts,
                    des.jcts,
                    "{}/{}/{}: unit-penalty DES must stay bit-identical",
                    scenario.name(),
                    kind.name(),
                    policy.name()
                );
                assert_eq!(analytic.makespan, des.makespan);
                assert_eq!(analytic.wf_evals, des.wf_evals);
                assert!(
                    des.tier_tasks.is_empty(),
                    "penalty 1 takes the locality-free path: no telemetry"
                );
            }
        }
    }
}

#[test]
fn tier_telemetry_counts_every_task_exactly_once() {
    let cfg = tiny_cfg(Scenario::Alibaba);
    let jobs = materialize_jobs(&cfg).unwrap();
    let total: u64 = jobs.iter().map(|j| j.total_tasks()).sum();
    for kind in TopologyKind::ALL {
        let mut sim = SimConfig::default();
        sim.locality_penalty = 3.0;
        sim.topology = kind;
        for policy in [
            SchedPolicy::fifo(AssignPolicy::Wf),
            SchedPolicy::ocwf(false),
        ] {
            let out = run_des(&jobs, cfg.cluster.servers, policy, &sim, 7).unwrap();
            assert_eq!(
                out.tier_tasks.len(),
                kind.num_tiers(),
                "{}/{}: one counter per tier",
                kind.name(),
                policy.name()
            );
            assert_eq!(
                out.tier_tasks.iter().sum::<u64>(),
                total,
                "{}/{}: every task lands in exactly one tier",
                kind.name(),
                policy.name()
            );
        }
    }
}

fn random_jobs(rng: &mut Rng, m: usize, njobs: usize) -> Vec<Job> {
    let mut arrival = 0u64;
    (0..njobs)
        .map(|id| {
            arrival += rng.gen_range(7);
            let k = 1 + rng.gen_range(3) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let ns = 1 + rng.gen_range(4) as usize;
                    let mut sv: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut sv);
                    sv.truncate(ns);
                    TaskGroup::new(rng.gen_range_incl(1, 24), sv)
                })
                .collect();
            Job {
                id,
                arrival,
                groups,
                mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
            }
        })
        .collect()
}

/// Apply the server relabeling `perm` (old id → new id) to a job list.
fn relabel_jobs(jobs: &[Job], perm: &[usize]) -> Vec<Job> {
    jobs.iter()
        .map(|j| {
            let mut mu = vec![0u64; perm.len()];
            for s in 0..perm.len() {
                mu[perm[s]] = j.mu[s];
            }
            Job {
                id: j.id,
                arrival: j.arrival,
                groups: j
                    .groups
                    .iter()
                    .map(|g| TaskGroup::new(g.size, g.servers.iter().map(|&s| perm[s]).collect()))
                    .collect(),
                mu,
            }
        })
        .collect()
}

#[test]
fn within_rack_relabeling_keeps_telemetry_shape() {
    // Swap (1,3) inside rack 0 and (8,10) inside rack 2: the tier table
    // commutes with this permutation (topology-layer theorem), and end to
    // end the run must keep the same tier arity with every task still
    // credited exactly once — at any policy and topology.
    let m = 16;
    let mut perm: Vec<usize> = (0..m).collect();
    perm.swap(1, 3);
    perm.swap(8, 10);
    let mut rng = Rng::seed_from(0x7ACC);
    for case in 0..6 {
        let jobs = random_jobs(&mut rng, m, 3 + case);
        let total: u64 = jobs.iter().map(|j| j.total_tasks()).sum();
        let renamed = relabel_jobs(&jobs, &perm);
        for kind in [
            TopologyKind::MultiRack,
            TopologyKind::MultiZone,
            TopologyKind::FatTree,
        ] {
            let mut sim = SimConfig::default();
            sim.locality_penalty = 2.0;
            sim.topology = kind;
            for policy in [
                SchedPolicy::fifo(AssignPolicy::Wf),
                SchedPolicy::ocwf(true),
            ] {
                let a = run_des(&jobs, m, policy, &sim, 3).unwrap();
                let b = run_des(&renamed, m, policy, &sim, 3).unwrap();
                assert_eq!(
                    a.tier_tasks.len(),
                    b.tier_tasks.len(),
                    "case {case} {}/{}",
                    kind.name(),
                    policy.name()
                );
                assert_eq!(
                    a.tier_tasks.iter().sum::<u64>(),
                    total,
                    "case {case} {}/{}",
                    kind.name(),
                    policy.name()
                );
                assert_eq!(
                    b.tier_tasks.iter().sum::<u64>(),
                    total,
                    "case {case} {}/{}: relabeled run must credit every task too",
                    kind.name(),
                    policy.name()
                );
                assert_eq!(a.jcts.len(), b.jcts.len());
            }
        }
    }
}

#[test]
fn growing_penalty_never_speeds_a_pinned_job() {
    // One job local to server 0 only, uniform capacity: the assigners are
    // penalty-oblivious, so the expanded placement is identical at every
    // penalty > 1 and the DES charges weakly longer remote durations as
    // the top-tier penalty grows — the JCT cannot improve.
    let jobs = vec![Job {
        id: 0,
        arrival: 0,
        groups: vec![TaskGroup::new(120, vec![0])],
        mu: vec![2; 16],
    }];
    let mut prev: Option<u64> = None;
    for p in [2.0, 4.0, 8.0] {
        let mut sim = SimConfig::default();
        sim.topology = TopologyKind::MultiZone;
        sim.locality_penalty = p;
        let out = run_des(&jobs, 16, SchedPolicy::fifo(AssignPolicy::Wf), &sim, 3).unwrap();
        assert_eq!(out.tier_tasks.len(), 4);
        assert_eq!(out.tier_tasks.iter().sum::<u64>(), 120);
        let jct = out.jcts[0];
        if let Some(q) = prev {
            assert!(
                jct >= q,
                "penalty {p}: JCT {jct} must not beat the cheaper run's {q}"
            );
        }
        prev = Some(jct);
    }
}
