//! End-to-end integration tests over full trace replays: the paper's
//! qualitative claims must hold on scaled-down versions of its setup, and
//! the algorithm-level invariants must hold along entire simulations.

use taos::assign::wf::Wf;
use taos::assign::{bounds, validate_assignment, AssignPolicy, Assigner, Instance};
use taos::cluster::placement::Placement;
use taos::cluster::Cluster;
use taos::config::ExperimentConfig;
use taos::job::TaskGroup;
use taos::proptest::{forall, Config};
use taos::sched::SchedPolicy;
use taos::sim::{run_experiment, run_policy};
use taos::trace::Trace;
use taos::util::rng::Rng;

fn quick_cfg(seed: u64, alpha: f64, util: f64) -> ExperimentConfig {
    let mut cfg = taos::sweep::quick_base(seed);
    cfg.cluster.zipf_alpha = alpha;
    cfg.trace.utilization = util;
    cfg
}

#[test]
fn all_six_algorithms_complete_a_trace() {
    let cfg = quick_cfg(1, 1.0, 0.5);
    for policy in SchedPolicy::ALL {
        let out = run_experiment(&cfg, policy).expect(policy.name());
        assert_eq!(out.jcts.len(), cfg.trace.jobs, "{}", policy.name());
        assert!(out.makespan > 0, "{}", policy.name());
        assert!(out.overhead.count() > 0, "{}", policy.name());
    }
}

#[test]
fn obta_and_nlip_identical_jcts_across_whole_trace() {
    // Both solve P exactly, so their schedules coincide job for job
    // (the paper: "OBTA and NLIP have fairly close performance ... both
    // are theoretically optimal").
    let cfg = quick_cfg(2, 2.0, 0.75);
    let obta = run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Obta)).unwrap();
    let nlip = run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Nlip)).unwrap();
    assert_eq!(obta.jcts, nlip.jcts);
    // And the narrowing must cut the number of feasibility probes (the
    // deterministic measure of the paper's efficiency claim; wall-clock
    // is seed/load-noisy and is reported by the benches instead).
    // (ilp_unknown is a subset of ilp_calls, not an extra probe.)
    let probes = |s: &taos::assign::feasible::OracleStats| {
        s.flow_infeasible + s.ceil_feasible + s.floor_residual_feasible + s.ilp_calls
    };
    let po = probes(&obta.oracle_stats.unwrap());
    let pn = probes(&nlip.oracle_stats.unwrap());
    assert!(
        po * 2 <= pn,
        "narrowing should at least halve the probe count: OBTA {po} vs NLIP {pn}"
    );
}

#[test]
fn ocwf_acc_identical_to_ocwf_and_cheaper() {
    let cfg = quick_cfg(3, 2.0, 0.75);
    let ocwf = run_experiment(&cfg, SchedPolicy::ocwf(false)).unwrap();
    let acc = run_experiment(&cfg, SchedPolicy::ocwf(true)).unwrap();
    assert_eq!(ocwf.jcts, acc.jcts, "early-exit must not change the schedule");
    assert!(
        acc.wf_evals < ocwf.wf_evals,
        "early-exit must prune WF evaluations ({} vs {})",
        acc.wf_evals,
        ocwf.wf_evals
    );
}

#[test]
fn wf_overhead_orders_of_magnitude_below_obta() {
    let cfg = quick_cfg(4, 1.0, 0.5);
    let wf = run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Wf)).unwrap();
    let obta = run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Obta)).unwrap();
    assert!(
        wf.overhead.mean_us() * 10.0 < obta.overhead.mean_us(),
        "WF {:.1}us vs OBTA {:.1}us",
        wf.overhead.mean_us(),
        obta.overhead.mean_us()
    );
}

#[test]
fn reordering_robust_to_skew_fifo_degrades() {
    // Figs 10-12's trend: FIFO JCT grows sharply with alpha; OCWF stays
    // comparatively flat.
    let lo = run_experiment(&quick_cfg(5, 0.0, 0.75), SchedPolicy::fifo(AssignPolicy::Wf))
        .unwrap()
        .mean_jct();
    let hi = run_experiment(&quick_cfg(5, 2.0, 0.75), SchedPolicy::fifo(AssignPolicy::Wf))
        .unwrap()
        .mean_jct();
    let ocwf_lo = run_experiment(&quick_cfg(5, 0.0, 0.75), SchedPolicy::ocwf(true))
        .unwrap()
        .mean_jct();
    let ocwf_hi = run_experiment(&quick_cfg(5, 2.0, 0.75), SchedPolicy::ocwf(true))
        .unwrap()
        .mean_jct();
    assert!(hi > lo, "FIFO WF must degrade with skew: {lo} -> {hi}");
    let fifo_growth = hi / lo;
    let ocwf_growth = ocwf_hi / ocwf_lo.max(1e-9);
    assert!(
        ocwf_growth < fifo_growth,
        "reordering must dampen skew: fifo x{fifo_growth:.2} vs ocwf x{ocwf_growth:.2}"
    );
}

#[test]
fn jct_decreases_with_utilization_drop() {
    for policy in [SchedPolicy::fifo(AssignPolicy::Wf), SchedPolicy::ocwf(true)] {
        let hi = run_experiment(&quick_cfg(6, 1.0, 0.75), policy).unwrap().mean_jct();
        let lo = run_experiment(&quick_cfg(6, 1.0, 0.25), policy).unwrap().mean_jct();
        assert!(
            lo < hi,
            "{}: 25% util {lo} must beat 75% util {hi}",
            policy.name()
        );
    }
}

#[test]
fn csv_trace_roundtrip_through_simulation() {
    // gen-trace style CSV -> parse -> materialize -> simulate.
    let mut tcfg = taos::config::TraceConfig::default();
    tcfg.jobs = 12;
    tcfg.total_tasks = 600;
    let mut rng = Rng::seed_from(9);
    let trace = Trace::synth_alibaba(&tcfg, &mut rng);
    let mut csv = String::new();
    for (j, job) in trace.jobs.iter().enumerate() {
        for (g, size) in job.group_sizes.iter().enumerate() {
            csv.push_str(&format!(
                "{:.0},{:.0},j_{j:04},t_{g},{size},Terminated,100,0.5\n",
                job.arrival_raw * 1000.0,
                job.arrival_raw * 1000.0 + 1.0
            ));
        }
    }
    let parsed = taos::trace::csv::parse_batch_task(&csv).unwrap();
    assert_eq!(parsed.total_tasks(), trace.total_tasks());
    assert_eq!(parsed.jobs.len(), trace.jobs.len());

    let mut ccfg = taos::config::ClusterConfig::default();
    ccfg.servers = 20;
    ccfg.avail_lo = 3;
    ccfg.avail_hi = 5;
    let cluster = Cluster::generate(&ccfg, &mut rng);
    let placement = Placement::new(20, 1.0, &mut rng);
    let jobs = parsed
        .materialize(&cluster, &placement, 0.5, &mut rng)
        .unwrap();
    let out =
        run_policy(&jobs, 20, SchedPolicy::fifo(AssignPolicy::Rd), &Default::default(), 3).unwrap();
    assert_eq!(out.jcts.len(), 12);
}

// ---------- property tests over the algorithm invariants ----------

fn random_instance_owned(rng: &mut Rng) -> (Vec<TaskGroup>, Vec<u64>, Vec<u64>) {
    let m = 2 + rng.gen_range(6) as usize;
    let k = 1 + rng.gen_range(4) as usize;
    let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(1, 5)).collect();
    let busy: Vec<u64> = (0..m).map(|_| rng.gen_range(10)).collect();
    let groups: Vec<TaskGroup> = (0..k)
        .map(|_| {
            let ns = 1 + rng.gen_range(m as u64) as usize;
            let mut sv: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut sv);
            sv.truncate(ns);
            TaskGroup::new(rng.gen_range_incl(1, 50), sv)
        })
        .collect();
    (groups, mu, busy)
}

#[test]
fn property_every_assigner_covers_all_tasks() {
    forall(
        Config::default().cases(80).seed(0xA11),
        |rng| random_instance_owned(rng),
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            AssignPolicy::ALL.iter().all(|p| {
                let a = p.build(1).assign(&inst);
                validate_assignment(&inst, &a).is_ok()
            })
        },
    );
}

#[test]
fn property_wf_within_kc_times_opt() {
    // Theorem 2: WF <= K_c * OPT on every instance.
    forall(
        Config::default().cases(60).seed(0xA12),
        |rng| random_instance_owned(rng),
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            let wf = Wf::new().assign(&inst);
            let opt = AssignPolicy::Obta.build(0).assign(&inst);
            wf.phi <= opt.phi * groups.len() as u64
        },
    );
}

#[test]
fn property_phi_bounds_bracket_opt() {
    // eqs. (5)-(7): Φ⁻ <= Φ* and Φ* within the (collision-padded) Φ⁺.
    forall(
        Config::default().cases(60).seed(0xA13),
        |rng| random_instance_owned(rng),
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            let opt = AssignPolicy::Obta.build(0).assign(&inst);
            let lo = bounds::phi_lower(&inst);
            let hi = bounds::phi_upper(&inst) + groups.len() as u64;
            lo <= opt.phi && opt.phi <= hi
        },
    );
}

#[test]
fn property_rd_never_beats_opt_and_covers() {
    forall(
        Config::default().cases(60).seed(0xA14),
        |rng| random_instance_owned(rng),
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            let rd = AssignPolicy::Rd.build(5).assign(&inst);
            let opt = AssignPolicy::Obta.build(0).assign(&inst);
            opt.phi <= rd.phi
        },
    );
}

#[test]
fn property_theorem1_family_ratio() {
    // The Thm-1 family: ratio WF/OPT = K_c·θ / (θ+2) for every θ ≥ 2 —
    // approaching K_c as θ grows.
    for theta in [2u64, 3, 5, 8] {
        let k_c = 3usize;
        let sizes: Vec<u64> = (1..=k_c)
            .map(|k| (1..=(k_c - k + 1) as u32).map(|e| theta.pow(e)).sum())
            .collect();
        let m_total = sizes[0] as usize;
        let groups: Vec<TaskGroup> = (0..k_c)
            .map(|k| TaskGroup::new(theta * sizes[k], (0..sizes[k] as usize).collect()))
            .collect();
        let mu = vec![1u64; m_total];
        let busy = vec![0u64; m_total];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let wf = Wf::new().assign(&inst);
        let opt = AssignPolicy::Obta.build(0).assign(&inst);
        assert_eq!(wf.phi, k_c as u64 * theta, "theta {theta}");
        assert_eq!(opt.phi, theta + 2, "theta {theta}");
    }
}

#[test]
fn deterministic_replay_same_seed_same_results() {
    let cfg = quick_cfg(7, 1.5, 0.5);
    for policy in [SchedPolicy::fifo(AssignPolicy::Rd), SchedPolicy::ocwf(true)] {
        let a = run_experiment(&cfg, policy).unwrap();
        let b = run_experiment(&cfg, policy).unwrap();
        assert_eq!(a.jcts, b.jcts, "{}", policy.name());
        assert_eq!(a.makespan, b.makespan);
    }
}
