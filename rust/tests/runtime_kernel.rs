//! Cross-layer integration: the rust PJRT runtime loading and executing
//! the AOT artifacts, and the L1 Pallas kernels agreeing with the L3
//! native implementations.
//!
//! Requires `make artifacts` (skips with a clear message otherwise — CI
//! runs `make test`, which builds them first).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use taos::coordinator::{verify, AccelHandle};
use taos::runtime::{ArtifactIndex, PjrtRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let idx = ArtifactIndex::load(&dir).unwrap();
    for name in ["wf_phi", "wf_phi_large", "payload"] {
        assert!(idx.names().contains(&name), "missing {name}");
        assert!(idx.path_of(name).unwrap().exists());
    }
    assert_eq!(idx.param("payload", "D").unwrap(), 32);
}

#[test]
fn pjrt_loads_and_runs_payload() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu"));
    let idx = ArtifactIndex::load(&dir).unwrap();
    let exe = rt.load_hlo_text(&idx.path_of("payload").unwrap()).unwrap();
    let n = idx.param("payload", "N").unwrap() as usize;
    let d = idx.param("payload", "D").unwrap() as usize;
    let x = vec![0.0f32; n * d];
    let outs = exe.run_f32(&[(&x, &[n as i64, d as i64])]).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].len(), n);
    // tanh(0)^2 summed = 0.
    assert!(outs[0].iter().all(|&y| y.abs() < 1e-6));

    // Nonzero input must produce nonzero, bounded output (tanh² ≤ 1 per
    // feature).
    let x: Vec<f32> = (0..n * d).map(|i| (i % 7) as f32 * 0.3 - 0.9).collect();
    let outs = exe.run_f32(&[(&x, &[n as i64, d as i64])]).unwrap();
    let f = (d / 2) as f32;
    assert!(outs[0].iter().any(|&y| y > 1e-3));
    assert!(outs[0].iter().all(|&y| (0.0..=f + 1e-3).contains(&y)));
}

#[test]
fn payload_matches_rust_reimplementation() {
    // The projection W is deterministic (kernels/payload.py
    // fixed_projection); recompute it here and cross-check the full
    // pipeline rust-side.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let exe = rt.load_hlo_text(&idx.path_of("payload").unwrap()).unwrap();
    let n = idx.param("payload", "N").unwrap() as usize;
    let d = idx.param("payload", "D").unwrap() as usize;
    let f = d / 2;

    // fixed_projection(d, f, seed=0x7A05): sin(i*12.9898 + j*78.233 + s)*0.43
    let s = (0x7A05 % 1000) as f32 / 1000.0;
    let w: Vec<f32> = (0..d)
        .flat_map(|i| {
            (0..f).map(move |j| ((i as f32) * 12.9898 + (j as f32) * 78.233 + s).sin() * 0.43)
        })
        .collect();

    let x: Vec<f32> = (0..n * d).map(|i| ((i * 37 % 101) as f32 / 50.5) - 1.0).collect();
    let outs = exe.run_f32(&[(&x, &[n as i64, d as i64])]).unwrap();
    for row in 0..n {
        let mut expect = 0.0f64;
        for jf in 0..f {
            let mut acc = 0.0f64;
            for jd in 0..d {
                acc += x[row * d + jd] as f64 * w[jd * f + jf] as f64;
            }
            let t = acc.tanh();
            expect += t * t;
        }
        let got = outs[0][row] as f64;
        assert!(
            (got - expect).abs() < 1e-3,
            "row {row}: kernel {got} vs rust {expect}"
        );
    }
}

#[test]
fn wf_kernel_agrees_with_native_wf() {
    let Some(dir) = artifacts_dir() else { return };
    let (checked, _) = verify::verify_wf_kernel(&dir, 48, 0xBEEF).unwrap();
    assert_eq!(checked, 48);
}

#[test]
fn accel_service_coalesces_concurrent_payloads() {
    let Some(dir) = artifacts_dir() else { return };
    let accel = Arc::new(AccelHandle::spawn(&dir).unwrap());
    let d = accel.payload_d;
    let mut joins = Vec::new();
    for t in 0..16 {
        let accel = Arc::clone(&accel);
        joins.push(std::thread::spawn(move || {
            let row: Vec<f32> = (0..d).map(|i| ((t * 31 + i) % 13) as f32 * 0.1).collect();
            accel.payload(row).unwrap()
        }));
    }
    let results: Vec<f32> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(results.len(), 16);
    assert!(results.iter().all(|y| y.is_finite()));
    // Identical rows must give identical answers regardless of batching.
    let row: Vec<f32> = (0..d).map(|i| (i % 5) as f32 * 0.2).collect();
    let a = accel.payload(row.clone()).unwrap();
    let b = accel.payload(row).unwrap();
    assert_eq!(a, b);
}

#[test]
fn offloaded_reorder_matches_native_ocwf() {
    // The §IV reordering with candidate Φ evaluated by the AOT Pallas
    // kernel must produce the same order and assignments as the native
    // rust driver.
    let Some(dir) = artifacts_dir() else { return };
    use taos::coordinator::reorder_offload::{native_reorder, OffloadedReorder};
    use taos::job::{Job, TaskGroup};
    use taos::sched::ocwf::Outstanding;
    use taos::util::rng::Rng;

    let accel = Arc::new(AccelHandle::spawn(&dir).unwrap());
    let offload = OffloadedReorder::new(Arc::clone(&accel));
    let m = (accel.wf_m).min(12);
    let mut rng = Rng::seed_from(0xF00D);
    for case in 0..6 {
        let njobs = 2 + rng.gen_range(6) as usize;
        let jobs: Vec<Job> = (0..njobs)
            .map(|id| {
                let k = 1 + rng.gen_range(4) as usize;
                let groups: Vec<TaskGroup> = (0..k)
                    .map(|_| {
                        let ns = 1 + rng.gen_range(m as u64) as usize;
                        let mut sv: Vec<usize> = (0..m).collect();
                        rng.shuffle(&mut sv);
                        sv.truncate(ns);
                        TaskGroup::new(rng.gen_range_incl(1, 40), sv)
                    })
                    .collect();
                Job {
                    id,
                    arrival: id as u64,
                    groups,
                    mu: (0..m).map(|_| rng.gen_range_incl(1, 5)).collect(),
                }
            })
            .collect();
        let outstanding: Vec<Outstanding> = jobs
            .iter()
            .map(|j| Outstanding {
                job: j,
                remaining: j.groups.iter().map(|g| g.size).collect(),
            })
            .collect();
        let native = native_reorder(&outstanding, m);
        let offloaded = offload.reorder(&outstanding, m).unwrap();
        assert_eq!(native.order, offloaded.order, "case {case}");
        assert_eq!(native.assignments, offloaded.assignments, "case {case}");
    }
}

#[test]
fn wf_phi_large_artifact_loads() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let idx = ArtifactIndex::load(&dir).unwrap();
    let exe = rt
        .load_hlo_text(&idx.path_of("wf_phi_large").unwrap())
        .unwrap();
    let (b, k, m) = (
        idx.param("wf_phi_large", "B").unwrap() as usize,
        idx.param("wf_phi_large", "K").unwrap() as usize,
        idx.param("wf_phi_large", "M").unwrap() as usize,
    );
    // One non-trivial row, rest padded.
    let mut busy = vec![0i32; b * m];
    let mut mu = vec![1i32; b * m];
    let mut sizes = vec![0i32; b * k];
    let mut avail = vec![0i32; b * k * m];
    busy[0] = 3;
    mu[0] = 2;
    mu[1] = 2;
    sizes[0] = 10;
    avail[0] = 1;
    avail[1] = 1;
    let outs = exe
        .run_i32(&[
            (&busy, &[b as i64, m as i64]),
            (&mu, &[b as i64, m as i64]),
            (&sizes, &[b as i64, k as i64]),
            (&avail, &[b as i64, k as i64, m as i64]),
        ])
        .unwrap();
    // Water level: busy (3,0), mu (2,2), 10 tasks: level 4 gives
    // (1+4)*2 = 10 -> xi = 4.
    assert_eq!(outs[0][0], 4);
    assert!(outs[0][1..].iter().all(|&p| p == 0), "padded rows are zero");
}
