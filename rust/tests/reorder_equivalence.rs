//! Parallel-vs-serial equivalence of the OCWF(-ACC) reorder driver.
//!
//! The two-phase driver (`sched::ocwf::reorder_into`) fans candidate Φ
//! evaluations across worker threads but replays the serial decision
//! rules, so the schedule must be **bit-identical at any thread count**:
//! same `ReorderOutcome` (order, assignments, wf_evals) per round, and
//! therefore same JCT vector / makespan / total wf_evals per simulation —
//! across every named workload scenario in the catalog.

use taos::config::ExperimentConfig;
use taos::job::Job;
use taos::sched::ocwf::{reorder_into, Outstanding, ReorderOutcome, ReorderWorkspace};
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;

fn scenario_cfg(sc: Scenario, reorder_threads: usize) -> ExperimentConfig {
    let mut cfg = taos::sweep::quick_base(77);
    cfg.trace.jobs = 18;
    cfg.trace.total_tasks = 1_000;
    cfg.cluster.servers = 16;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    sc.apply(&mut cfg);
    cfg.sim.reorder_threads = reorder_threads;
    cfg
}

#[test]
fn reordered_schedules_bit_identical_across_thread_counts() {
    // Thread counts come from TAOS_TEST_THREADS (default 1,2,8) so the CI
    // matrix can pin one count per leg.
    let counts = taos::sweep::pool::test_thread_counts();
    for sc in Scenario::ALL {
        for acc in [false, true] {
            let reference = run_experiment(&scenario_cfg(sc, 1), SchedPolicy::ocwf(acc))
                .unwrap_or_else(|e| panic!("{}: {e}", sc.name()));
            for &threads in &counts {
                let out = run_experiment(&scenario_cfg(sc, threads), SchedPolicy::ocwf(acc))
                    .unwrap_or_else(|e| panic!("{}: {e}", sc.name()));
                let tag = format!("{} acc={acc} threads={threads}", sc.name());
                assert_eq!(reference.jcts, out.jcts, "JCTs diverged: {tag}");
                assert_eq!(reference.makespan, out.makespan, "makespan diverged: {tag}");
                assert_eq!(reference.wf_evals, out.wf_evals, "wf_evals diverged: {tag}");
            }
        }
    }
}

#[test]
fn acc_still_prunes_under_parallel_rounds() {
    // The early-exit savings must survive the chunked speculative driver:
    // the *counted* wf_evals are the serial ACC's, at every thread count.
    for sc in Scenario::ALL {
        let plain = run_experiment(&scenario_cfg(sc, 8), SchedPolicy::ocwf(false))
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name()));
        let accd = run_experiment(&scenario_cfg(sc, 8), SchedPolicy::ocwf(true))
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name()));
        assert_eq!(plain.jcts, accd.jcts, "{}: OCWF == OCWF-ACC", sc.name());
        assert!(
            accd.wf_evals <= plain.wf_evals,
            "{}: ACC must not count more evals ({} vs {})",
            sc.name(),
            accd.wf_evals,
            plain.wf_evals
        );
    }
}

fn random_jobs(rng: &mut Rng, m: usize, njobs: usize) -> Vec<Job> {
    use taos::job::TaskGroup;
    (0..njobs)
        .map(|id| {
            let k = 1 + rng.gen_range(4) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let ns = 1 + rng.gen_range(m as u64) as usize;
                    let mut sv: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut sv);
                    sv.truncate(ns);
                    TaskGroup::new(rng.gen_range_incl(1, 40), sv)
                })
                .collect();
            Job {
                id,
                arrival: id as u64,
                groups,
                mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
            }
        })
        .collect()
}

#[test]
fn reorder_outcome_byte_identical_at_1_2_8_threads() {
    // Direct driver-level check including partially processed jobs: the
    // full ReorderOutcome must match field for field.
    let m = 8;
    let mut rng = Rng::seed_from(0x0C3F);
    for case in 0..15 {
        let jobs = random_jobs(&mut rng, m, 2 + (case % 9));
        let mut outstanding: Vec<Outstanding> = jobs
            .iter()
            .map(|j| Outstanding {
                job: j,
                remaining: j.groups.iter().map(|g| g.size).collect(),
            })
            .collect();
        // Simulate partial progress on some jobs.
        for o in outstanding.iter_mut().step_by(2) {
            for r in o.remaining.iter_mut() {
                *r -= *r / 2;
            }
        }
        for acc in [false, true] {
            let mut reference = ReorderOutcome::default();
            reorder_into(
                &outstanding,
                m,
                acc,
                1,
                &mut ReorderWorkspace::default(),
                &mut reference,
            );
            for threads in taos::sweep::pool::test_thread_counts() {
                let mut out = ReorderOutcome::default();
                reorder_into(
                    &outstanding,
                    m,
                    acc,
                    threads,
                    &mut ReorderWorkspace::default(),
                    &mut out,
                );
                assert_eq!(
                    reference, out,
                    "case {case} acc={acc} threads={threads} diverged"
                );
            }
        }
    }
}

#[test]
fn composed_sweep_and_reorder_fanout_matches_direct_serial_run() {
    // Combined sweep × reorder case: the same scenario config executed
    // (a) directly, serial everywhere, and (b) as cells of a 4-thread
    // sweep whose cells each fan reorder rounds across 4 threads — the
    // shape the executor's admission budget exists for. Schedules and
    // wf_evals must be byte-identical; only wall-clock may differ.
    use taos::sweep::{run_specs, CellSpec};
    let scenarios = [Scenario::Bursty, Scenario::HotspotHeavyTail];
    let mut specs = Vec::new();
    for (si, sc) in scenarios.into_iter().enumerate() {
        for acc in [false, true] {
            specs.push(CellSpec {
                cfg: scenario_cfg(sc, 4),
                policy: SchedPolicy::ocwf(acc),
                setting: si as f64,
                trial: 0,
            });
        }
    }
    let composed = run_specs(&specs, 4).unwrap();
    for (spec, out) in specs.iter().zip(&composed) {
        let direct = run_experiment(
            &scenario_cfg_serial(spec),
            spec.policy,
        )
        .unwrap();
        assert_eq!(direct.jcts, out.jcts, "{}@{}", spec.policy.name(), spec.setting);
        assert_eq!(direct.wf_evals, out.wf_evals, "{}@{}", spec.policy.name(), spec.setting);
        assert_eq!(direct.makespan, out.makespan, "{}@{}", spec.policy.name(), spec.setting);
    }
}

/// The serial twin of a composed spec: same experiment, reorder_threads
/// forced back to 1.
fn scenario_cfg_serial(spec: &taos::sweep::CellSpec) -> ExperimentConfig {
    let mut cfg = spec.cfg.clone();
    cfg.sim.reorder_threads = 1;
    cfg
}

#[test]
fn reorder_threads_zero_resolves_to_all_cores() {
    // `0` must behave like "some parallel count": still bit-identical.
    let sc = Scenario::Hotspot;
    let serial = run_experiment(&scenario_cfg(sc, 1), SchedPolicy::ocwf(true)).unwrap();
    let auto = run_experiment(&scenario_cfg(sc, 0), SchedPolicy::ocwf(true)).unwrap();
    assert_eq!(serial.jcts, auto.jcts);
    assert_eq!(serial.wf_evals, auto.wf_evals);
}
