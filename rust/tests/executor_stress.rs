//! Multi-submitter stress for the persistent worker-pool executor.
//!
//! Every other suite submits batches from one thread. Production sweeps
//! do not: cells run *on* pool workers and submit nested reorder batches
//! while the main thread submits the next sweep batch. This suite drives
//! that shape directly — several OS threads submitting batches of
//! varying stripe counts (some nested) against a deliberately small pool
//! — and asserts the two properties the admission budget must preserve:
//! **exact per-stripe execution counts** (each stripe of each batch runs
//! exactly once, no matter which thread claims it) and **no deadlock**
//! (the submitter-helps rule drains every batch even when the budget
//! admits zero helpers). A 60 s watchdog turns a hang into a failure
//! instead of a CI timeout.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use taos::runtime::executor::Executor;

/// Run `f` on a fresh thread and fail loudly if it does not finish in
/// time — the deadlock check for every stress shape below.
fn with_watchdog<F: FnOnce() + Send + 'static>(name: &str, f: F) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    rx.recv_timeout(Duration::from_secs(60))
        .unwrap_or_else(|_| panic!("{name}: executor stress deadlocked"));
}

#[test]
fn concurrent_submitters_count_every_stripe_exactly_once() {
    with_watchdog("flat", || {
        let ex = Executor::new(2);
        let submitters = 6usize;
        let rounds = 40usize;
        std::thread::scope(|scope| {
            for t in 0..submitters {
                let ex = &ex;
                scope.spawn(move || {
                    for round in 0..rounds {
                        // Varying stripe counts, 2..=8, different per
                        // (submitter, round) so batches of different
                        // shapes constantly overlap in the queue.
                        let stripes = 2 + (t + round) % 7;
                        let counts: Vec<AtomicU32> =
                            (0..stripes).map(|_| AtomicU32::new(0)).collect();
                        ex.run_batch(stripes, &|s| {
                            counts[s].fetch_add(1, Ordering::Relaxed);
                        });
                        for (s, c) in counts.iter().enumerate() {
                            assert_eq!(
                                c.load(Ordering::Relaxed),
                                1,
                                "submitter {t} round {round}: stripe {s} of {stripes}"
                            );
                        }
                    }
                });
            }
        });
        // Quiescent pool: every claimed stripe was retired.
        assert_eq!(ex.stripes_in_flight(), 0);
    });
}

#[test]
fn concurrent_nested_submissions_complete_with_exact_counts() {
    with_watchdog("nested", || {
        // 4 submitters × outer batches of 3 stripes, every outer stripe
        // submitting an inner batch — against a pool smaller than the
        // submitter count, so the budget repeatedly admits zero helpers
        // and submitter-helps must carry whole batches.
        let ex = Executor::new(2);
        let submitters = 4usize;
        let rounds = 25usize;
        let total_inner = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..submitters {
                let ex = &ex;
                let total_inner = &total_inner;
                scope.spawn(move || {
                    for round in 0..rounds {
                        let inner_stripes = 2 + (t + round) % 4;
                        let inner_runs = AtomicU32::new(0);
                        ex.run_batch(3, &|_outer| {
                            ex.run_batch(inner_stripes, &|_inner| {
                                inner_runs.fetch_add(1, Ordering::Relaxed);
                                total_inner.fetch_add(1, Ordering::Relaxed);
                            });
                        });
                        assert_eq!(
                            inner_runs.load(Ordering::Relaxed) as usize,
                            3 * inner_stripes,
                            "submitter {t} round {round}"
                        );
                    }
                });
            }
        });
        // Cross-check the global tally: Σ over (t, round) of 3 × inner.
        let expect: u64 = (0..submitters)
            .flat_map(|t| (0..rounds).map(move |r| 3 * (2 + (t + r) % 4) as u64))
            .sum();
        assert_eq!(total_inner.load(Ordering::Relaxed), expect);
        assert_eq!(ex.stripes_in_flight(), 0);
    });
}

#[test]
fn budget_telemetry_stays_consistent_under_contention() {
    with_watchdog("telemetry", || {
        let ex = Executor::new(3);
        let batches_per_thread = 30u64;
        std::thread::scope(|scope| {
            for _ in 0..5 {
                let ex = &ex;
                scope.spawn(move || {
                    for _ in 0..batches_per_thread {
                        ex.run_batch(8, &|_s| {
                            std::hint::spin_loop();
                        });
                    }
                });
            }
        });
        // Every batch wanted min(8 − 1, pool) = 3 helpers; each was
        // either admitted from the idle stack or trimmed by the budget —
        // under contention most are trimmed, but the split must be exact.
        let wanted = 5 * batches_per_thread * 3;
        assert_eq!(
            ex.helpers_woken_total() + ex.wakeups_trimmed_total(),
            wanted,
            "admitted + trimmed must equal wanted helpers"
        );
        assert_eq!(ex.epochs_dispatched(), 5 * batches_per_thread);
        assert_eq!(ex.stripes_in_flight(), 0);
        assert!(ex.idle_workers() <= ex.threads());
    });
}

#[test]
fn mixed_flat_and_nested_submitters_against_one_worker() {
    with_watchdog("mixed-1worker", || {
        // The meanest shape: a single-worker pool, three submitters, a
        // mix of wide flat batches and nested ones. Progress can only
        // come from submitter-helps plus the lone worker; any lost
        // wakeup or budget accounting error deadlocks here.
        let ex = Executor::new(1);
        let done = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for t in 0..3usize {
                let ex = &ex;
                let done = &done;
                scope.spawn(move || {
                    for round in 0..30usize {
                        if (t + round) % 2 == 0 {
                            let ran = AtomicU32::new(0);
                            ex.run_batch(16, &|_s| {
                                ran.fetch_add(1, Ordering::Relaxed);
                            });
                            assert_eq!(ran.load(Ordering::Relaxed), 16);
                        } else {
                            ex.run_batch(2, &|_s| {
                                ex.run_batch(3, &|_t| {
                                    done.fetch_add(1, Ordering::Relaxed);
                                });
                            });
                        }
                    }
                });
            }
        });
        assert_eq!(ex.stripes_in_flight(), 0);
        assert!(done.load(Ordering::Relaxed) > 0);
    });
}
