//! Metamorphic tests: transformations of an instance with a provable
//! relation between the original and transformed outputs.
//!
//! - **Server relabeling**: permuting server identities (and the μ/busy
//!   vectors with them) cannot change any completion-time *value*: the
//!   objective of program `P` is symmetric in server identity. OBTA's
//!   optimum and WF's estimate are invariant, and WF's final busy vector
//!   is exactly the permuted original. (Concrete *allocations* may
//!   legally differ — remainder placement follows server order — and
//!   RD's random tie-breaking consumes its RNG in a relabeling-dependent
//!   order, so RD is checked only for validity.)
//! - **Uniform rate scaling**: multiplying every group size and every μ
//!   by the same factor `c` leaves all slot counts identical
//!   (`ceil(cn/cμ) = ceil(n/μ)`): OBTA's optimum is unchanged and WF's
//!   walk is reproduced step for step, so its allocation scales exactly
//!   entry by entry.
//! - **Baseline invariances**: with pairwise-distinct μ the jsq and
//!   delay selection keys `(…, Reverse(μ), id)` never reach their
//!   server-id tie-break, so both are exactly relabel-*covariant* (the
//!   allocation is the permuted original). Uniform rate scaling
//!   preserves every comparison those keys make (slot counts are
//!   invariant, μ order and μ ties survive multiplication), so the
//!   server choices are identical and the allocations scale entry by
//!   entry — no distinct-μ hypothesis needed.
//! - **Engine agreement**: the analytic FIFO engine and the slot-stepping
//!   ground-truth validator must produce identical JCTs/makespans on the
//!   *compound* scenario presets (`bursty-hetero`, `hotspot-heavy-tail`),
//!   which previously only the single-axis scenarios exercised.
//! - **DES relabeling**: the discrete-event engine must *commute* with
//!   server relabeling — relabeled-DES equals relabeled-analytic exactly
//!   as original-DES equals original-analytic — and on workloads whose
//!   placements are forced (single-server groups) the deterministic DES
//!   completion times are exactly relabel-invariant, pinning down that
//!   nothing in the event core (heap tie-breaks, lane scan order,
//!   replica-target ranking) leaks server identity into outcomes.

use taos::assign::wf::Wf;
use taos::assign::{validate_assignment, AssignPolicy, Assigner, Instance};
use taos::config::SimConfig;
use taos::des::run_des;
use taos::job::{Job, TaskGroup};
use taos::sched::SchedPolicy;
use taos::sim::stepping::run_fifo_stepping;
use taos::sim::{materialize_jobs, run_fifo, run_reordered};
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;

struct OwnedInst {
    groups: Vec<TaskGroup>,
    mu: Vec<u64>,
    busy: Vec<u64>,
}

impl OwnedInst {
    fn view(&self) -> Instance<'_> {
        Instance {
            groups: &self.groups,
            mu: &self.mu,
            busy: &self.busy,
        }
    }
}

fn random_instance(rng: &mut Rng, max_m: usize) -> OwnedInst {
    let m = 2 + rng.gen_range((max_m - 1) as u64) as usize;
    let k = 1 + rng.gen_range(4) as usize;
    let groups = (0..k)
        .map(|_| {
            let ns = 1 + rng.gen_range(m as u64) as usize;
            let mut sv: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut sv);
            sv.truncate(ns);
            TaskGroup::new(rng.gen_range_incl(1, 30), sv)
        })
        .collect();
    OwnedInst {
        groups,
        mu: (0..m).map(|_| rng.gen_range_incl(1, 5)).collect(),
        busy: (0..m).map(|_| rng.gen_range(9)).collect(),
    }
}

/// Like [`random_instance`] but with pairwise-distinct μ (a shuffled
/// `1..=m`): the jsq/delay selection keys then never reach the
/// server-id tie-break, making their allocations functions of values
/// alone — the hypothesis the relabeling covariance test needs.
fn random_distinct_mu_instance(rng: &mut Rng, max_m: usize) -> OwnedInst {
    let mut inst = random_instance(rng, max_m);
    let m = inst.mu.len();
    let mut mu: Vec<u64> = (1..=m as u64).collect();
    rng.shuffle(&mut mu);
    inst.mu = mu;
    inst
}

/// Canonicalize an allocation for order-insensitive comparison: the
/// chunked baselines emit each group's rows in (relabeling-dependent)
/// server order.
fn canon(per_group: &[Vec<(usize, u64)>]) -> Vec<Vec<(usize, u64)>> {
    per_group
        .iter()
        .map(|g| {
            let mut v = g.clone();
            v.sort_unstable();
            v
        })
        .collect()
}

/// Apply the server relabeling `perm` (old id → new id) to an instance.
fn relabel(inst: &OwnedInst, perm: &[usize]) -> OwnedInst {
    let m = inst.mu.len();
    let mut mu = vec![0u64; m];
    let mut busy = vec![0u64; m];
    for s in 0..m {
        mu[perm[s]] = inst.mu[s];
        busy[perm[s]] = inst.busy[s];
    }
    let groups = inst
        .groups
        .iter()
        .map(|g| {
            TaskGroup::new(g.size, g.servers.iter().map(|&s| perm[s]).collect())
        })
        .collect();
    OwnedInst { groups, mu, busy }
}

#[test]
fn server_relabeling_preserves_completion_times() {
    let mut rng = Rng::seed_from(0x3E7A);
    for case in 0..60 {
        let orig = random_instance(&mut rng, 6);
        let m = orig.mu.len();
        let mut perm: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut perm);
        let renamed = relabel(&orig, &perm);

        let obta_a = AssignPolicy::Obta.build(0).assign(&orig.view());
        let obta_b = AssignPolicy::Obta.build(0).assign(&renamed.view());
        assert_eq!(obta_a.phi, obta_b.phi, "case {case}: OBTA optimum moved");

        let (wf_a, busy_a) = Wf::new().assign_with_busy(&orig.view());
        let (wf_b, busy_b) = Wf::new().assign_with_busy(&renamed.view());
        assert_eq!(wf_a.phi, wf_b.phi, "case {case}: WF estimate moved");
        for s in 0..m {
            assert_eq!(
                busy_a[s],
                busy_b[perm[s]],
                "case {case}: WF final busy must be the permuted original"
            );
        }
        validate_assignment(&renamed.view(), &wf_b)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));

        // RD: the relabeling changes its RNG consumption order, so only
        // structural validity is invariant.
        let rd = AssignPolicy::Rd.build(7).assign(&renamed.view());
        validate_assignment(&renamed.view(), &rd)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn uniform_rate_scaling_preserves_schedules() {
    let mut rng = Rng::seed_from(0x5CA1E);
    for case in 0..60 {
        let orig = random_instance(&mut rng, 6);
        let c = [2u64, 3, 5][(case % 3) as usize];
        let scaled = OwnedInst {
            groups: orig
                .groups
                .iter()
                .map(|g| TaskGroup::new(g.size * c, g.servers.clone()))
                .collect(),
            mu: orig.mu.iter().map(|&x| x * c).collect(),
            busy: orig.busy.clone(),
        };

        let obta_a = AssignPolicy::Obta.build(0).assign(&orig.view());
        let obta_b = AssignPolicy::Obta.build(0).assign(&scaled.view());
        assert_eq!(
            obta_a.phi, obta_b.phi,
            "case {case} c={c}: OBTA optimum must be scale-invariant"
        );

        let wf_a = AssignPolicy::Wf.build(0).assign(&orig.view());
        let wf_b = AssignPolicy::Wf.build(0).assign(&scaled.view());
        assert_eq!(wf_a.phi, wf_b.phi, "case {case} c={c}: WF estimate moved");
        assert_eq!(
            wf_a.per_group.len(),
            wf_b.per_group.len(),
            "case {case}: arity"
        );
        for (ga, gb) in wf_a.per_group.iter().zip(&wf_b.per_group) {
            let scaled_ga: Vec<(usize, u64)> = ga.iter().map(|&(s, n)| (s, n * c)).collect();
            assert_eq!(
                &scaled_ga, gb,
                "case {case} c={c}: WF allocation must scale exactly"
            );
        }
    }
}

#[test]
fn baseline_relabeling_is_exactly_covariant_with_distinct_mu() {
    let mut rng = Rng::seed_from(0xBA5E);
    for case in 0..60 {
        let orig = random_distinct_mu_instance(&mut rng, 6);
        let m = orig.mu.len();
        let mut perm: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut perm);
        let renamed = relabel(&orig, &perm);
        for alg in [AssignPolicy::Jsq, AssignPolicy::Delay] {
            let a = alg.build(0).assign(&orig.view());
            let b = alg.build(0).assign(&renamed.view());
            validate_assignment(&renamed.view(), &b)
                .unwrap_or_else(|e| panic!("case {case}/{}: {e}", alg.name()));
            assert_eq!(
                a.phi,
                b.phi,
                "case {case}: {} Φ moved under relabeling",
                alg.name()
            );
            let mapped: Vec<Vec<(usize, u64)>> = a
                .per_group
                .iter()
                .map(|g| g.iter().map(|&(s, n)| (perm[s], n)).collect())
                .collect();
            assert_eq!(
                canon(&mapped),
                canon(&b.per_group),
                "case {case}: {} allocation must be the permuted original",
                alg.name()
            );
        }
    }
}

#[test]
fn baseline_rate_scaling_preserves_schedules() {
    // No distinct-μ hypothesis here: multiplying every μ by the same c
    // preserves μ order *and* μ ties, so the id tie-break fires on
    // exactly the same comparisons and the whole selection sequence is
    // reproduced step for step.
    let mut rng = Rng::seed_from(0x5CA1F);
    for case in 0..60 {
        let orig = random_instance(&mut rng, 6);
        let c = [2u64, 3, 5][(case % 3) as usize];
        let scaled = OwnedInst {
            groups: orig
                .groups
                .iter()
                .map(|g| TaskGroup::new(g.size * c, g.servers.clone()))
                .collect(),
            mu: orig.mu.iter().map(|&x| x * c).collect(),
            busy: orig.busy.clone(),
        };
        for alg in [AssignPolicy::Jsq, AssignPolicy::Delay] {
            let a = alg.build(0).assign(&orig.view());
            let b = alg.build(0).assign(&scaled.view());
            assert_eq!(
                a.phi,
                b.phi,
                "case {case} c={c}: {} Φ must be scale-invariant",
                alg.name()
            );
            let scaled_a: Vec<Vec<(usize, u64)>> = a
                .per_group
                .iter()
                .map(|g| g.iter().map(|&(s, n)| (s, n * c)).collect())
                .collect();
            assert_eq!(
                canon(&scaled_a),
                canon(&b.per_group),
                "case {case} c={c}: {} allocation must scale entry by entry",
                alg.name()
            );
        }
    }
}

fn random_jobs(rng: &mut Rng, m: usize, njobs: usize, single_server_groups: bool) -> Vec<Job> {
    let mut arrival = 0u64;
    (0..njobs)
        .map(|id| {
            arrival += rng.gen_range(7);
            let k = 1 + rng.gen_range(3) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let ns = if single_server_groups {
                        1
                    } else {
                        1 + rng.gen_range(m as u64) as usize
                    };
                    let mut sv: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut sv);
                    sv.truncate(ns);
                    TaskGroup::new(rng.gen_range_incl(1, 24), sv)
                })
                .collect();
            Job {
                id,
                arrival,
                groups,
                mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
            }
        })
        .collect()
}

/// Apply the server relabeling `perm` (old id → new id) to a whole job
/// list: group server sets and μ vectors permute together.
fn relabel_jobs(jobs: &[Job], perm: &[usize]) -> Vec<Job> {
    jobs.iter()
        .map(|j| {
            let mut mu = vec![0u64; perm.len()];
            for s in 0..perm.len() {
                mu[perm[s]] = j.mu[s];
            }
            Job {
                id: j.id,
                arrival: j.arrival,
                groups: j
                    .groups
                    .iter()
                    .map(|g| TaskGroup::new(g.size, g.servers.iter().map(|&s| perm[s]).collect()))
                    .collect(),
                mu,
            }
        })
        .collect()
}

#[test]
fn des_engine_commutes_with_server_relabeling() {
    // The deterministic DES is an oracle for the analytic engines on
    // *any* job list — in particular on a relabeled one. (Completion
    // *values* may legally move under relabeling here: WF's remainder
    // placement follows server order, so the commutation — DES tracking
    // the analytic engine through the relabeling — is the invariant, not
    // the values themselves.)
    let m = 5;
    let cfg = SimConfig::default();
    let mut rng = Rng::seed_from(0x3E7B);
    for case in 0..12 {
        let jobs = random_jobs(&mut rng, m, 2 + case % 8, false);
        let mut perm: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut perm);
        let renamed = relabel_jobs(&jobs, &perm);
        for variant in [&jobs, &renamed] {
            let fifo = run_fifo(variant, m, AssignPolicy::Wf, &cfg, 3).unwrap();
            let des = run_des(variant, m, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 3).unwrap();
            assert_eq!(fifo.jcts, des.jcts, "case {case}: FIFO commutation");
            let re = run_reordered(variant, m, true, &cfg).unwrap();
            let des_re = run_des(variant, m, SchedPolicy::ocwf(true), &cfg, 3).unwrap();
            assert_eq!(re.jcts, des_re.jcts, "case {case}: reordered commutation");
        }
    }
}

#[test]
fn des_engine_relabel_invariant_on_forced_placements() {
    // Single-server groups force every assigner's allocation, taking the
    // assignment layer (whose remainder placement is order-dependent)
    // out of the picture: the deterministic DES completion times must
    // then be *exactly* invariant under server relabeling. Any
    // divergence would expose server-identity leakage inside the event
    // core itself.
    let m = 6;
    let cfg = SimConfig::default();
    let mut rng = Rng::seed_from(0x3E7C);
    for case in 0..15 {
        let jobs = random_jobs(&mut rng, m, 2 + case % 9, true);
        let mut perm: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut perm);
        let renamed = relabel_jobs(&jobs, &perm);
        for policy in [
            SchedPolicy::fifo(AssignPolicy::Wf),
            SchedPolicy::fifo(AssignPolicy::Obta),
            SchedPolicy::ocwf(false),
            SchedPolicy::ocwf(true),
        ] {
            let a = run_des(&jobs, m, policy, &cfg, 3).unwrap();
            let b = run_des(&renamed, m, policy, &cfg, 3).unwrap();
            assert_eq!(
                a.jcts,
                b.jcts,
                "case {case}, {}: forced placements must be relabel-invariant",
                policy.name()
            );
            assert_eq!(a.makespan, b.makespan, "case {case}, {}", policy.name());
        }
    }
}

#[test]
fn fifo_engine_matches_stepping_validator_on_compound_scenarios() {
    for name in ["bursty-hetero", "hotspot-heavy-tail"] {
        let scenario = Scenario::parse(name).expect("compound scenario exists");
        let mut cfg = taos::sweep::quick_base(0xC0DE);
        cfg.trace.jobs = 12;
        cfg.trace.total_tasks = 500;
        cfg.cluster.servers = 12;
        cfg.cluster.avail_lo = 2;
        cfg.cluster.avail_hi = 4;
        scenario.apply(&mut cfg);
        let jobs = materialize_jobs(&cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        let sim_cfg = SimConfig::default();
        for policy in [AssignPolicy::Wf, AssignPolicy::Rd, AssignPolicy::Obta] {
            let fast = run_fifo(&jobs, cfg.cluster.servers, policy, &sim_cfg, 11).unwrap();
            let slow = run_fifo_stepping(&jobs, cfg.cluster.servers, policy, &sim_cfg, 11);
            assert_eq!(
                fast.jcts,
                slow.jcts,
                "{name}/{}: analytic and stepping engines disagree",
                policy.name()
            );
            assert_eq!(fast.makespan, slow.makespan, "{name}/{}", policy.name());
        }
    }
}
