//! Differential test harness for the assignment layer.
//!
//! The paper's central §III claims are checked against the exhaustive
//! oracle [`taos::assign::brute::brute_force_opt_phi`] on two corpora:
//!
//! 1. a **systematic enumeration** of tiny instances (≤ 4 servers, ≤ 3
//!    groups, ≤ 6 tasks, every nonempty available-server subset), and
//! 2. **seeded random tiny instances drawn through every scenario
//!    preset's** cluster shape (placement mode, Zipf skew, capacity
//!    profile), so scatter placements and skewed μ vectors are covered.
//!
//! Per instance:
//! - OBTA and NLIP must equal the brute-force optimum exactly (they are
//!   exact solvers of program `P`), and their allocations must realize
//!   the claimed Φ;
//! - WF must satisfy Φ* ≤ Φ_WF ≤ K_c · Φ* (Theorems 1–2);
//! - RD must produce a valid assignment with Φ ≥ Φ*.
//!
//! RD vs WF is checked as a **corpus aggregate** (RD at-or-below WF on at
//! least half the corpus): the paper reports RD beating WF *on average*,
//! but neither proves per-instance dominance, and a heuristic with random
//! tie-breaking can lose individual instances.

use taos::assign::brute::brute_force_opt_phi;
use taos::assign::{program_phi, realized_phi, validate_assignment, AssignPolicy, Assigner, Instance};
use taos::cluster::Cluster;
use taos::config::ExperimentConfig;
use taos::job::TaskGroup;
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;

/// Corpus-level counters for the aggregate RD-vs-WF and
/// OBTA-vs-baseline checks.
#[derive(Default)]
struct Tally {
    total: u64,
    rd_le_wf: u64,
    wf_strictly_above_opt: u64,
    baseline_checks: u64,
    obta_at_or_below_realized: u64,
}

impl Tally {
    fn assert_aggregate(&self, corpus: &str) {
        assert!(self.total > 0, "{corpus}: empty corpus");
        // RD's global balancing should match or beat the per-group WF on
        // most small instances (ties with the optimum are common); a
        // majority is the defensible floor for a heuristic with random
        // tie-breaking and no per-instance dominance theorem.
        assert!(
            self.rd_le_wf * 2 >= self.total,
            "{corpus}: RD ≤ WF on only {}/{} instances",
            self.rd_le_wf,
            self.total
        );
        // OBTA's program optimum vs the baselines' *realized* schedule.
        // Not a per-instance theorem: realized accounting pools tasks
        // across groups on a server (ceil of the sum ≤ sum of ceils), so
        // a baseline's realized Φ can dip below the program optimum on
        // instances where the per-group ceiling slack dominates. On
        // small corpora that slack is rare — an overwhelming-majority
        // floor is the strongest defensible assertion.
        assert!(self.baseline_checks > 0, "{corpus}: no baseline checks ran");
        assert!(
            self.obta_at_or_below_realized * 10 >= self.baseline_checks * 9,
            "{corpus}: OBTA ≤ baseline realized Φ on only {}/{} checks",
            self.obta_at_or_below_realized,
            self.baseline_checks
        );
    }
}

/// Run every §III assigner on the instance and check it against the
/// brute-force optimum.
fn check_instance(tag: &str, groups: &[TaskGroup], mu: &[u64], busy: &[u64], seed: u64, tally: &mut Tally) {
    let inst = Instance { groups, mu, busy };
    let opt = brute_force_opt_phi(&inst);
    let k_c = groups.iter().filter(|g| g.size > 0).count() as u64;

    let obta = AssignPolicy::Obta.build(seed).assign(&inst);
    validate_assignment(&inst, &obta).unwrap_or_else(|e| panic!("{tag}: OBTA invalid: {e}"));
    assert_eq!(obta.phi, opt, "{tag}: OBTA must equal the brute-force optimum");
    assert_eq!(
        program_phi(&inst, &obta.per_group),
        opt,
        "{tag}: OBTA's allocation must realize the optimum"
    );

    let nlip = AssignPolicy::Nlip.build(seed).assign(&inst);
    validate_assignment(&inst, &nlip).unwrap_or_else(|e| panic!("{tag}: NLIP invalid: {e}"));
    assert_eq!(nlip.phi, opt, "{tag}: NLIP must equal the brute-force optimum");

    let wf = AssignPolicy::Wf.build(seed).assign(&inst);
    validate_assignment(&inst, &wf).unwrap_or_else(|e| panic!("{tag}: WF invalid: {e}"));
    assert!(opt <= wf.phi, "{tag}: optimum {opt} cannot exceed WF {}", wf.phi);
    assert!(
        wf.phi <= k_c.max(1) * opt,
        "{tag}: WF {} above the K_c·OPT bound ({k_c} × {opt})",
        wf.phi
    );
    assert!(
        program_phi(&inst, &wf.per_group) <= wf.phi,
        "{tag}: WF's allocation must not exceed its estimate"
    );

    let rd = AssignPolicy::Rd.build(seed).assign(&inst);
    validate_assignment(&inst, &rd).unwrap_or_else(|e| panic!("{tag}: RD invalid: {e}"));
    assert!(opt <= rd.phi, "{tag}: optimum {opt} cannot exceed RD {}", rd.phi);

    // The baseline panel (jsq, jsq-affinity, delay, maxweight):
    // heuristics with no optimality claim, so the per-instance
    // assertions are validity, exact Φ accounting, and the Φ* lower
    // bound; OBTA-dominance on the realized schedule is a corpus
    // aggregate (see `Tally::assert_aggregate`).
    for baseline in AssignPolicy::BASELINES {
        let out = baseline.build(seed).assign(&inst);
        validate_assignment(&inst, &out)
            .unwrap_or_else(|e| panic!("{tag}: {} invalid: {e}", baseline.name()));
        assert_eq!(
            out.phi,
            program_phi(&inst, &out.per_group),
            "{tag}: {} must report its exact program objective",
            baseline.name()
        );
        assert!(
            opt <= out.phi,
            "{tag}: optimum {opt} cannot exceed {} {}",
            baseline.name(),
            out.phi
        );
        tally.baseline_checks += 1;
        if obta.phi <= realized_phi(&inst, &out.per_group) {
            tally.obta_at_or_below_realized += 1;
        }
    }

    tally.total += 1;
    if rd.phi <= wf.phi {
        tally.rd_le_wf += 1;
    }
    if wf.phi > opt {
        tally.wf_strictly_above_opt += 1;
    }
}

/// The nonempty server subsets of `0..m`, as sorted lists.
fn subsets(m: usize) -> Vec<Vec<usize>> {
    (1u32..(1 << m))
        .map(|mask| (0..m).filter(|&s| mask & (1 << s) != 0).collect())
        .collect()
}

/// Every third instance re-runs with a heterogeneous (μ, busy) profile so
/// the enumeration is not blind to capacity skew and backlog.
fn profiles(m: usize, counter: u64) -> Vec<(Vec<u64>, Vec<u64>)> {
    let uniform = (vec![1u64; m], vec![0u64; m]);
    if counter % 3 == 0 {
        let hetero_mu: Vec<u64> = [1u64, 2, 3, 4][..m].to_vec();
        let hetero_busy: Vec<u64> = [0u64, 1, 0, 2][..m].to_vec();
        vec![uniform, (hetero_mu, hetero_busy)]
    } else {
        vec![uniform]
    }
}

#[test]
fn systematic_enumeration_matches_brute_force() {
    let mut tally = Tally::default();
    let mut counter = 0u64;
    let run = |groups: &[TaskGroup], m: usize, tally: &mut Tally, counter: &mut u64| {
        for (mu, busy) in profiles(m, *counter) {
            let tag = format!("enum m={m} #{counter} groups={groups:?} mu={mu:?}");
            check_instance(&tag, groups, &mu, &busy, 0x9000 + *counter, tally);
        }
        *counter += 1;
    };

    // Single group: every server subset × sizes 1..=4 (1..=6 at m = 4).
    for m in 1..=4usize {
        let max_size = if m == 4 { 6 } else { 4 };
        for sv in subsets(m) {
            for size in 1..=max_size {
                let groups = vec![TaskGroup::new(size, sv.clone())];
                run(&groups, m, &mut tally, &mut counter);
            }
        }
    }

    // Two groups: subset pairs × small size pairs.
    for m in 2..=4usize {
        let sizes: &[(u64, u64)] = if m == 4 {
            &[(1, 1), (2, 2), (3, 1), (1, 3)]
        } else {
            &[(1, 1), (1, 2), (2, 1), (2, 2)]
        };
        for a in subsets(m) {
            for b in subsets(m) {
                for &(s1, s2) in sizes {
                    let groups = vec![
                        TaskGroup::new(s1, a.clone()),
                        TaskGroup::new(s2, b.clone()),
                    ];
                    run(&groups, m, &mut tally, &mut counter);
                }
            }
        }
    }

    // Three groups at m = 3: every subset triple, smallest sizes.
    for a in subsets(3) {
        for b in subsets(3) {
            for c in subsets(3) {
                for sizes in [[1u64, 1, 1], [2, 1, 1]] {
                    let groups = vec![
                        TaskGroup::new(sizes[0], a.clone()),
                        TaskGroup::new(sizes[1], b.clone()),
                        TaskGroup::new(sizes[2], c.clone()),
                    ];
                    run(&groups, 3, &mut tally, &mut counter);
                }
            }
        }
    }

    assert!(
        tally.wf_strictly_above_opt > 0,
        "enumeration never separated WF from the optimum — corpus too easy"
    );
    tally.assert_aggregate("systematic enumeration");
}

#[test]
fn scenario_preset_instances_match_brute_force() {
    let mut tally = Tally::default();
    for (si, scenario) in Scenario::ALL.iter().enumerate() {
        // Shrink the scenario's cluster to the brute-force regime while
        // keeping its characteristic twists (placement mode, Zipf skew,
        // μ skew).
        let mut cfg = ExperimentConfig::default();
        scenario.apply(&mut cfg);
        cfg.cluster.servers = 4;
        cfg.cluster.avail_lo = 1;
        cfg.cluster.avail_hi = 3;
        let mut rng = Rng::seed_from(0xD1FF + si as u64);
        let cluster = Cluster::generate(&cfg.cluster, &mut rng);
        let placement = taos::cluster::placement::Placement::with_mode(
            cfg.cluster.servers,
            cfg.cluster.zipf_alpha,
            cfg.cluster.placement_mode,
            &mut rng,
        );
        for case in 0..40u64 {
            let k = 1 + rng.gen_range(3) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let servers = cluster.sample_available(&placement, &mut rng);
                    TaskGroup::new(rng.gen_range_incl(1, 6 / k as u64), servers)
                })
                .collect();
            let mu = cluster.sample_mu(&mut rng);
            let busy: Vec<u64> = (0..cfg.cluster.servers)
                .map(|_| rng.gen_range(4))
                .collect();
            let tag = format!("{} case {case}", scenario.name());
            check_instance(&tag, &groups, &mu, &busy, 0xA000 + case, &mut tally);
        }
    }
    tally.assert_aggregate("scenario presets");
}
