//! Integration surface for the extensible policy registry and the
//! baseline panel (PR 9).
//!
//! - The registry is the single source of truth: canonical names and
//!   every alias parse back to their row, rows follow the canonical
//!   panel order, and `PolicySet` round-trips through its display form.
//! - The `policies` / `delay_bound` config keys reach the engine knobs
//!   (`ExperimentConfig::policies`, `SimConfig::assign_params`).
//! - Delay scheduling's bound D is exercised *end to end* on a
//!   hand-built two-job fixture whose outcome is fully hand-traceable:
//!   a patient bound waits out a holder's backlog, an impatient one
//!   spills the whole group to the idle remote server — identically on
//!   the analytic and DES paths.
//! - The `fig-baselines-load` sweep runs the full ten-policy panel
//!   bit-identically across worker thread counts, and a narrowed
//!   `--policies` panel renders no ghost rows.

use taos::assign::AssignPolicy;
use taos::config::{ExperimentConfig, SimConfig};
use taos::des::run_des;
use taos::job::{Job, TaskGroup};
use taos::sched::{PolicySet, SchedPolicy, REGISTRY};
use taos::sim::run_fifo;
use taos::sweep::{self, pool, SweepOptions};

#[test]
fn registry_names_and_aliases_parse_to_their_row() {
    let panel: Vec<&str> = SchedPolicy::EXTENDED.iter().map(|p| p.name()).collect();
    let rows: Vec<&str> = REGISTRY.iter().map(|d| d.policy.name()).collect();
    assert_eq!(rows, panel, "registry rows must follow the canonical panel order");
    for d in REGISTRY {
        assert_eq!(SchedPolicy::parse(d.policy.name()), Some(d.policy));
        for &alias in d.aliases {
            assert_eq!(SchedPolicy::parse(alias), Some(d.policy), "alias {alias}");
        }
        assert!(!d.summary.is_empty(), "{}: summary", d.policy.name());
        assert!(!d.citation.is_empty(), "{}: citation", d.policy.name());
    }
    assert_eq!(SchedPolicy::parse("no-such-policy"), None);
}

#[test]
fn policy_set_parses_dedups_and_round_trips() {
    let set = PolicySet::parse("obta, jsq ,obta,max_weight").unwrap();
    assert_eq!(set.names(), "obta,jsq,maxweight");
    assert_eq!(set.len(), 3);
    assert!(set.contains(SchedPolicy::fifo(AssignPolicy::Jsq)));
    assert!(!set.contains(SchedPolicy::ocwf(true)));

    assert_eq!(PolicySet::default(), PolicySet::paper());
    assert_eq!(PolicySet::extended().len(), 10);
    assert_eq!(
        PolicySet::parse(&PolicySet::extended().names()).unwrap(),
        PolicySet::extended(),
        "canonical names must re-parse to the same panel"
    );

    let err = PolicySet::parse("obta,bogus").unwrap_err();
    assert!(
        err.contains("bogus") && err.contains("maxweight"),
        "the error must name the offender and list the registry: {err}"
    );
    assert!(PolicySet::parse("  ,, ").is_err(), "empty list must error");
}

#[test]
fn config_keys_reach_the_engine_knobs() {
    let cfg = ExperimentConfig::from_str("policies = \"jsq,delay\"\ndelay_bound = 7\n").unwrap();
    assert_eq!(cfg.policies.names(), "jsq,delay");
    assert_eq!(cfg.sim.delay_bound, 7);
    assert_eq!(cfg.sim.assign_params().delay_bound, 7);
    assert_eq!(ExperimentConfig::default().policies, PolicySet::paper());
    assert!(
        ExperimentConfig::from_str("policies = \"jsq,nope\"").is_err(),
        "unknown policy names must be a config error"
    );
}

/// Two jobs on two servers, μ = 2 everywhere. Job 0 backlogs server 0
/// (4 forced tasks → its queue frees at slot 2); job 1 holds its
/// replicas on server 0 but is eligible to spill to the idle server 1.
fn replica_holder_fixture() -> Vec<Job> {
    vec![
        Job {
            id: 0,
            arrival: 0,
            groups: vec![TaskGroup::new(4, vec![0])],
            mu: vec![2, 2],
        },
        Job {
            id: 1,
            arrival: 0,
            groups: vec![TaskGroup::with_local(4, vec![0, 1], vec![0])],
            mu: vec![2, 2],
        },
    ]
}

#[test]
fn delay_bound_trades_locality_for_queueing_end_to_end() {
    // Bound 3 tolerates job 1's 2-slot local wait — all four tasks stay
    // on the holder and finish at slot 4. Bound 1 does not — the whole
    // group spills to the idle remote server and finishes at slot 2.
    // Deterministic integer schedule, so analytic and DES agree bit for
    // bit.
    let jobs = replica_holder_fixture();
    for (bound, want, span) in [(3u64, vec![2u64, 4], 4u64), (1, vec![2, 2], 2)] {
        let mut cfg = SimConfig::default();
        cfg.delay_bound = bound;
        let fifo = run_fifo(&jobs, 2, AssignPolicy::Delay, &cfg, 0).unwrap();
        assert_eq!(fifo.jcts, want, "bound {bound}: analytic JCTs");
        assert_eq!(fifo.makespan, span, "bound {bound}");
        let policy = SchedPolicy::fifo(AssignPolicy::Delay);
        let des = run_des(&jobs, 2, policy, &cfg, 0).unwrap();
        assert_eq!(fifo.jcts, des.jcts, "bound {bound}: DES must agree");
        assert_eq!(fifo.makespan, des.makespan, "bound {bound}");
    }
}

#[test]
fn baseline_panel_semantics_on_the_replica_holder_fixture() {
    // One fixture, four hand-traced schedules. jsq and jsq-affinity
    // spill everything (the idle remote queue beats the 2-slot local
    // wait; affinity only stays local when the holder ties the global
    // minimum). delay's default bound D = 2 keeps the first chunk local
    // and spills the rest once its own chunk pushes the wait past D.
    // maxweight's 2× holder weight routes the first chunk remote while
    // the backlog dominates, then back to the holder — same split, so
    // the same completion times by a different rule.
    let jobs = replica_holder_fixture();
    let cfg = SimConfig::default();
    for (alg, want) in [
        (AssignPolicy::Jsq, vec![2u64, 2]),
        (AssignPolicy::JsqAffinity, vec![2, 2]),
        (AssignPolicy::Delay, vec![2, 3]),
        (AssignPolicy::MaxWeight, vec![2, 3]),
    ] {
        let out = run_fifo(&jobs, 2, alg, &cfg, 0).unwrap();
        assert_eq!(out.jcts, want, "{}", alg.name());
    }
}

fn tiny_base(seed: u64) -> ExperimentConfig {
    let mut cfg = sweep::quick_base(seed);
    cfg.trace.jobs = 16;
    cfg.trace.total_tasks = 800;
    cfg.cluster.servers = 12;
    cfg.cluster.avail_lo = 2;
    cfg.cluster.avail_hi = 4;
    cfg
}

#[test]
fn baselines_figure_bit_identical_across_thread_counts() {
    let base = tiny_base(77);
    let utils = [0.4, 0.8];
    let opts = |threads| {
        SweepOptions::default()
            .with_policies(PolicySet::extended())
            .with_threads(threads)
    };
    let reference = sweep::fig_baselines_opts(&base, &utils, &opts(1)).unwrap();
    let panel: Vec<&str> = SchedPolicy::EXTENDED.iter().map(|p| p.name()).collect();
    assert_eq!(reference.policies(), panel, "full panel in canonical order");
    assert_eq!(reference.cells.len(), panel.len() * utils.len());
    for threads in pool::test_thread_counts() {
        let fig = sweep::fig_baselines_opts(&base, &utils, &opts(threads)).unwrap();
        assert_eq!(fig.cells.len(), reference.cells.len());
        for (a, b) in reference.cells.iter().zip(&fig.cells) {
            assert_eq!(
                (a.policy, a.setting),
                (b.policy, b.setting),
                "cell order moved at {threads} threads"
            );
            assert_eq!(a.mean_jct, b.mean_jct, "{}@{}: {threads} threads", a.policy, a.setting);
            assert_eq!(a.p50_jct, b.p50_jct, "{}@{}", a.policy, a.setting);
            assert_eq!(a.p99_jct, b.p99_jct, "{}@{}", a.policy, a.setting);
            assert_eq!(a.cdf, b.cdf, "{}@{}", a.policy, a.setting);
        }
    }
}

#[test]
fn narrowed_policy_set_renders_no_ghost_rows() {
    let base = tiny_base(5);
    let opts = SweepOptions::default()
        .with_threads(1)
        .with_policies(PolicySet::parse("delay,jsq").unwrap());
    let fig = sweep::fig_baselines_opts(&base, &[0.5], &opts).unwrap();
    assert_eq!(fig.policies(), vec!["delay", "jsq"], "panel order as given");
    let text = fig.render();
    assert!(text.contains("delay") && text.contains("jsq"));
    for absent in ["obta", "nlip", "ocwf", "maxweight"] {
        assert!(!text.contains(absent), "ghost row `{absent}` in:\n{text}");
    }
}
