//! Black-box tests of the `taos` binary (launcher, config plumbing,
//! figure reproduction, trace generation).

use std::process::Command;

fn taos() -> Command {
    Command::new(env!("CARGO_BIN_EXE_taos"))
}

fn run_ok(args: &[&str]) -> String {
    let out = taos().args(args).output().expect("spawn taos");
    assert!(
        out.status.success(),
        "taos {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn help_lists_subcommands() {
    let out = taos().arg("--help").output().unwrap();
    let text = String::from_utf8_lossy(&out.stderr).into_owned()
        + &String::from_utf8_lossy(&out.stdout);
    for sub in ["simulate", "compare", "repro", "gen-trace", "live", "verify-kernel"] {
        assert!(text.contains(sub), "help missing {sub}: {text}");
    }
}

#[test]
fn simulate_small_run_text_and_json() {
    let args = [
        "simulate", "--alg", "wf", "--jobs", "15", "--tasks", "600", "--servers", "20",
        "--avail", "3:5", "--seed", "5",
    ];
    let text = run_ok(&args);
    assert!(text.contains("mean JCT"), "{text}");

    let mut jargs = args.to_vec();
    jargs.push("--json");
    let json = run_ok(&jargs);
    let parsed = taos::util::json::Json::parse(json.trim()).expect("valid json");
    assert_eq!(
        parsed.get("algorithm").and_then(|a| a.as_str()),
        Some("wf")
    );
    assert!(parsed.get("jct").and_then(|j| j.get("mean")).is_some());
}

#[test]
fn simulate_reordered_policy() {
    let text = run_ok(&[
        "simulate", "--alg", "ocwf-acc", "--jobs", "12", "--tasks", "400", "--servers", "15",
        "--avail", "3:5",
    ]);
    assert!(text.contains("WF evaluations"), "{text}");
}

#[test]
fn unknown_algorithm_rejected() {
    let out = taos()
        .args(["simulate", "--alg", "frobnicate"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown algorithm"));
}

#[test]
fn repro_quick_fig13_prints_table1_rows() {
    let text = run_ok(&["repro", "--fig", "table1", "--quick", "--seed", "3"]);
    for alg in ["nlip", "obta", "wf", "rd", "ocwf", "ocwf-acc"] {
        assert!(text.contains(alg), "missing {alg} row: {text}");
    }
    assert!(text.contains("p=4"), "{text}");
    assert!(text.contains("p=12"), "{text}");
    assert!(text.contains("overhead"), "{text}");
}

#[test]
fn simulate_des_engine_matches_analytic_end_to_end() {
    // The deterministic DES oracle through the binary: identical JCT
    // statistics and makespan to the analytic engine, for a FIFO and a
    // reordered policy.
    for alg in ["wf", "ocwf-acc"] {
        let base = [
            "simulate", "--alg", alg, "--jobs", "12", "--tasks", "400", "--servers", "15",
            "--avail", "3:5", "--seed", "5", "--json",
        ];
        let analytic = run_ok(&base);
        let mut dargs = base.to_vec();
        dargs.extend_from_slice(&["--engine", "des"]);
        let des = run_ok(&dargs);
        let a = taos::util::json::Json::parse(analytic.trim()).expect("analytic json");
        let d = taos::util::json::Json::parse(des.trim()).expect("des json");
        assert_eq!(d.get("engine").and_then(|e| e.as_str()), Some("des"));
        for key in ["mean", "p50", "p90", "p99", "max"] {
            assert_eq!(
                a.get("jct").unwrap().get(key).unwrap().as_f64(),
                d.get("jct").unwrap().get(key).unwrap().as_f64(),
                "{alg}: jct.{key} must match bit for bit"
            );
        }
        assert_eq!(
            a.get("makespan").unwrap().as_f64(),
            d.get("makespan").unwrap().as_f64(),
            "{alg}"
        );
    }
}

#[test]
fn simulate_stochastic_flags_require_des_engine() {
    let out = taos()
        .args([
            "simulate", "--alg", "wf", "--jobs", "8", "--tasks", "200", "--servers", "10",
            "--avail", "2:4", "--service", "exp:1.5",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("engine"),
        "error must point at --engine des"
    );
}

#[test]
fn repro_scenarios_sweep_rejects_engine_flags() {
    // The catalog sweep applies each scenario per cell, and scenarios own
    // the engine knobs — explicit engine flags would be silently
    // discarded, so the combination is rejected (like --scenario).
    let out = taos()
        .args(["repro", "--fig", "scenarios", "--quick", "--engine", "des"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--fig scenarios"),
        "error must explain the rejected combination"
    );
}

#[test]
fn repro_quick_engine_presets_run_end_to_end() {
    for scenario in ["straggler", "multi-locality", "multi-rack", "multi-zone"] {
        let text = run_ok(&[
            "repro", "--fig", "13", "--quick", "--scenario", scenario, "--seed", "3",
        ]);
        assert!(text.contains("p50/p99"), "{scenario}: percentile table: {text}");
        assert!(text.contains("ocwf-acc"), "{scenario}: {text}");
    }
}

#[test]
fn repro_topology_fig_reports_tier_hit_rates() {
    let text = run_ok(&["repro", "--fig", "topology", "--quick", "--seed", "3"]);
    assert!(text.contains("fig-topology-locality"), "{text}");
    assert!(text.contains("locality tier hit rates"), "{text}");
    assert!(text.contains("penalty=16"), "{text}");
}

#[test]
fn repro_topology_fig_rejects_penalty_flag() {
    let out = taos()
        .args(["repro", "--fig", "topology", "--quick", "--locality-penalty", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--fig topology"),
        "error must explain the rejected combination"
    );
}

#[test]
fn simulate_topology_locality_emits_tier_telemetry() {
    let json = run_ok(&[
        "simulate", "--alg", "wf", "--jobs", "12", "--tasks", "400", "--servers", "16",
        "--avail", "3:5", "--seed", "5", "--engine", "des", "--locality-penalty", "2",
        "--topology", "multi-rack", "--json",
    ]);
    let parsed = taos::util::json::Json::parse(json.trim()).expect("valid json");
    assert_eq!(
        parsed.get("topology").and_then(|t| t.as_str()),
        Some("multi-rack")
    );
    let tiers = parsed
        .get("tier_tasks")
        .and_then(|t| t.as_arr())
        .expect("tier telemetry exported");
    assert_eq!(tiers.len(), 3, "multi-rack = local/rack/remote");
    let total: f64 = tiers.iter().filter_map(|t| t.as_f64()).sum();
    assert_eq!(total, 400.0, "every task lands in exactly one tier");
}

#[test]
fn explicit_engine_flags_override_scenario_presets() {
    // Every engine knob: the preset sets it, the explicit flag must win.
    // `straggler` turns on pareto service + speculation; forcing them
    // back off (plus det service) must reproduce the deterministic path,
    // whose mean JCT matches the same workload run without the preset's
    // engine twist at all.
    let base = [
        "simulate", "--alg", "wf", "--jobs", "12", "--tasks", "400", "--servers", "15",
        "--avail", "3:5", "--seed", "5", "--json",
    ];
    let mut overridden = base.to_vec();
    overridden.extend_from_slice(&[
        "--scenario", "straggler", "--service", "det", "--speculate", "0",
    ]);
    let o = taos::util::json::Json::parse(run_ok(&overridden).trim()).unwrap();

    // The same trace shape with the engine twist stripped: straggler's
    // workload is the alibaba shape, so compare against an explicit des
    // run of the plain workload.
    let mut plain = base.to_vec();
    plain.extend_from_slice(&["--engine", "des"]);
    let p = taos::util::json::Json::parse(run_ok(&plain).trim()).unwrap();
    assert_eq!(
        o.get("jct").unwrap().get("mean").unwrap().as_f64(),
        p.get("jct").unwrap().get("mean").unwrap().as_f64(),
        "--service/--speculate must override the straggler preset"
    );

    // --topology flat + --locality-penalty 1 neutralize the multi-rack
    // preset the same way.
    let mut flat = base.to_vec();
    flat.extend_from_slice(&[
        "--scenario", "multi-rack", "--topology", "flat", "--locality-penalty", "1",
    ]);
    let f = taos::util::json::Json::parse(run_ok(&flat).trim()).unwrap();
    assert_eq!(
        f.get("topology").and_then(|t| t.as_str()),
        Some("flat"),
        "--topology must override the multi-rack preset"
    );
    assert_eq!(
        f.get("jct").unwrap().get("mean").unwrap().as_f64(),
        p.get("jct").unwrap().get("mean").unwrap().as_f64(),
        "--topology/--locality-penalty must override the multi-rack preset"
    );

    // --engine analytic against a DES-only preset is an explicit
    // (rejected) choice — proof the flag, not the preset, decides.
    let out = taos()
        .args([
            "simulate", "--alg", "wf", "--jobs", "12", "--tasks", "400", "--servers", "15",
            "--avail", "3:5", "--scenario", "multi-zone", "--engine", "analytic",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("engine"),
        "the overriding flag must surface the engine-only validation error"
    );
}

#[test]
fn gen_trace_roundtrips_through_simulate() {
    let dir = std::env::temp_dir().join("taos_cli_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    let out = run_ok(&[
        "gen-trace", "--jobs", "10", "--tasks", "300", "--out",
        path.to_str().unwrap(),
    ]);
    assert!(out.contains("10 jobs"));
    assert!(out.contains("300 tasks"));

    let text = run_ok(&[
        "simulate", "--alg", "rd", "--csv", path.to_str().unwrap(), "--servers", "15",
        "--avail", "3:5",
    ]);
    assert!(text.contains("jobs           : 10"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compare_runs_all_algorithms() {
    let text = run_ok(&[
        "compare", "--jobs", "10", "--tasks", "300", "--servers", "15", "--avail", "3:5",
        "--json",
    ]);
    let parsed = taos::util::json::Json::parse(text.trim()).expect("valid json");
    let rows = parsed.as_arr().expect("array");
    assert_eq!(rows.len(), 6);
}

#[test]
fn config_file_respected() {
    let dir = std::env::temp_dir().join("taos_cli_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.cfg");
    std::fs::write(
        &cfg,
        "servers = 12\njobs = 8\ntotal_tasks = 200\navail_lo = 2\navail_hi = 4\nseed = 9\n",
    )
    .unwrap();
    let text = run_ok(&["simulate", "--config", cfg.to_str().unwrap(), "--alg", "wf"]);
    assert!(text.contains("jobs           : 8"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
