//! The DES oracle harness: the discrete-event engine in deterministic
//! mode must reproduce the analytic engines' JCT vectors **bit for bit**
//! — for every scheduling policy, on every scenario preset, at every
//! reorder thread count — and the stochastic modes must be
//! seed-reproducible (same seed → byte-identical JCT vectors across runs
//! and thread counts).
//!
//! Thread counts come from `TAOS_TEST_THREADS` (default 1,2,8) so the CI
//! determinism matrix can pin one count per leg, exactly like
//! `sweep_determinism` / `reorder_equivalence`.

use taos::config::ExperimentConfig;
use taos::des::service::{EngineKind, ServiceModel};
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;
use taos::sweep::{self, pool};
use taos::trace::scenarios::Scenario;

fn tiny_cfg(scenario: Scenario) -> ExperimentConfig {
    let mut cfg = sweep::quick_base(0xDE5E);
    cfg.trace.jobs = 18;
    cfg.trace.total_tasks = 900;
    cfg.cluster.servers = 14;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    scenario.apply(&mut cfg);
    cfg
}

#[test]
fn deterministic_des_matches_analytic_on_every_preset_and_policy() {
    for scenario in Scenario::ALL {
        if scenario.has_engine_twist() {
            // The engine presets are stochastic by definition; their
            // reproducibility is asserted below.
            continue;
        }
        let cfg = tiny_cfg(scenario);
        assert_eq!(cfg.sim.engine, EngineKind::Analytic);
        let mut des_cfg = cfg.clone();
        des_cfg.sim.engine = EngineKind::Des;
        // The whole extended panel: the paper's six plus the baseline
        // assigners (jsq, jsq-affinity, delay, maxweight) — deterministic
        // pure integer functions of the instance, so the bit-identity
        // invariant extends to them with no per-policy carve-outs.
        for policy in SchedPolicy::EXTENDED {
            let analytic = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            let des = run_experiment(&des_cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            assert_eq!(
                analytic.jcts,
                des.jcts,
                "{}/{}: deterministic DES must reproduce the analytic JCT vector",
                scenario.name(),
                policy.name()
            );
            assert_eq!(
                analytic.makespan,
                des.makespan,
                "{}/{}",
                scenario.name(),
                policy.name()
            );
            assert_eq!(
                analytic.wf_evals,
                des.wf_evals,
                "{}/{}: the reorder call pattern must be identical",
                scenario.name(),
                policy.name()
            );
        }
    }
}

#[test]
fn des_reordered_bit_identical_across_reorder_thread_counts() {
    // Both the deterministic oracle mode and the stochastic engine
    // presets: the reorder fan-out is a wall-clock knob only.
    for scenario in [
        Scenario::Alibaba,
        Scenario::Hotspot,
        Scenario::Straggler,
        Scenario::MultiLocality,
        Scenario::MultiRack,
        Scenario::MultiZone,
    ] {
        let mut cfg = tiny_cfg(scenario);
        cfg.sim.engine = EngineKind::Des;
        for acc in [false, true] {
            let policy = SchedPolicy::ocwf(acc);
            cfg.sim.reorder_threads = 1;
            let reference = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/acc={acc}: {e}", scenario.name()));
            for threads in pool::test_thread_counts() {
                cfg.sim.reorder_threads = threads;
                let par = run_experiment(&cfg, policy).unwrap();
                assert_eq!(
                    reference.jcts,
                    par.jcts,
                    "{}/acc={acc}: DES JCTs diverged at {threads} reorder threads",
                    scenario.name()
                );
                assert_eq!(reference.wf_evals, par.wf_evals, "{}/acc={acc}", scenario.name());
                assert_eq!(reference.makespan, par.makespan, "{}/acc={acc}", scenario.name());
            }
        }
    }
}

#[test]
fn baseline_assigners_match_analytic_at_every_thread_count() {
    // The four baseline assigners are deterministic pure functions of
    // the instance, so analytic-vs-DES bit-identity must hold per policy
    // × preset × thread count. The reorder fan-out is inert for FIFO
    // policies — asserting identity under it is the point.
    for scenario in [Scenario::Alibaba, Scenario::Hotspot] {
        let cfg = tiny_cfg(scenario);
        let mut des_cfg = cfg.clone();
        des_cfg.sim.engine = EngineKind::Des;
        for policy in SchedPolicy::BASELINES {
            let reference = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            for threads in pool::test_thread_counts() {
                des_cfg.sim.reorder_threads = threads;
                let des = run_experiment(&des_cfg, policy).unwrap();
                assert_eq!(
                    reference.jcts,
                    des.jcts,
                    "{}/{}: baseline DES JCTs diverged at {threads} threads",
                    scenario.name(),
                    policy.name()
                );
                assert_eq!(reference.makespan, des.makespan);
            }
        }
    }
}

#[test]
fn stochastic_presets_are_seed_reproducible() {
    for scenario in [
        Scenario::Straggler,
        Scenario::MultiLocality,
        Scenario::MultiRack,
        Scenario::MultiZone,
    ] {
        let cfg = tiny_cfg(scenario);
        assert_eq!(cfg.sim.engine, EngineKind::Des);
        for policy in [
            SchedPolicy::fifo(taos::assign::AssignPolicy::Wf),
            SchedPolicy::fifo(taos::assign::AssignPolicy::Rd),
            // Affinity-aware baselines: exercises the holder sets the
            // topology expansion records (`TaskGroup::local`).
            SchedPolicy::fifo(taos::assign::AssignPolicy::JsqAffinity),
            SchedPolicy::fifo(taos::assign::AssignPolicy::Delay),
            SchedPolicy::ocwf(true),
        ] {
            let a = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            let b = run_experiment(&cfg, policy).unwrap();
            assert_eq!(
                a.jcts,
                b.jcts,
                "{}/{}: same seed must give byte-identical JCTs",
                scenario.name(),
                policy.name()
            );
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.jcts.len(), cfg.trace.jobs);
        }
    }
}

#[test]
fn straggler_tails_actually_move_completion_times() {
    // The engine preset must not silently degenerate to the
    // deterministic oracle: on the same materialized trace, Pareto
    // service tails have to move at least one completion time.
    let cfg = tiny_cfg(Scenario::Straggler);
    assert!(matches!(
        cfg.sim.service,
        ServiceModel::ParetoTail { .. }
    ));
    let mut det = cfg.clone();
    det.sim.service = ServiceModel::Deterministic;
    det.sim.speculate = 0.0;
    let policy = SchedPolicy::fifo(taos::assign::AssignPolicy::Wf);
    let noisy = run_experiment(&cfg, policy).unwrap();
    let clean = run_experiment(&det, policy).unwrap();
    assert_ne!(
        noisy.jcts, clean.jcts,
        "Pareto tails + speculation must perturb the schedule"
    );
    // No makespan-ordering assertion: replica racing can legitimately
    // beat the deterministic schedule by moving a straggler's work to an
    // idle server, so neither direction is a theorem.
}

#[test]
fn hierarchical_presets_report_tier_hit_rates() {
    // The topology presets must surface the locality telemetry: one
    // counter per tier, every task credited exactly once, and the flat
    // two-tier alias keeps its two-bucket shape.
    for (scenario, tiers) in [
        (Scenario::MultiLocality, 2),
        (Scenario::MultiRack, 3),
        (Scenario::MultiZone, 4),
    ] {
        let cfg = tiny_cfg(scenario);
        for policy in [
            SchedPolicy::fifo(taos::assign::AssignPolicy::Wf),
            SchedPolicy::ocwf(false),
        ] {
            let out = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", scenario.name(), policy.name()));
            assert_eq!(
                out.tier_tasks.len(),
                tiers,
                "{}/{}: one counter per topology tier",
                scenario.name(),
                policy.name()
            );
            assert_eq!(
                out.tier_tasks.iter().sum::<u64>(),
                900,
                "{}/{}: every task credited to exactly one tier",
                scenario.name(),
                policy.name()
            );
        }
    }
}

#[test]
fn multi_locality_penalty_trades_against_spreading() {
    // With the penalty the assigners may spread onto remote servers (the
    // expanded sets); remote work runs slower. The run must complete,
    // reproduce, and differ from the strictly-local deterministic run.
    let cfg = tiny_cfg(Scenario::MultiLocality);
    let policy = SchedPolicy::ocwf(true);
    let remote = run_experiment(&cfg, policy).unwrap();
    let mut local = cfg.clone();
    local.sim.locality_penalty = 1.0;
    let strict = run_experiment(&local, policy).unwrap();
    assert_eq!(remote.jcts.len(), strict.jcts.len());
    assert_ne!(
        remote.jcts, strict.jcts,
        "expanded placement + rate penalty must change the schedule"
    );
}
