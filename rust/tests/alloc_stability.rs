//! Capacity-stability tests for the zero-allocation hot paths.
//!
//! The assignment workspaces (WF scratch + outcome arenas, the OCWF
//! reorder workspace, the feasibility-oracle arenas, RD's replica tables)
//! must stop growing once warmed: re-running the same workload through a
//! pooled workspace may not change any reserved capacity. A capacity that
//! creeps between identical passes means a buffer is being dropped and
//! re-allocated per call — exactly the regression these tests guard
//! against. (Capacities are compared, not allocator calls: capacity
//! growth is the only way a `Vec`-based hot path can allocate.)

use taos::assign::wf::{Wf, WfOutcome};
use taos::assign::{Assigner, Instance};
use taos::job::TaskGroup;
use taos::sched::ocwf::{
    reorder_into, Outstanding, OutstandingSet, ReorderOutcome, ReorderWorkspace,
};
use taos::util::rng::Rng;

/// An owned random instance mixing shapes (group counts, server sets).
struct OwnedInst {
    groups: Vec<TaskGroup>,
    mu: Vec<u64>,
    busy: Vec<u64>,
}

impl OwnedInst {
    fn view(&self) -> Instance<'_> {
        Instance {
            groups: &self.groups,
            mu: &self.mu,
            busy: &self.busy,
        }
    }
}

fn workload(rng: &mut Rng, m: usize, count: usize) -> Vec<OwnedInst> {
    (0..count)
        .map(|_| {
            let k = 1 + rng.gen_range(5) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let ns = 1 + rng.gen_range(m as u64) as usize;
                    let mut sv: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut sv);
                    sv.truncate(ns);
                    TaskGroup::new(rng.gen_range_incl(1, 60), sv)
                })
                .collect();
            OwnedInst {
                groups,
                mu: (0..m).map(|_| rng.gen_range_incl(1, 5)).collect(),
                busy: (0..m).map(|_| rng.gen_range(12)).collect(),
            }
        })
        .collect()
}

#[test]
fn wf_assign_into_capacity_freezes_after_warmup() {
    let mut rng = Rng::seed_from(0xA110C);
    let insts = workload(&mut rng, 12, 24);
    let mut wf = Wf::new();
    let mut out = WfOutcome::default();
    // Warmup pass: buffers grow to the workload's high-water mark.
    for inst in &insts {
        wf.assign_into(&inst.view(), &mut out);
    }
    let fp = wf.scratch_footprint() + out.footprint();
    assert!(fp > 0, "warmup must have reserved scratch");
    // Steady state: identical passes may not move a single capacity.
    for pass in 0..4 {
        for inst in &insts {
            wf.assign_into(&inst.view(), &mut out);
        }
        assert_eq!(
            fp,
            wf.scratch_footprint() + out.footprint(),
            "WF scratch grew on steady-state pass {pass}"
        );
    }
}

#[test]
fn wf_outcomes_unchanged_by_buffer_reuse() {
    // Reusing one outcome across a mixed workload must give the same
    // results as a fresh outcome per call.
    let mut rng = Rng::seed_from(0xA110D);
    let insts = workload(&mut rng, 10, 16);
    let mut pooled_wf = Wf::new();
    let mut pooled_out = WfOutcome::default();
    for inst in &insts {
        pooled_wf.assign_into(&inst.view(), &mut pooled_out);
        let mut fresh_out = WfOutcome::default();
        Wf::new().assign_into(&inst.view(), &mut fresh_out);
        assert_eq!(pooled_out.to_assignment(), fresh_out.to_assignment());
        assert_eq!(pooled_out.final_busy(), fresh_out.final_busy());
    }
}

fn reorder_workload<'a>(jobs: &'a [taos::job::Job]) -> Vec<Outstanding<'a>> {
    jobs.iter()
        .map(|j| Outstanding {
            job: j,
            remaining: j.groups.iter().map(|g| g.size).collect(),
        })
        .collect()
}

fn random_jobs(rng: &mut Rng, m: usize, njobs: usize) -> Vec<taos::job::Job> {
    (0..njobs)
        .map(|id| {
            let k = 1 + rng.gen_range(3) as usize;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|_| {
                    let ns = 1 + rng.gen_range(m as u64) as usize;
                    let mut sv: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut sv);
                    sv.truncate(ns);
                    TaskGroup::new(rng.gen_range_incl(1, 30), sv)
                })
                .collect();
            taos::job::Job {
                id,
                arrival: id as u64,
                groups,
                mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
            }
        })
        .collect()
}

#[test]
fn reorder_capacity_freezes_after_warmup_serial_and_parallel() {
    let m = 10;
    let mut rng = Rng::seed_from(0xA110E);
    let jobs = random_jobs(&mut rng, m, 12);
    let outstanding = reorder_workload(&jobs);
    for (threads, acc) in [(1, false), (1, true), (2, false), (2, true)] {
        let mut ws = ReorderWorkspace::default();
        let mut out = ReorderOutcome::default();
        reorder_into(&outstanding, m, acc, threads, &mut ws, &mut out);
        let reference = out.clone();
        let fp = ws.footprint() + out.footprint();
        for pass in 0..4 {
            reorder_into(&outstanding, m, acc, threads, &mut ws, &mut out);
            assert_eq!(reference, out, "threads={threads} acc={acc}");
            assert_eq!(
                fp,
                ws.footprint() + out.footprint(),
                "reorder scratch grew: threads={threads} acc={acc} pass={pass}"
            );
        }
    }
}

#[test]
fn reorder_workspace_survives_alternating_shapes() {
    // Alternating between a wide and a narrow outstanding set through one
    // workspace: results stay correct and, after one full cycle, the
    // footprint freezes (row pools never shrink).
    let m = 8;
    let mut rng = Rng::seed_from(0xA110F);
    let wide_jobs = random_jobs(&mut rng, m, 14);
    let narrow_jobs = random_jobs(&mut rng, m, 3);
    let wide = reorder_workload(&wide_jobs);
    let narrow = reorder_workload(&narrow_jobs);
    let mut ws = ReorderWorkspace::default();
    let mut out = ReorderOutcome::default();
    // Warmup cycle.
    reorder_into(&wide, m, true, 1, &mut ws, &mut out);
    let wide_ref = out.clone();
    reorder_into(&narrow, m, true, 1, &mut ws, &mut out);
    let narrow_ref = out.clone();
    let fp = ws.footprint();
    for _ in 0..3 {
        reorder_into(&wide, m, true, 1, &mut ws, &mut out);
        assert_eq!(wide_ref, out);
        reorder_into(&narrow, m, true, 1, &mut ws, &mut out);
        assert_eq!(narrow_ref, out);
        assert_eq!(fp, ws.footprint(), "workspace churned between shapes");
    }
}

#[test]
fn exact_assigner_workspaces_freeze_after_warmup() {
    // OBTA / NLIP pool the feasibility-oracle arenas; RD pools its
    // replica tables. Cycling the same workload twice must not grow them.
    let mut rng = Rng::seed_from(0xA1110);
    let insts = workload(&mut rng, 8, 10);

    let mut obta = taos::assign::obta::Obta::new();
    for inst in &insts {
        obta.assign(&inst.view());
    }
    let fp = obta.workspace_footprint();
    for _ in 0..2 {
        for inst in &insts {
            obta.assign(&inst.view());
        }
        assert_eq!(fp, obta.workspace_footprint(), "OBTA oracle arena grew");
    }

    let mut rd = taos::assign::rd::Rd::new(5);
    for inst in &insts {
        rd.assign(&inst.view());
    }
    let fp = rd.scratch_footprint();
    for _ in 0..2 {
        for inst in &insts {
            rd.assign(&inst.view());
        }
        assert_eq!(fp, rd.scratch_footprint(), "RD replica tables grew");
    }
}

#[test]
fn reordered_arrival_path_footprint_freezes_after_warmup() {
    // The whole per-arrival path of the reordered engine — outstanding
    // set, reorder workspace/outcome, server queues (entries + recycled
    // parts buffers) and the QueueRebuild grouping rows — must stop
    // allocating once warm. The trace repeats an identical wave of jobs
    // with long gaps (queues fully drain between waves), so every wave
    // after warmup touches exactly the pooled buffers of the previous
    // one: any footprint movement is a per-arrival allocation.
    use taos::config::SimConfig;
    use taos::sim::ReorderedRun;

    let m = 8;
    let waves = 7usize;
    let per_wave = 5usize;
    let mut jobs: Vec<taos::job::Job> = Vec::new();
    for w in 0..waves {
        for j in 0..per_wave {
            // Identical shape in every wave (sizes/servers/mu depend on
            // j only), so the high-water mark is reached in wave one.
            let k = 1 + j % 3;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|g| {
                    let servers: Vec<usize> = (0..m).filter(|s| (s + g + j) % 2 == 0).collect();
                    TaskGroup::new(4 + 3 * j as u64 + g as u64, servers)
                })
                .collect();
            jobs.push(taos::job::Job {
                id: w * per_wave + j,
                arrival: (w as u64) * 10_000,
                groups,
                mu: (0..m).map(|s| 1 + ((s + j) % 3) as u64).collect(),
            });
        }
    }

    for (acc, threads) in [(true, 1), (false, 1), (true, 2)] {
        let cfg = SimConfig {
            reorder_threads: threads,
            ..SimConfig::default()
        };
        let mut run = ReorderedRun::new(&jobs, m, acc, &cfg);
        // Warmup: two waves (the first grows fresh buffers, the second
        // settles the recycled-buffer pairings in the spare pools).
        assert!(run.step());
        assert!(run.step());
        let fp = run.pool_footprint();
        assert!(fp > 0, "warmup must have pooled buffers");
        let mut wave = 2;
        loop {
            let more = run.step();
            assert_eq!(
                fp,
                run.pool_footprint(),
                "arrival path allocated on wave {wave} (acc={acc}, threads={threads})"
            );
            if !more {
                break;
            }
            wave += 1;
        }
        let out = run.finish().unwrap();
        assert_eq!(out.jcts.len(), jobs.len());
    }
}

#[test]
fn des_event_path_footprint_freezes_after_warmup() {
    // The DES engine's whole event path — the pooled event heap, lane
    // queues with their recycled parts buffers, the pair slab, the
    // rebuild rows and the shared reorder pools — must stop allocating
    // once warm. Same wave construction as the reordered-arrival test:
    // identical waves separated by gaps long enough to fully drain, so
    // in deterministic mode every wave after warmup replays the exact
    // buffer pattern of the previous one.
    use taos::config::SimConfig;
    use taos::des::DesRun;
    use taos::sched::SchedPolicy;

    let m = 8;
    let waves = 7usize;
    let per_wave = 5usize;
    let mut jobs: Vec<taos::job::Job> = Vec::new();
    for w in 0..waves {
        for j in 0..per_wave {
            let k = 1 + j % 3;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|g| {
                    let servers: Vec<usize> = (0..m).filter(|s| (s + g + j) % 2 == 0).collect();
                    TaskGroup::new(4 + 3 * j as u64 + g as u64, servers)
                })
                .collect();
            jobs.push(taos::job::Job {
                id: w * per_wave + j,
                arrival: (w as u64) * 10_000,
                groups,
                mu: (0..m).map(|s| 1 + ((s + j) % 3) as u64).collect(),
            });
        }
    }

    let warmup_deadline = 2 * 10_000; // two full waves
    for (policy, threads) in [
        (SchedPolicy::fifo(taos::assign::AssignPolicy::Wf), 1usize),
        (SchedPolicy::ocwf(true), 1),
        (SchedPolicy::ocwf(true), 2),
    ] {
        let cfg = SimConfig {
            reorder_threads: threads,
            ..SimConfig::default()
        };
        let mut run = DesRun::new(&jobs, m, policy, &cfg, 5);
        // Warmup: pump through the first two waves.
        let mut more = true;
        while more && run.now() < warmup_deadline {
            more = run.pump().unwrap();
        }
        let fp = run.pool_footprint();
        assert!(fp > 0, "warmup must have pooled buffers");
        while more {
            more = run.pump().unwrap();
            assert_eq!(
                fp,
                run.pool_footprint(),
                "DES event path allocated after warmup at slot {} ({}, {} threads)",
                run.now(),
                policy.name(),
                threads
            );
        }
        let out = run.finish().unwrap();
        assert_eq!(out.jcts.len(), jobs.len());
    }
}

#[test]
fn des_stochastic_speculation_footprint_freezes_after_warmup() {
    // Stochastic service + replica racing: single-job waves with two
    // disjoint two-server groups keep the queue *shapes* independent of
    // the sampled durations — at most four entries per wave, each with a
    // fixed replica target (the only other server of its group), so
    // every pooled counter is structurally below its next capacity
    // boundary (≤ 4 pairs on a min-capacity-4 slab, lane depth ≤ 2,
    // parts population ≤ 8 on the 4→8 spare-pool growth path) no matter
    // *which* subset of entries happens to straggle in a given wave.
    // The only capacity step left is the first fired replica (parts
    // population 4→5), and with a Pareto(1) tail virtually every entry
    // straggles, so warmup crosses it immediately. The footprint then
    // freezes even though every wave draws different service noise.
    use taos::config::SimConfig;
    use taos::des::service::ServiceModel;
    use taos::des::DesRun;
    use taos::sched::SchedPolicy;

    let m = 4;
    let waves = 12usize;
    let jobs: Vec<taos::job::Job> = (0..waves)
        .map(|w| taos::job::Job {
            id: w,
            arrival: (w as u64) * 50_000,
            groups: vec![
                TaskGroup::new(9, vec![0, 2]),
                TaskGroup::new(6, vec![1, 3]),
            ],
            mu: vec![1; m],
        })
        .collect();

    let warmup_deadline = 6 * 50_000; // six of twelve waves
    for policy in [
        SchedPolicy::fifo(taos::assign::AssignPolicy::Wf),
        SchedPolicy::ocwf(true),
    ] {
        let mut cfg = SimConfig::default();
        cfg.service = ServiceModel::ParetoTail {
            alpha: 1.0,
            cap: 4.0,
        };
        cfg.speculate = 1.0;
        let mut run = DesRun::new(&jobs, m, policy, &cfg, 9);
        let mut more = true;
        while more && run.now() < warmup_deadline {
            more = run.pump().unwrap();
        }
        let fp = run.pool_footprint();
        assert!(fp > 0, "warmup must have pooled buffers");
        while more {
            more = run.pump().unwrap();
            assert_eq!(
                fp,
                run.pool_footprint(),
                "speculative DES path allocated after warmup at slot {} ({})",
                run.now(),
                policy.name()
            );
        }
        let out = run.finish().unwrap();
        assert_eq!(out.jcts.len(), jobs.len());
    }
}

#[test]
fn jct_stats_scratch_capacity_freezes() {
    // The pooled sweep-statistics path (PR 10): one scratch buffer reused
    // across every per-cell `JctStats`/CDF computation must stop growing
    // after the first pass over the largest cell.
    use taos::metrics::{jct_cdf_pooled, JctStats, StatsScratch};

    let mut rng = Rng::seed_from(0xA1113);
    let big: Vec<u64> = (0..600).map(|_| rng.gen_range_incl(1, 10_000)).collect();
    let small: Vec<u64> = (0..40).map(|_| rng.gen_range_incl(1, 10_000)).collect();
    let mut scratch = StatsScratch::new();
    // Warmup: grow to the largest cell once.
    let _ = JctStats::from_jcts_pooled(&big, &mut scratch);
    let fp = scratch.footprint();
    assert!(fp >= big.len(), "warmup must have reserved the sort buffer");
    for pass in 0..4 {
        // Alternate shapes like a real sweep's collapse loop does.
        let _ = JctStats::from_jcts_pooled(&small, &mut scratch);
        let _ = jct_cdf_pooled(&small, 64, &mut scratch);
        let _ = JctStats::from_jcts_pooled(&big, &mut scratch);
        let _ = jct_cdf_pooled(&big, 64, &mut scratch);
        assert_eq!(fp, scratch.footprint(), "stats scratch grew on pass {pass}");
    }
}

#[test]
fn des_event_path_with_tracing_attached_footprint_freezes() {
    // Tracing on may not re-introduce steady-state allocations: the ring
    // buffer is sized at construction and the queue-depth histogram is a
    // fixed array, so a traced DES run must freeze exactly like the
    // untraced one (the tracer's frozen capacity is part of the
    // footprint).
    use taos::config::SimConfig;
    use taos::des::DesRun;
    use taos::obs::ObsSink;
    use taos::sched::SchedPolicy;

    let m = 8;
    let waves = 7usize;
    let per_wave = 5usize;
    let mut jobs: Vec<taos::job::Job> = Vec::new();
    for w in 0..waves {
        for j in 0..per_wave {
            let k = 1 + j % 3;
            let groups: Vec<TaskGroup> = (0..k)
                .map(|g| {
                    let servers: Vec<usize> = (0..m).filter(|s| (s + g + j) % 2 == 0).collect();
                    TaskGroup::new(4 + 3 * j as u64 + g as u64, servers)
                })
                .collect();
            jobs.push(taos::job::Job {
                id: w * per_wave + j,
                arrival: (w as u64) * 10_000,
                groups,
                mu: (0..m).map(|s| 1 + ((s + j) % 3) as u64).collect(),
            });
        }
    }

    let warmup_deadline = 2 * 10_000;
    for policy in [
        SchedPolicy::fifo(taos::assign::AssignPolicy::Wf),
        SchedPolicy::ocwf(true),
    ] {
        let cfg = SimConfig::default();
        let mut run = DesRun::new(&jobs, m, policy, &cfg, 5);
        run.attach_obs(ObsSink::new(1 << 12, true));
        let mut more = true;
        while more && run.now() < warmup_deadline {
            more = run.pump().unwrap();
        }
        let fp = run.pool_footprint();
        assert!(fp >= 1 << 12, "tracer capacity must be in the footprint");
        while more {
            more = run.pump().unwrap();
            assert_eq!(
                fp,
                run.pool_footprint(),
                "traced DES path allocated after warmup at slot {} ({})",
                run.now(),
                policy.name()
            );
        }
        let out = run.finish().unwrap();
        assert_eq!(out.jcts.len(), jobs.len());
    }
}

#[test]
fn executor_spawns_zero_threads_after_warmup() {
    // Every parallel entry point in this crate runs on the process-wide
    // persistent executor. After one warmup batch the worker count is
    // frozen: no code path may spawn another thread, no matter how many
    // batches (sweep cells, reorder chunks) are dispatched.
    //
    // This test binary creates no test-local pools, so the process-wide
    // spawn counter can only move if the pool itself respawns — exactly
    // the regression this guards against.
    let m = 8;
    let mut rng = Rng::seed_from(0xA1111);
    let jobs = random_jobs(&mut rng, m, 10);
    let outstanding = reorder_workload(&jobs);

    // Warmup: exercise both fan-outs once.
    let _ = taos::sweep::pool::parallel_map(32, 4, |i| i * i);
    let mut ws = ReorderWorkspace::default();
    let mut out = ReorderOutcome::default();
    reorder_into(&outstanding, m, true, 4, &mut ws, &mut out);

    let spawned = taos::runtime::executor::threads_spawned_total();
    assert!(spawned >= 1, "warmup must have started the pool");
    for pass in 0..20usize {
        let v = taos::sweep::pool::parallel_map(64, 8, |i| i + pass);
        assert_eq!(v.len(), 64);
        reorder_into(&outstanding, m, true, 8, &mut ws, &mut out);
        reorder_into(&outstanding, m, false, 2, &mut ws, &mut out);
    }
    assert_eq!(
        spawned,
        taos::runtime::executor::threads_spawned_total(),
        "executor spawned threads after warmup"
    );
}

#[test]
fn outstanding_set_performs_no_per_arrival_allocations() {
    // The pooled replacement for run_reordered's per-arrival
    // `Outstanding.remaining` clones: rebuilding the set through the pool
    // — including shrinking and regrowing the live row count, as the
    // simulator does between arrivals — must stop growing capacity after
    // the first full cycle.
    let m = 9;
    let mut rng = Rng::seed_from(0xA1112);
    let jobs = random_jobs(&mut rng, m, 16);
    let remaining: Vec<Vec<u64>> = jobs
        .iter()
        .map(|j| j.groups.iter().map(|g| g.size).collect())
        .collect();

    let mut set = OutstandingSet::new();
    let arrivals = [16usize, 4, 11, 16, 2, 9, 16];
    // Warmup cycle: buffers grow to the high-water mark.
    for &live in &arrivals {
        set.clear();
        for i in 0..live {
            set.push(&jobs[i], &remaining[i]);
        }
    }
    let fp = set.footprint();
    assert!(fp > 0, "warmup must have pooled buffers");
    for pass in 0..4 {
        for &live in &arrivals {
            set.clear();
            for i in 0..live {
                set.push(&jobs[i], &remaining[i]);
            }
            assert_eq!(set.len(), live);
            // Contents are faithful copies, not stale pool leftovers.
            let last = &set.as_slice()[live - 1];
            assert_eq!(last.remaining, remaining[live - 1]);
        }
        assert_eq!(fp, set.footprint(), "outstanding pool grew on pass {pass}");
    }
}
