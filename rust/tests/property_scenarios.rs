//! Property tests (via the in-repo `taos::proptest` framework) for the
//! paper's approximation guarantees and the scenario-generator subsystem.
//!
//! - Thms 1–2: on random instances, WF's estimated completion time Φ is
//!   at most K_c × OBTA's exact Φ (K_c = number of task groups), and the
//!   bound holds for the realized program-P objective of the returned
//!   allocations too.
//! - Every assigner's output passes `validate_assignment` — including on
//!   scatter-shaped (non-contiguous) available-server sets.
//! - Every named scenario generates calibrated traces: exact task totals,
//!   ≥ 1 task per group, chronological arrivals, and materializations
//!   that respect the cluster's ranges.

// `Assigner` must be in scope for the `.assign` calls on the boxed trait
// objects `AssignPolicy::build` returns.
use taos::assign::{program_phi, validate_assignment, AssignPolicy, Assigner, Instance};
use taos::cluster::placement::{Placement, PlacementMode};
use taos::cluster::Cluster;
use taos::config::{ClusterConfig, TraceConfig};
use taos::job::TaskGroup;
use taos::proptest::{forall, Config};
use taos::trace::scenarios::Scenario;
use taos::util::rng::Rng;

/// Random instance whose group server-sets come from a (possibly
/// scattered) placement sampler — the shapes the scenario subsystem
/// actually produces, unlike the uniform-random sets of the older tests.
fn random_placed_instance(rng: &mut Rng) -> (Vec<TaskGroup>, Vec<u64>, Vec<u64>) {
    let m = 3 + rng.gen_range(10) as usize;
    let k = 1 + rng.gen_range(4) as usize;
    let alpha = rng.gen_f64() * 2.0;
    let mode = if rng.gen_range(2) == 0 {
        PlacementMode::Ring
    } else {
        PlacementMode::Scatter
    };
    let pl = Placement::with_mode(m, alpha, mode, rng);
    let p_hi = 2 + rng.gen_range((m - 1) as u64) as usize;
    let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(1, 5)).collect();
    let busy: Vec<u64> = (0..m).map(|_| rng.gen_range(12)).collect();
    let groups: Vec<TaskGroup> = (0..k)
        .map(|_| {
            let servers = pl.sample_group_servers(rng, 1, p_hi);
            TaskGroup::new(rng.gen_range_incl(1, 40), servers)
        })
        .collect();
    (groups, mu, busy)
}

#[test]
fn property_all_assigners_valid_on_placed_instances() {
    forall(
        Config::default().cases(96).seed(0xB01),
        random_placed_instance,
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            AssignPolicy::ALL.iter().all(|p| {
                let a = p.build(3).assign(&inst);
                validate_assignment(&inst, &a).is_ok()
            })
        },
    );
}

#[test]
fn property_wf_phi_within_kc_times_obta() {
    // Theorem 2: WF(I) <= K_c · Φ*(I). OBTA solves P exactly, so its Φ
    // is the optimum. Checked on the reported Φ and on the program-P
    // objective of the concrete allocations.
    forall(
        Config::default().cases(72).seed(0xB02),
        random_placed_instance,
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            let k_c = groups.iter().filter(|g| g.size > 0).count() as u64;
            let wf = AssignPolicy::Wf.build(0).assign(&inst);
            let opt = AssignPolicy::Obta.build(0).assign(&inst);
            wf.phi <= k_c * opt.phi
                && program_phi(&inst, &wf.per_group) <= k_c * program_phi(&inst, &opt.per_group)
        },
    );
}

#[test]
fn property_obta_never_above_wf() {
    // The exact optimum lower-bounds the approximation on every instance.
    forall(
        Config::default().cases(72).seed(0xB03),
        random_placed_instance,
        |(groups, mu, busy)| {
            let inst = Instance { groups, mu, busy };
            let wf = AssignPolicy::Wf.build(0).assign(&inst);
            let opt = AssignPolicy::Obta.build(0).assign(&inst);
            opt.phi <= wf.phi
        },
    );
}

#[test]
fn property_scenarios_generate_calibrated_traces() {
    forall(
        Config::default().cases(40).seed(0xB04),
        |rng| {
            let jobs = 5 + rng.gen_range(40) as usize;
            let tasks = jobs * (2 + rng.gen_range(60) as usize);
            let scenario = Scenario::ALL[rng.gen_range(Scenario::ALL.len() as u64) as usize];
            let seed = rng.next_u64();
            (jobs, tasks, scenario, seed)
        },
        |&(jobs, tasks, scenario, seed)| {
            let mut cfg = TraceConfig::default();
            cfg.jobs = jobs;
            cfg.total_tasks = tasks;
            let trace = scenario.synth(&cfg, &mut Rng::seed_from(seed));
            // Calibration contract: exact total, except it never shrinks a
            // group below one task.
            let expected = (tasks as u64).max(trace.total_groups() as u64);
            trace.jobs.len() == jobs
                && trace.total_tasks() == expected
                && trace.jobs.iter().flat_map(|j| &j.group_sizes).all(|&s| s >= 1)
                && trace.jobs.windows(2).all(|w| w[0].arrival_raw <= w[1].arrival_raw)
        },
    );
}

#[test]
fn property_scatter_sets_distinct_and_sized() {
    forall(
        Config::default().cases(80).seed(0xB05),
        |rng| {
            let m = 2 + rng.gen_range(40) as usize;
            let alpha = rng.gen_f64() * 2.0;
            let p_lo = 1 + rng.gen_range(m as u64) as usize;
            let p_hi = p_lo + rng.gen_range(8) as usize;
            let seed = rng.next_u64();
            (m, alpha, p_lo, p_hi, seed)
        },
        |&(m, alpha, p_lo, p_hi, seed)| {
            let mut rng = Rng::seed_from(seed);
            let pl = Placement::with_mode(m, alpha, PlacementMode::Scatter, &mut rng);
            (0..20).all(|_| {
                let s = pl.sample_group_servers(&mut rng, p_lo, p_hi);
                let mut d = s.clone();
                d.dedup(); // scatter output is sorted
                s.len() >= p_lo.min(m)
                    && s.len() <= p_hi.min(m).max(1)
                    && d.len() == s.len()
                    && s.iter().all(|&x| x < m)
            })
        },
    );
}

#[test]
fn property_hetero_cluster_mu_positive_and_calibrated() {
    forall(
        Config::default().cases(48).seed(0xB06),
        |rng| {
            let servers = 2 + rng.gen_range(60) as usize;
            let skew = rng.gen_f64() * 2.0;
            let seed = rng.next_u64();
            (servers, skew, seed)
        },
        |&(servers, skew, seed)| {
            let mut cfg = ClusterConfig::default();
            cfg.servers = servers;
            cfg.mu_skew = skew;
            let mut rng = Rng::seed_from(seed);
            let cluster = Cluster::generate(&cfg, &mut rng);
            let mu = cluster.sample_mu(&mut rng);
            mu.len() == servers
                && mu.iter().all(|&x| x >= 1)
                && cluster.mean_mu().is_finite()
                && cluster.mean_mu() >= 1.0
        },
    );
}

#[test]
fn property_csv_roundtrip_preserves_structure() {
    use taos::trace::csv::{parse_batch_task, to_batch_task_csv};
    forall(
        Config::default().cases(32).seed(0xB07),
        |rng| {
            let jobs = 2 + rng.gen_range(25) as usize;
            let tasks = jobs * (3 + rng.gen_range(30) as usize);
            let seed = rng.next_u64();
            (jobs, tasks, seed)
        },
        |&(jobs, tasks, seed)| {
            let mut cfg = TraceConfig::default();
            cfg.jobs = jobs;
            cfg.total_tasks = tasks;
            let trace = Scenario::Bursty.synth(&cfg, &mut Rng::seed_from(seed));
            let parsed = match parse_batch_task(&to_batch_task_csv(&trace)) {
                Ok(t) => t,
                Err(_) => return false,
            };
            parsed.jobs.len() == trace.jobs.len()
                && parsed.total_tasks() == trace.total_tasks()
                && parsed
                    .jobs
                    .iter()
                    .zip(&trace.jobs)
                    .all(|(a, b)| a.group_sizes == b.group_sizes)
        },
    );
}
