//! Observability-layer invariants (PR 10):
//!
//! 1. **Zero perturbation** — attaching a tracing + metrics sink must not
//!    change a single bit of any outcome, per engine × policy.
//! 2. **Determinism** — exported trace and metrics artifacts are
//!    byte-identical for a fixed seed at every `TAOS_TEST_THREADS` count
//!    (timestamps are simulation slots, never wall clock; the registry
//!    deliberately excludes every wall-clock metric).
//! 3. **Conservation** — the latency decomposition satisfies
//!    `wait + service = JCT` per job, and FIFO waits agree bit-for-bit
//!    between the analytic and DES engines.
//! 4. **Bounded memory** — the trace ring really truncates oldest-first
//!    and reports the drop count.

use taos::config::ExperimentConfig;
use taos::des::service::EngineKind;
use taos::obs::{registry_from, to_chrome_json, to_jsonl, ObsSink, TraceKind, Tracer};
use taos::sched::SchedPolicy;
use taos::sim::{run_experiment, run_experiment_obs};
use taos::sweep::{self, pool};
use taos::util::json::Json;

fn tiny_base(engine: EngineKind) -> ExperimentConfig {
    let mut cfg = sweep::quick_base(4242);
    cfg.trace.jobs = 24;
    cfg.trace.total_tasks = 1_500;
    cfg.cluster.servers = 12;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    cfg.sim.engine = engine;
    cfg
}

fn panel() -> Vec<SchedPolicy> {
    vec![
        SchedPolicy::parse("wf").unwrap(),
        SchedPolicy::parse("obta").unwrap(),
        SchedPolicy::parse("ocwf").unwrap(),
        SchedPolicy::parse("ocwf-acc").unwrap(),
    ]
}

#[test]
fn tracing_never_changes_outcomes() {
    for engine in [EngineKind::Analytic, EngineKind::Des] {
        let cfg = tiny_base(engine);
        for policy in panel() {
            let plain = run_experiment(&cfg, policy).unwrap();
            let mut obs = ObsSink::new(1 << 14, true);
            let traced = run_experiment_obs(&cfg, policy, &mut obs).unwrap();
            let tag = format!("{} / {}", engine.name(), policy.name());
            assert_eq!(plain.jcts, traced.jcts, "JCTs perturbed: {tag}");
            assert_eq!(plain.waits, traced.waits, "waits perturbed: {tag}");
            assert_eq!(plain.makespan, traced.makespan, "makespan perturbed: {tag}");
            assert_eq!(plain.wf_evals, traced.wf_evals, "wf_evals perturbed: {tag}");
            assert!(obs.trace.total() > 0, "no events recorded: {tag}");
            let kinds: Vec<TraceKind> = obs.trace.iter_in_order().map(|e| e.kind).collect();
            assert!(kinds.contains(&TraceKind::JobArrive), "{tag}");
            if engine == EngineKind::Des || policy.is_fifo() {
                // The DES loop and the analytic FIFO fold see every task
                // start and completion; the analytic reordered engine
                // only traces arrivals and reorder rounds.
                assert!(kinds.contains(&TraceKind::TaskStart), "{tag}");
                let completes = kinds
                    .iter()
                    .filter(|&&k| k == TraceKind::JobComplete)
                    .count();
                assert_eq!(completes, plain.jcts.len(), "one completion per job: {tag}");
            } else {
                assert!(kinds.contains(&TraceKind::ReorderRound), "{tag}");
            }
        }
    }
}

#[test]
fn latency_decomposition_conserves_jct() {
    for engine in [EngineKind::Analytic, EngineKind::Des] {
        let cfg = tiny_base(engine);
        for policy in panel() {
            let out = run_experiment(&cfg, policy).unwrap();
            let tag = format!("{} / {}", engine.name(), policy.name());
            assert_eq!(out.waits.len(), out.jcts.len(), "{tag}");
            for (i, (&w, &jct)) in out.waits.iter().zip(&out.jcts).enumerate() {
                assert!(w <= jct, "job {i}: wait {w} > JCT {jct} ({tag})");
            }
            // mean_wait + mean_service == mean_jct by construction; check
            // the floating-point identity actually holds.
            let recomposed = out.mean_wait() + out.mean_service();
            assert!(
                (recomposed - out.mean_jct()).abs() < 1e-9,
                "decomposition drifted: {tag}"
            );
        }
    }
    // Waits must agree bit-for-bit across engines under deterministic
    // service (same rule in both: first slot of real progress minus
    // arrival) — the CI DES-vs-analytic JSON diff relies on this for
    // every policy, not just FIFO.
    for policy in panel() {
        let a = run_experiment(&tiny_base(EngineKind::Analytic), policy).unwrap();
        let d = run_experiment(&tiny_base(EngineKind::Des), policy).unwrap();
        assert_eq!(
            a.waits,
            d.waits,
            "{}: wait vectors diverged across engines",
            policy.name()
        );
    }
}

#[test]
fn exported_artifacts_byte_identical_across_thread_counts() {
    // The reordered policies fan admission rounds across
    // `reorder_threads`; the trace and the metrics registry must come out
    // byte-identical at every thread count (the registry excludes every
    // wall-clock metric for exactly this reason).
    for engine in [EngineKind::Analytic, EngineKind::Des] {
        let mut reference: Option<(String, String, String, String)> = None;
        for threads in pool::test_thread_counts() {
            let mut cfg = tiny_base(engine);
            cfg.sim.reorder_threads = threads;
            let mut obs = ObsSink::new(1 << 14, true);
            let out = run_experiment_obs(&cfg, SchedPolicy::parse("ocwf").unwrap(), &mut obs)
                .unwrap();
            let reg = registry_from(&out, &obs);
            let artifacts = (
                to_chrome_json(&obs.trace, cfg.cluster.servers),
                to_jsonl(&obs.trace),
                reg.to_json().to_string(),
                reg.to_prometheus(),
            );
            match &reference {
                None => reference = Some(artifacts),
                Some(r) => {
                    let tag = format!("{} @ {threads} threads", engine.name());
                    assert_eq!(r.0, artifacts.0, "chrome trace diverged: {tag}");
                    assert_eq!(r.1, artifacts.1, "jsonl trace diverged: {tag}");
                    assert_eq!(r.2, artifacts.2, "metrics json diverged: {tag}");
                    assert_eq!(r.3, artifacts.3, "prometheus text diverged: {tag}");
                }
            }
        }
    }
}

#[test]
fn chrome_export_is_valid_and_schema_complete() {
    let cfg = tiny_base(EngineKind::Des);
    let mut obs = ObsSink::new(1 << 14, true);
    run_experiment_obs(&cfg, SchedPolicy::parse("wf").unwrap(), &mut obs).unwrap();
    let body = to_chrome_json(&obs.trace, cfg.cluster.servers);
    let parsed = Json::parse(&body).expect("chrome trace JSON parses");
    let Json::Obj(top) = parsed else {
        panic!("top level must be an object")
    };
    let Some(Json::Arr(events)) = top.get("traceEvents") else {
        panic!("traceEvents array missing")
    };
    assert!(!events.is_empty());
    for (i, ev) in events.iter().enumerate() {
        let Json::Obj(e) = ev else {
            panic!("event {i} not an object")
        };
        for key in ["ph", "ts", "pid"] {
            assert!(e.contains_key(key), "event {i} missing `{key}`");
        }
    }
    // Every JSONL line is itself a JSON object with the raw fields.
    let jsonl = to_jsonl(&obs.trace);
    for (i, line) in jsonl.lines().enumerate() {
        let Json::Obj(e) = Json::parse(line).expect("jsonl line parses") else {
            panic!("line {i} not an object")
        };
        for key in ["ts", "kind", "job", "server"] {
            assert!(e.contains_key(key), "line {i} missing `{key}`");
        }
    }
}

#[test]
fn ring_truncates_oldest_first_and_counts_drops() {
    let mut tr = Tracer::with_capacity(4);
    for t in 0..10u64 {
        tr.job_arrive(t, t as usize, 1, 1);
    }
    assert_eq!(tr.len(), 4);
    assert_eq!(tr.total(), 10);
    assert_eq!(tr.dropped(), 6);
    let times: Vec<u64> = tr.iter_in_order().map(|e| e.time).collect();
    assert_eq!(times, vec![6, 7, 8, 9], "last-N semantics, oldest first");
    // And the footprint is frozen at the construction-time capacity.
    assert_eq!(tr.footprint(), 4);
}

#[test]
fn metrics_registry_reflects_run_and_decomposition() {
    let cfg = tiny_base(EngineKind::Des);
    let mut obs = ObsSink::new(0, true); // metrics without tracing
    let out = run_experiment_obs(&cfg, SchedPolicy::parse("wf").unwrap(), &mut obs).unwrap();
    let reg = registry_from(&out, &obs);
    let j = reg.to_json().to_string();
    for name in [
        "taos_jobs_total",
        "taos_makespan_slots",
        "taos_job_jct_slots",
        "taos_job_wait_slots",
        "taos_job_service_slots",
        "taos_queue_depth_slots",
    ] {
        assert!(j.contains(name), "registry missing `{name}`:\n{j}");
    }
    let prom = reg.to_prometheus();
    assert!(prom.contains("taos_jobs_total"));
    assert!(prom.contains("_bucket{le="), "histogram exposition missing");
    assert!(prom.ends_with('\n'), "exposition ends with a newline");
}
