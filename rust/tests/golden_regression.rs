//! Golden-trace regression tests: a small fixed-seed experiment with a
//! snapshotted mean JCT per policy (the four FIFO assigners plus
//! OCWF/OCWF-ACC), and an exact round-trip through the `batch_task.csv`
//! serializer/parser.
//!
//! Snapshot protocol: the expected values live in
//! `rust/tests/golden/jct_snapshot.txt`. When the file is missing the
//! test *blesses* it (writes the observed values and passes, printing a
//! note); when it exists the observed values must match exactly. CI runs
//! `cargo test` twice back-to-back so the second run always verifies the
//! freshly blessed snapshot — any nondeterminism or cross-platform drift
//! in the simulation pipeline fails the build. Regenerate intentionally
//! with `TAOS_BLESS=1 cargo test -q --test golden_regression`.
//!
//! With `TAOS_GOLDEN_REQUIRE=1` a missing snapshot is an **error**
//! instead of a bless: set on every CI run after the first so the suite
//! *verifies* rather than silently re-blessing (e.g. when a cache wipe
//! drops the first run's file). Once a reviewed snapshot from the CI
//! artifact is committed, CI can set it unconditionally.

use taos::config::ExperimentConfig;
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;

fn golden_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.jobs = 25;
    cfg.trace.total_tasks = 1_500;
    cfg.trace.utilization = 0.6;
    cfg.cluster.servers = 20;
    cfg.cluster.zipf_alpha = 1.0;
    cfg.cluster.avail_lo = 4;
    cfg.cluster.avail_hi = 6;
    cfg.seed = 2024;
    cfg
}

/// Render the snapshot: one `policy mean_jct` line per algorithm, mean
/// formatted to 6 decimals (JCTs are integer slots, so the mean of 25 of
/// them is exactly representable at this precision).
fn observed_snapshot() -> String {
    let cfg = golden_cfg();
    let mut out = String::new();
    for policy in SchedPolicy::ALL {
        let res = run_experiment(&cfg, policy).expect(policy.name());
        out.push_str(&format!("{} {:.6}\n", policy.name(), res.mean_jct()));
    }
    out
}

fn snapshot_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
        .join("jct_snapshot.txt")
}

/// Env-var switch: set-and-nonzero means on. (`VAR=0` and `VAR=` count
/// as off, so CI can compute the value in a detection step and pass it
/// unconditionally instead of editing the workflow when the snapshot
/// lands.)
fn env_flag(name: &str) -> bool {
    matches!(std::env::var(name), Ok(v) if !v.is_empty() && v != "0")
}

#[test]
fn golden_mean_jct_per_policy() {
    let observed = observed_snapshot();
    let path = snapshot_path();
    if !path.exists() && env_flag("TAOS_GOLDEN_REQUIRE") {
        panic!(
            "golden snapshot {} missing but TAOS_GOLDEN_REQUIRE is set — \
             the verifying run must not silently re-bless; run once \
             without the variable (or commit the reviewed CI artifact) \
             first",
            path.display()
        );
    }
    let bless = env_flag("TAOS_BLESS") || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir golden/");
        std::fs::write(&path, &observed).expect("write snapshot");
        eprintln!("blessed golden snapshot at {}:\n{observed}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read snapshot");
    assert_eq!(
        observed,
        expected,
        "mean JCT drifted from the golden snapshot ({}); if the change is \
         intentional, regenerate with TAOS_BLESS=1",
        path.display()
    );
}

#[test]
fn golden_run_is_deterministic_in_process() {
    // The snapshot is only meaningful if two in-process runs agree.
    assert_eq!(observed_snapshot(), observed_snapshot());
}

#[test]
fn csv_roundtrip_exact() {
    use taos::trace::csv::{parse_batch_task, to_batch_task_csv};
    use taos::trace::Trace;
    use taos::util::rng::Rng;

    let mut tcfg = taos::config::TraceConfig::default();
    tcfg.jobs = 30;
    tcfg.total_tasks = 2_000;
    let trace = Trace::synth_alibaba(&tcfg, &mut Rng::seed_from(77));
    let csv = to_batch_task_csv(&trace);
    let parsed = parse_batch_task(&csv).expect("parse generated csv");

    assert_eq!(parsed.jobs.len(), trace.jobs.len());
    assert_eq!(parsed.total_tasks(), trace.total_tasks());
    assert_eq!(parsed.total_groups(), trace.total_groups());
    for (i, (a, b)) in parsed.jobs.iter().zip(&trace.jobs).enumerate() {
        assert_eq!(a.group_sizes, b.group_sizes, "job {i} group sizes");
    }
    // Arrival order survives quantization (normalized to start at 0, in
    // milliseconds of raw time).
    for w in parsed.jobs.windows(2) {
        assert!(w[0].arrival_raw <= w[1].arrival_raw);
    }
    assert_eq!(parsed.jobs[0].arrival_raw, 0.0);

    // A second round trip is a fixed point: parse(serialize(parse(x)))
    // equals parse(x) exactly.
    let again = parse_batch_task(&to_batch_task_csv(&parsed)).expect("reparse");
    for (a, b) in again.jobs.iter().zip(&parsed.jobs) {
        assert_eq!(a.group_sizes, b.group_sizes);
    }
}
