//! Differential suite for the k-replica redundancy engine.
//!
//! The k-member replica-set slab replaced the hard-coded two-member
//! pair slab, so the contract is backwards bit-compatibility plus new
//! conservation laws:
//!
//! - `--replicas 2` (explicit) is bit-identical to the `--speculate`
//!   alias (replicas = 0, speculate armed) — the old one-sibling
//!   engine's behaviour — on the straggler preset at every reorder
//!   thread count.
//! - `--replicas 1` (racing off) is bit-identical to no speculation at
//!   all: the fork gate never opens and no telemetry accrues.
//! - Wasted work obeys conservation: `wasted_work <= busy_work`, the
//!   fraction lands in [0, 1], and a race-free run wastes nothing.
//! - Every K is seed-reproducible: same seed, same config, same JCT
//!   vector and the same wasted-work ledger, run after run.
//!
//! Thread counts come from `TAOS_TEST_THREADS` (default 1,2,8) so the
//! CI determinism matrix can pin one count per leg, exactly like
//! `des_equivalence` / `sweep_determinism`.

use taos::assign::AssignPolicy;
use taos::config::ExperimentConfig;
use taos::des::service::{EngineKind, ReplicationBudget, ServiceModel};
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;
use taos::sweep::{self, pool};
use taos::trace::scenarios::Scenario;

fn straggler_cfg() -> ExperimentConfig {
    let mut cfg = sweep::quick_base(0x4E90);
    cfg.trace.jobs = 18;
    cfg.trace.total_tasks = 900;
    cfg.cluster.servers = 14;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    Scenario::Straggler.apply(&mut cfg);
    cfg
}

#[test]
fn explicit_k2_bit_identical_to_speculate_alias() {
    // The speculate alias (replicas = 0, speculate armed) must be the
    // same engine as an explicit two-member race: same fork decisions,
    // same winner, same RNG stream, same ledger.
    let alias = straggler_cfg();
    assert_eq!(alias.sim.replicas, 0, "preset leaves the alias in charge");
    assert!(alias.sim.speculate > 0.0);
    let mut explicit = alias.clone();
    explicit.sim.replicas = 2;
    for policy in [
        SchedPolicy::fifo(AssignPolicy::Wf),
        SchedPolicy::fifo(AssignPolicy::Rd),
        SchedPolicy::ocwf(true),
    ] {
        for threads in pool::test_thread_counts() {
            let mut a = alias.clone();
            let mut e = explicit.clone();
            a.sim.reorder_threads = threads;
            e.sim.reorder_threads = threads;
            let old = run_experiment(&a, policy)
                .unwrap_or_else(|err| panic!("alias/{}/{threads}: {err}", policy.name()));
            let new = run_experiment(&e, policy)
                .unwrap_or_else(|err| panic!("k2/{}/{threads}: {err}", policy.name()));
            assert_eq!(
                old.jcts,
                new.jcts,
                "{}/{threads} threads: K=2 must be bit-identical to the speculate alias",
                policy.name()
            );
            assert_eq!(old.makespan, new.makespan, "{}/{threads}", policy.name());
            assert_eq!(old.wf_evals, new.wf_evals, "{}/{threads}", policy.name());
            assert_eq!(
                (old.wasted_work, old.busy_work),
                (new.wasted_work, new.busy_work),
                "{}/{threads}: the wasted-work ledger is part of the contract",
                policy.name()
            );
        }
    }
}

#[test]
fn k1_bit_identical_to_no_speculation() {
    // replicas = 1 means "racing off" even with --speculate armed: the
    // fork gate never opens, so the run must match speculate = 0 bit
    // for bit and waste nothing.
    let mut off = straggler_cfg();
    off.sim.speculate = 0.0;
    let mut k1 = straggler_cfg();
    k1.sim.replicas = 1; // speculate stays armed from the preset
    for policy in [SchedPolicy::fifo(AssignPolicy::Wf), SchedPolicy::ocwf(false)] {
        let base = run_experiment(&off, policy)
            .unwrap_or_else(|e| panic!("off/{}: {e}", policy.name()));
        let solo = run_experiment(&k1, policy)
            .unwrap_or_else(|e| panic!("k1/{}: {e}", policy.name()));
        assert_eq!(
            base.jcts,
            solo.jcts,
            "{}: K=1 must equal no-speculation bit for bit",
            policy.name()
        );
        assert_eq!(base.makespan, solo.makespan, "{}", policy.name());
        assert_eq!(solo.wasted_work, 0, "{}: no race, no waste", policy.name());
        assert_eq!(base.wasted_work, 0, "{}", policy.name());
        assert!(solo.busy_work > 0, "{}: DES runs account service slots", policy.name());
        assert_eq!(solo.busy_work, base.busy_work, "{}", policy.name());
    }
}

#[test]
fn wasted_work_obeys_conservation() {
    // On the k-replica preset (K = 3, Pareto tails) the loser slots are
    // a strict subset of all service slots, the fraction is a
    // probability, and the races actually fire.
    let mut cfg = sweep::quick_base(0x4E91);
    cfg.trace.jobs = 18;
    cfg.trace.total_tasks = 900;
    cfg.cluster.servers = 14;
    cfg.cluster.avail_lo = 3;
    cfg.cluster.avail_hi = 5;
    Scenario::KReplica.apply(&mut cfg);
    assert_eq!(cfg.sim.replicas, 3);
    let mut any_wasted = false;
    for policy in [
        SchedPolicy::fifo(AssignPolicy::Wf),
        SchedPolicy::fifo(AssignPolicy::Rd),
        SchedPolicy::ocwf(true),
    ] {
        let out = run_experiment(&cfg, policy)
            .unwrap_or_else(|e| panic!("{}: {e}", policy.name()));
        assert!(out.busy_work > 0, "{}", policy.name());
        assert!(
            out.wasted_work <= out.busy_work,
            "{}: losers ({}) cannot outnumber all service slots ({})",
            policy.name(),
            out.wasted_work,
            out.busy_work
        );
        let f = out.wasted_fraction();
        assert!((0.0..=1.0).contains(&f), "{}: fraction {f}", policy.name());
        any_wasted |= out.wasted_work > 0;
    }
    assert!(
        any_wasted,
        "K=3 Pareto races must cancel at least one running loser across policies"
    );
}

#[test]
fn every_k_is_seed_reproducible() {
    // Same seed, same K → byte-identical JCTs and the same ledger, for
    // every replica count the CLI accepts on this preset.
    for k in 1..=4usize {
        let mut cfg = straggler_cfg();
        cfg.sim.replicas = k;
        for policy in [SchedPolicy::fifo(AssignPolicy::Wf), SchedPolicy::ocwf(true)] {
            let a = run_experiment(&cfg, policy)
                .unwrap_or_else(|e| panic!("k{k}/{}: {e}", policy.name()));
            let b = run_experiment(&cfg, policy).unwrap();
            assert_eq!(
                a.jcts,
                b.jcts,
                "k{k}/{}: same seed must give byte-identical JCTs",
                policy.name()
            );
            assert_eq!(a.makespan, b.makespan, "k{k}/{}", policy.name());
            assert_eq!(
                (a.wasted_work, a.busy_work),
                (b.wasted_work, b.busy_work),
                "k{k}/{}: the ledger must reproduce too",
                policy.name()
            );
            assert_eq!(a.jcts.len(), cfg.trace.jobs, "k{k}/{}", policy.name());
        }
    }
}

#[test]
fn budget_gates_are_live_and_deterministic() {
    // `always` forks without a speculate threshold; `idle` only forks
    // onto strictly idle servers. Both must validate, run, and
    // reproduce; `always` on an exponential cluster must actually burn
    // loser slots.
    let mut cfg = straggler_cfg();
    cfg.sim.engine = EngineKind::Des;
    cfg.sim.service = ServiceModel::Exp { mean: 1.0 };
    cfg.sim.speculate = 0.0;
    cfg.sim.replicas = 2;
    cfg.sim.replication_budget = ReplicationBudget::Always;
    cfg.validate().expect("always-budget racing needs no speculate threshold");
    let policy = SchedPolicy::fifo(AssignPolicy::Wf);
    let a = run_experiment(&cfg, policy).unwrap();
    let b = run_experiment(&cfg, policy).unwrap();
    assert_eq!(a.jcts, b.jcts, "always-budget runs must reproduce");
    assert!(
        a.wasted_work > 0,
        "forking every primary on exp service must cancel some loser mid-flight"
    );
    assert!(a.wasted_work <= a.busy_work);

    let mut idle = straggler_cfg();
    idle.sim.replicas = 3;
    idle.sim.replication_budget = ReplicationBudget::Idle;
    idle.validate().expect("idle budget rides the preset's speculate threshold");
    let i1 = run_experiment(&idle, policy).unwrap();
    let i2 = run_experiment(&idle, policy).unwrap();
    assert_eq!(i1.jcts, i2.jcts, "idle-budget runs must reproduce");
    assert!(i1.wasted_work <= i1.busy_work);
}
