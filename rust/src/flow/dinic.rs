//! Dinic's max-flow algorithm with integer capacities.
//!
//! Complexity O(V²E) in general; on the unit-ish bipartite networks the
//! assignment layer builds it behaves like O(E·√V), which is why OBTA's
//! per-candidate-Φ feasibility check is cheap. The arena supports `reset`
//! so the assignment loop can re-run flows without reallocating.

/// Opaque handle to an edge, for querying its flow after `max_flow`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeRef(usize);

#[derive(Clone, Debug)]
struct Edge {
    to: usize,
    cap: u64,
    /// Original capacity (for `reset` / `flow_of`).
    orig: u64,
}

/// Dinic max-flow solver over a fixed node set. The arena is reusable two
/// ways: [`Dinic::reset`] re-runs flows on the same topology, and
/// [`Dinic::reinit`] rebuilds a fresh graph while keeping every
/// allocation (adjacency rows, edge arena, BFS queue) — the feasibility
/// oracle's per-arrival pooling relies on the latter.
#[derive(Clone, Debug, Default)]
pub struct Dinic {
    /// Adjacency: node -> indices into `edges`. Edge `i^1` is the reverse
    /// of edge `i` (edges are pushed in pairs). Rows beyond the active
    /// node count are kept (empty) for reuse.
    adj: Vec<Vec<usize>>,
    /// Active node count (≤ `adj.len()` after a shrinking `reinit`).
    nodes: usize,
    edges: Vec<Edge>,
    level: Vec<i32>,
    iter: Vec<usize>,
    /// Pooled BFS frontier.
    queue: std::collections::VecDeque<usize>,
}

impl Dinic {
    pub fn new(n: usize) -> Self {
        let mut d = Dinic::default();
        d.reinit(n);
        d
    }

    /// Clear the graph for reuse with `n` nodes, keeping all allocations.
    pub fn reinit(&mut self, n: usize) {
        self.edges.clear();
        for row in self.adj.iter_mut() {
            row.clear();
        }
        if self.adj.len() < n {
            self.adj.resize_with(n, Vec::new);
        }
        if self.level.len() < n {
            self.level.resize(n, -1);
        }
        if self.iter.len() < n {
            self.iter.resize(n, 0);
        }
        self.nodes = n;
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes
    }

    /// Reserved capacity across the internal arenas (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.adj.capacity()
            + self.adj.iter().map(|a| a.capacity()).sum::<usize>()
            + self.edges.capacity()
            + self.level.capacity()
            + self.iter.capacity()
            + self.queue.capacity()
    }

    /// Add a directed edge `u -> v` with capacity `cap`.
    pub fn add_edge(&mut self, u: usize, v: usize, cap: u64) -> EdgeRef {
        let id = self.edges.len();
        self.edges.push(Edge { to: v, cap, orig: cap });
        self.edges.push(Edge { to: u, cap: 0, orig: 0 });
        self.adj[u].push(id);
        self.adj[v].push(id + 1);
        EdgeRef(id)
    }

    /// Flow currently pushed through the edge (after `max_flow`).
    pub fn flow_of(&self, e: EdgeRef) -> u64 {
        let edge = &self.edges[e.0];
        edge.orig - edge.cap
    }

    /// Restore all residual capacities to their original values so another
    /// `max_flow` can be run on the same topology.
    pub fn reset(&mut self) {
        for e in self.edges.iter_mut() {
            e.cap = e.orig;
        }
    }

    /// Update the capacity of an existing edge (also clears its flow).
    /// Used by the feasibility oracle when re-trying a different Φ on the
    /// same bipartite topology.
    pub fn set_cap(&mut self, e: EdgeRef, cap: u64) {
        self.edges[e.0].cap = cap;
        self.edges[e.0].orig = cap;
        self.edges[e.0 ^ 1].cap = 0;
        self.edges[e.0 ^ 1].orig = 0;
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.iter_mut().for_each(|l| *l = -1);
        self.queue.clear();
        self.level[s] = 0;
        self.queue.push_back(s);
        while let Some(u) = self.queue.pop_front() {
            for &ei in &self.adj[u] {
                let e = &self.edges[ei];
                if e.cap > 0 && self.level[e.to] < 0 {
                    self.level[e.to] = self.level[u] + 1;
                    self.queue.push_back(e.to);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, u: usize, t: usize, limit: u64) -> u64 {
        if u == t {
            return limit;
        }
        while self.iter[u] < self.adj[u].len() {
            let ei = self.adj[u][self.iter[u]];
            let (to, cap) = {
                let e = &self.edges[ei];
                (e.to, e.cap)
            };
            if cap > 0 && self.level[to] == self.level[u] + 1 {
                let pushed = self.dfs(to, t, limit.min(cap));
                if pushed > 0 {
                    self.edges[ei].cap -= pushed;
                    self.edges[ei ^ 1].cap += pushed;
                    return pushed;
                }
            }
            self.iter[u] += 1;
        }
        0
    }

    /// Compute the maximum s–t flow.
    pub fn max_flow(&mut self, s: usize, t: usize) -> u64 {
        assert_ne!(s, t);
        let mut flow = 0;
        while self.bfs(s, t) {
            self.iter.iter_mut().for_each(|i| *i = 0);
            loop {
                let pushed = self.dfs(s, t, u64::MAX);
                if pushed == 0 {
                    break;
                }
                flow += pushed;
            }
        }
        flow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_diamond() {
        // s=0, t=3; two disjoint paths of cap 10 and 5, plus a cross edge.
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 10);
        d.add_edge(0, 2, 5);
        d.add_edge(1, 3, 10);
        d.add_edge(2, 3, 5);
        d.add_edge(1, 2, 15);
        assert_eq!(d.max_flow(0, 3), 15);
    }

    #[test]
    fn bottleneck_respected() {
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 100);
        d.add_edge(1, 2, 7);
        assert_eq!(d.max_flow(0, 2), 7);
    }

    #[test]
    fn disconnected_is_zero() {
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 5);
        d.add_edge(2, 3, 5);
        assert_eq!(d.max_flow(0, 3), 0);
    }

    #[test]
    fn flow_conservation_and_edge_flows() {
        let mut d = Dinic::new(5);
        let e1 = d.add_edge(0, 1, 4);
        let e2 = d.add_edge(0, 2, 3);
        let e3 = d.add_edge(1, 3, 2);
        let e4 = d.add_edge(1, 4, 9);
        let e5 = d.add_edge(2, 4, 9);
        let e6 = d.add_edge(3, 4, 9);
        let f = d.max_flow(0, 4);
        assert_eq!(f, 7);
        // Conservation at node 1: in == out.
        assert_eq!(d.flow_of(e1), d.flow_of(e3) + d.flow_of(e4));
        // Conservation at node 2 / 3.
        assert_eq!(d.flow_of(e2), d.flow_of(e5));
        assert_eq!(d.flow_of(e3), d.flow_of(e6));
        // Source outflow equals total.
        assert_eq!(d.flow_of(e1) + d.flow_of(e2), f);
        // Capacity respected.
        assert!(d.flow_of(e3) <= 2);
    }

    #[test]
    fn reset_allows_rerun() {
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 6);
        d.add_edge(1, 2, 6);
        assert_eq!(d.max_flow(0, 2), 6);
        d.reset();
        assert_eq!(d.max_flow(0, 2), 6);
    }

    #[test]
    fn set_cap_changes_feasibility() {
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 10);
        let sink_edge = d.add_edge(1, 2, 0);
        assert_eq!(d.max_flow(0, 2), 0);
        d.reset();
        d.set_cap(sink_edge, 4);
        assert_eq!(d.max_flow(0, 2), 4);
    }

    #[test]
    fn parallel_edges_sum() {
        let mut d = Dinic::new(2);
        d.add_edge(0, 1, 3);
        d.add_edge(0, 1, 4);
        assert_eq!(d.max_flow(0, 1), 7);
    }

    #[test]
    fn reinit_rebuilds_without_growth() {
        let mut d = Dinic::new(4);
        d.add_edge(0, 1, 10);
        d.add_edge(1, 3, 10);
        assert_eq!(d.max_flow(0, 3), 10);
        let fp = d.footprint();
        // Same-shape rebuild: capacities must not grow.
        for _ in 0..3 {
            d.reinit(4);
            d.add_edge(0, 1, 7);
            d.add_edge(1, 3, 9);
            assert_eq!(d.max_flow(0, 3), 7);
            assert_eq!(d.footprint(), fp, "reinit must reuse arenas");
        }
        // Shrinking keeps the larger arenas alive.
        d.reinit(2);
        assert_eq!(d.num_nodes(), 2);
        d.add_edge(0, 1, 3);
        assert_eq!(d.max_flow(0, 1), 3);
        assert_eq!(d.footprint(), fp);
    }

    #[test]
    fn zero_capacity_edges_ignored() {
        let mut d = Dinic::new(3);
        d.add_edge(0, 1, 0);
        d.add_edge(1, 2, 10);
        assert_eq!(d.max_flow(0, 2), 0);
    }
}
