//! Max-flow substrate.
//!
//! The paper solves its assignment program `P` (eq. 4) with CPLEX. At a
//! fixed candidate completion time Φ, `P` reduces to a bipartite
//! *transportation feasibility* problem: can every task group push all its
//! tasks through servers whose remaining capacity is `max{Φ − b_m, 0}·μ_m`?
//! That is exactly a max-flow instance, and flow integrality yields the
//! integer slot counts `n_m^k` the program asks for. This module provides
//! the Dinic solver used by [`crate::assign::feasible`], plus a brute-force
//! checker used by the property tests.

mod dinic;

pub use dinic::{Dinic, EdgeRef};

#[cfg(test)]
mod brute {
    //! Exponential-time max-flow via augmenting-path DFS used only to
    //! cross-check Dinic on tiny graphs in tests.

    pub fn max_flow_brute(
        n: usize,
        edges: &[(usize, usize, u64)],
        s: usize,
        t: usize,
    ) -> u64 {
        // Build residual adjacency matrix (sums parallel edges).
        let mut cap = vec![vec![0u64; n]; n];
        for &(u, v, c) in edges {
            cap[u][v] += c;
        }
        let mut total = 0;
        loop {
            // BFS for any augmenting path.
            let mut parent = vec![usize::MAX; n];
            parent[s] = s;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                for v in 0..n {
                    if parent[v] == usize::MAX && cap[u][v] > 0 {
                        parent[v] = u;
                        queue.push_back(v);
                    }
                }
            }
            if parent[t] == usize::MAX {
                return total;
            }
            // Find bottleneck.
            let mut bottleneck = u64::MAX;
            let mut v = t;
            while v != s {
                let u = parent[v];
                bottleneck = bottleneck.min(cap[u][v]);
                v = u;
            }
            let mut v = t;
            while v != s {
                let u = parent[v];
                cap[u][v] -= bottleneck;
                cap[v][u] += bottleneck;
                v = u;
            }
            total += bottleneck;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::brute::max_flow_brute;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dinic_matches_brute_on_random_graphs() {
        let mut rng = Rng::seed_from(100);
        for case in 0..60 {
            let n = 2 + rng.gen_range(6) as usize; // 2..=7 nodes
            let m = rng.gen_range(12) as usize;
            let mut edges = vec![];
            for _ in 0..m {
                let u = rng.gen_range(n as u64) as usize;
                let v = rng.gen_range(n as u64) as usize;
                if u != v {
                    edges.push((u, v, rng.gen_range_incl(0, 10)));
                }
            }
            let s = 0;
            let t = n - 1;
            let expected = max_flow_brute(n, &edges, s, t);
            let mut d = Dinic::new(n);
            for &(u, v, c) in &edges {
                d.add_edge(u, v, c);
            }
            assert_eq!(d.max_flow(s, t), expected, "case {case}: edges {edges:?}");
        }
    }
}
