//! The job model (paper §II): jobs composed of independent tasks; tasks
//! partitioned into *task groups* by their available-server sets (eq. 3).

pub mod groups;

/// Index of a server, `0..M`.
pub type ServerId = usize;
/// A count of tasks.
pub type TaskCount = u64;
/// A duration / point in slotted time.
pub type Slots = u64;

/// One task group `T_c^k`: `size` tasks, each runnable on any server in
/// `servers` (the group's available-server set `S_c^k`, sorted, deduped).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskGroup {
    pub size: TaskCount,
    pub servers: Vec<ServerId>,
    /// The replica-holding subset of `servers` (sorted, deduped). `None`
    /// means every server in `servers` holds a replica — the flat model,
    /// where availability and locality coincide. The DES topology
    /// expansion widens `servers` to the whole eligible set and records
    /// the pre-expansion holders here so affinity-aware assigners
    /// (delay, jsq-affinity, maxweight) can still tell local from remote.
    pub local: Option<Vec<ServerId>>,
}

impl TaskGroup {
    pub fn new(size: TaskCount, mut servers: Vec<ServerId>) -> Self {
        servers.sort_unstable();
        servers.dedup();
        assert!(!servers.is_empty(), "task group with no available servers");
        TaskGroup {
            size,
            servers,
            local: None,
        }
    }

    /// A group whose eligible set `servers` is wider than its
    /// replica-holder set `local` (the topology-expanded view).
    pub fn with_local(size: TaskCount, servers: Vec<ServerId>, mut local: Vec<ServerId>) -> Self {
        let mut g = TaskGroup::new(size, servers);
        local.sort_unstable();
        local.dedup();
        debug_assert!(
            local.iter().all(|s| g.servers.contains(s)),
            "holder set must be a subset of the eligible set"
        );
        assert!(!local.is_empty(), "task group with no replica holders");
        g.local = Some(local);
        g
    }

    /// The servers holding a data replica for this group: `local` when
    /// the group was topology-expanded, else the full available set.
    pub fn holders(&self) -> &[ServerId] {
        self.local.as_deref().unwrap_or(&self.servers)
    }
}

/// A fully materialized job instance: arrival time, task groups with their
/// available servers, and the profiled per-server capacity `μ_m^c` for
/// this job (tasks per slot; same for every task of the job, per §II).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: usize,
    /// Absolute arrival slot.
    pub arrival: Slots,
    pub groups: Vec<TaskGroup>,
    /// `mu[m]` = μ_m^c for every server m (length M).
    pub mu: Vec<u64>,
}

impl Job {
    /// Total number of tasks |T_c|.
    pub fn total_tasks(&self) -> TaskCount {
        self.groups.iter().map(|g| g.size).sum()
    }

    /// Number of task groups K_c.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Union of available servers over all groups, sorted.
    pub fn available_servers(&self) -> Vec<ServerId> {
        let mut all: Vec<ServerId> = self
            .groups
            .iter()
            .flat_map(|g| g.servers.iter().copied())
            .collect();
        all.sort_unstable();
        all.dedup();
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job {
            id: 0,
            arrival: 5,
            groups: vec![
                TaskGroup::new(10, vec![2, 0, 1]),
                TaskGroup::new(4, vec![1, 3]),
            ],
            mu: vec![3, 3, 3, 3],
        }
    }

    #[test]
    fn group_sorts_and_dedups_servers() {
        let g = TaskGroup::new(5, vec![3, 1, 3, 2]);
        assert_eq!(g.servers, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "no available servers")]
    fn group_requires_servers() {
        TaskGroup::new(1, vec![]);
    }

    #[test]
    fn job_totals() {
        let j = job();
        assert_eq!(j.total_tasks(), 14);
        assert_eq!(j.num_groups(), 2);
        assert_eq!(j.available_servers(), vec![0, 1, 2, 3]);
    }
}
