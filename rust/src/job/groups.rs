//! Task-group derivation (eq. 3): given per-task available-server sets,
//! partition the tasks so that each group contains exactly the tasks that
//! share one available-server set.
//!
//! The trace-driven experiments take groups directly from trace entries
//! (paper §V-A), but callers constructing jobs from raw per-task chunk
//! placements (e.g. the live coordinator) use this derivation.

use std::collections::HashMap;

use super::{ServerId, TaskGroup};

/// Partition tasks by identical available-server sets.
///
/// `task_servers[i]` is the available-server set of task `i` (order and
/// duplicates are irrelevant). Returns groups in first-seen order.
pub fn derive_groups(task_servers: &[Vec<ServerId>]) -> Vec<TaskGroup> {
    let mut index: HashMap<Vec<ServerId>, usize> = HashMap::new();
    let mut groups: Vec<TaskGroup> = Vec::new();
    for servers in task_servers {
        let mut key = servers.clone();
        key.sort_unstable();
        key.dedup();
        assert!(!key.is_empty(), "task with no available servers");
        match index.get(&key) {
            Some(&gi) => groups[gi].size += 1,
            None => {
                index.insert(key.clone(), groups.len());
                groups.push(TaskGroup {
                    size: 1,
                    servers: key,
                    local: None,
                });
            }
        }
    }
    groups
}

/// Merge groups that share an identical available-server set (used to
/// canonicalize trace-derived groups, where distinct trace entries may
/// carry the same set).
pub fn merge_identical(groups: &[TaskGroup]) -> Vec<TaskGroup> {
    let mut index: HashMap<Vec<ServerId>, usize> = HashMap::new();
    let mut out: Vec<TaskGroup> = Vec::new();
    for g in groups {
        match index.get(&g.servers) {
            Some(&gi) => out[gi].size += g.size,
            None => {
                index.insert(g.servers.clone(), out.len());
                out.push(g.clone());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_identical_sets() {
        let tasks = vec![
            vec![1, 2, 3],
            vec![3, 2, 1], // same set, different order
            vec![1, 2],
            vec![1, 2, 3],
        ];
        let groups = derive_groups(&tasks);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].size, 3);
        assert_eq!(groups[0].servers, vec![1, 2, 3]);
        assert_eq!(groups[1].size, 1);
        assert_eq!(groups[1].servers, vec![1, 2]);
    }

    #[test]
    fn duplicate_servers_within_task_deduped() {
        let groups = derive_groups(&[vec![5, 5, 2]]);
        assert_eq!(groups[0].servers, vec![2, 5]);
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(derive_groups(&[]).is_empty());
    }

    #[test]
    fn merge_identical_sums_sizes() {
        let gs = vec![
            TaskGroup::new(3, vec![0, 1]),
            TaskGroup::new(2, vec![2]),
            TaskGroup::new(5, vec![0, 1]),
        ];
        let merged = merge_identical(&gs);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].size, 8);
        assert_eq!(merged[1].size, 2);
    }
}
