//! The live leader: ingests jobs, derives task groups from chunk
//! placement, assigns tasks with a paper algorithm against live
//! queue-depth estimates, and drives worker threads that execute each
//! task's chunk payload through the accelerator service.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::assign::{AssignPolicy, Assigner, Instance};
use crate::cluster::Cluster;
use crate::job::groups::derive_groups;
use crate::job::ServerId;
use crate::util::stats::Summary;
use crate::{Error, Result};

use super::accel::AccelHandle;

/// A job submitted to the live coordinator: tasks identified by the data
/// chunk they read.
#[derive(Clone, Debug)]
pub struct LiveJobSpec {
    pub id: usize,
    /// Chunk id per task; the task may run on any server holding a
    /// replica of its chunk.
    pub chunk_ids: Vec<u64>,
}

/// Outcome of a live run.
#[derive(Clone, Debug)]
pub struct LiveReport {
    /// Per-job wall-clock latency.
    pub latencies: Vec<Duration>,
    /// Total tasks executed.
    pub tasks: u64,
    /// End-to-end wall-clock of the whole run.
    pub elapsed: Duration,
    /// Sum of all per-task payload outputs (a checksum proving the real
    /// kernel ran).
    pub checksum: f64,
}

impl LiveReport {
    pub fn throughput_tps(&self) -> f64 {
        self.tasks as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn latency_summary(&self) -> Summary {
        let xs: Vec<f64> = self
            .latencies
            .iter()
            .map(|d| d.as_secs_f64() * 1e3)
            .collect();
        Summary::from(&xs)
    }
}

struct TaskMsg {
    chunk_id: u64,
}

/// The live coordinator.
pub struct Leader {
    cluster: Cluster,
    accel: Arc<AccelHandle>,
    replicas: usize,
    workers: Vec<Sender<TaskMsg>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
    /// Tasks queued per worker (live queue-depth estimate).
    depths: Arc<Vec<AtomicU64>>,
    done_count: Arc<AtomicU64>,
    checksum_bits: Arc<AtomicU64>,
}

impl Leader {
    /// Start workers (one per server). `accel` must outlive the leader.
    pub fn start(cluster: Cluster, accel: Arc<AccelHandle>, replicas: usize) -> Result<Leader> {
        let m = cluster.num_servers();
        let depths: Arc<Vec<AtomicU64>> = Arc::new((0..m).map(|_| AtomicU64::new(0)).collect());
        let done_count = Arc::new(AtomicU64::new(0));
        let checksum_bits = Arc::new(AtomicU64::new(0f64.to_bits()));
        let d = accel.payload_d;
        let mut workers = Vec::with_capacity(m);
        let mut worker_joins = Vec::with_capacity(m);
        for w in 0..m {
            let (tx, rx) = channel::<TaskMsg>();
            let accel = Arc::clone(&accel);
            let depths = Arc::clone(&depths);
            let done = Arc::clone(&done_count);
            let csum = Arc::clone(&checksum_bits);
            let join = std::thread::Builder::new()
                .name(format!("taos-worker-{w}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        // Materialize the chunk deterministically from its
                        // id (stand-in for reading a real data chunk).
                        let row: Vec<f32> = (0..d)
                            .map(|i| {
                                let x = task
                                    .chunk_id
                                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                                    .wrapping_add(i as u64);
                                ((x >> 40) as f32 / 16_777_216.0) - 0.5
                            })
                            .collect();
                        match accel.payload(row) {
                            Ok(y) => {
                                // Accumulate the checksum (CAS loop over
                                // f64 bits).
                                let mut cur = csum.load(Ordering::Relaxed);
                                loop {
                                    let new = (f64::from_bits(cur) + y as f64).to_bits();
                                    match csum.compare_exchange_weak(
                                        cur,
                                        new,
                                        Ordering::Relaxed,
                                        Ordering::Relaxed,
                                    ) {
                                        Ok(_) => break,
                                        Err(c) => cur = c,
                                    }
                                }
                            }
                            Err(_) => { /* counted as done; errors surface via checksum */ }
                        }
                        depths[w].fetch_sub(1, Ordering::Relaxed);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .map_err(|e| Error::Runtime(format!("spawn worker {w}: {e}")))?;
            workers.push(tx);
            worker_joins.push(join);
        }
        Ok(Leader {
            cluster,
            accel,
            replicas,
            workers,
            worker_joins,
            depths,
            done_count,
            checksum_bits,
        })
    }

    /// Assign and dispatch one job; returns the per-server task counts.
    pub fn submit(&self, spec: &LiveJobSpec, policy: AssignPolicy) -> Result<Vec<(ServerId, u64)>> {
        // Task groups from chunk placement (eq. 3 derivation).
        let task_servers: Vec<Vec<ServerId>> = spec
            .chunk_ids
            .iter()
            .map(|&c| self.cluster.chunk_holders(c, self.replicas))
            .collect();
        let groups = derive_groups(&task_servers);
        // Live busy estimate: queue depth / μ (μ = 1 task/slot per worker
        // in live mode — the accelerator batch is the real capacity).
        let m = self.cluster.num_servers();
        let busy: Vec<u64> = (0..m)
            .map(|w| self.depths[w].load(Ordering::Relaxed))
            .collect();
        let mu = vec![1u64; m];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let assignment = policy.build(spec.id as u64).assign(&inst);

        // Dispatch: round-robin the group's actual chunk ids over its
        // allocated servers.
        let mut per_server: std::collections::BTreeMap<ServerId, u64> = Default::default();
        // Bucket chunk ids by group.
        let mut group_chunks: Vec<Vec<u64>> = vec![Vec::new(); groups.len()];
        {
            // derive_groups assigns tasks to groups in first-seen order;
            // recompute the mapping.
            let mut index: std::collections::HashMap<Vec<ServerId>, usize> = Default::default();
            let mut next = 0;
            for (t, servers) in task_servers.iter().enumerate() {
                let mut key = servers.clone();
                key.sort_unstable();
                key.dedup();
                let gi = *index.entry(key).or_insert_with(|| {
                    let g = next;
                    next += 1;
                    g
                });
                group_chunks[gi].push(spec.chunk_ids[t]);
            }
        }
        for (gi, alloc) in assignment.per_group.iter().enumerate() {
            let chunks = &group_chunks[gi];
            let mut cursor = 0usize;
            for &(server, count) in alloc {
                for _ in 0..count {
                    let chunk_id = chunks[cursor];
                    cursor += 1;
                    self.depths[server].fetch_add(1, Ordering::Relaxed);
                    self.workers[server]
                        .send(TaskMsg { chunk_id })
                        .map_err(|_| Error::Runtime(format!("worker {server} gone")))?;
                    *per_server.entry(server).or_insert(0) += 1;
                }
            }
            debug_assert_eq!(cursor, chunks.len(), "all chunks dispatched");
        }
        Ok(per_server.into_iter().collect())
    }

    /// Submit a stream of jobs and wait for completion of each before
    /// reporting its latency (jobs run concurrently across workers).
    pub fn run_jobs(&self, specs: &[LiveJobSpec], policy: AssignPolicy) -> Result<LiveReport> {
        let t0 = Instant::now();
        let mut latencies = Vec::with_capacity(specs.len());
        let mut tasks = 0u64;
        for spec in specs {
            let j0 = Instant::now();
            let before = self.done_count.load(Ordering::Relaxed);
            let submitted: u64 = self
                .submit(spec, policy)?
                .iter()
                .map(|&(_, n)| n)
                .sum();
            tasks += submitted;
            // Wait for this job's tasks to drain (simple completion wait;
            // batching across jobs still happens inside the accelerator).
            let target = before + submitted;
            while self.done_count.load(Ordering::Relaxed) < target {
                std::thread::yield_now();
            }
            latencies.push(j0.elapsed());
        }
        Ok(LiveReport {
            latencies,
            tasks,
            elapsed: t0.elapsed(),
            checksum: f64::from_bits(self.checksum_bits.load(Ordering::Relaxed)),
        })
    }

    /// Stop all workers and join them.
    pub fn shutdown(mut self) {
        self.workers.clear(); // closes channels
        for j in self.worker_joins.drain(..) {
            let _ = j.join();
        }
    }

    pub fn accel(&self) -> &AccelHandle {
        &self.accel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_spec_shape() {
        let spec = LiveJobSpec {
            id: 1,
            chunk_ids: vec![1, 2, 3],
        };
        assert_eq!(spec.chunk_ids.len(), 3);
    }

    #[test]
    fn report_math() {
        let r = LiveReport {
            latencies: vec![Duration::from_millis(10), Duration::from_millis(30)],
            tasks: 100,
            elapsed: Duration::from_secs(2),
            checksum: 1.5,
        };
        assert!((r.throughput_tps() - 50.0).abs() < 1e-9);
        let s = r.latency_summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 20.0).abs() < 1e-9);
    }
}
