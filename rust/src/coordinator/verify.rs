//! Cross-layer verification: the AOT-compiled Pallas water-filling kernel
//! (L1/L2) must agree exactly with the native rust WF (L3) — same water
//! levels, same estimated completion times, same final busy vectors.
//! Exercised by `taos verify-kernel` and the `runtime_kernel` integration
//! test.

use std::path::Path;
use std::sync::Arc;

use crate::assign::wf::Wf;
use crate::assign::Instance;
use crate::job::TaskGroup;
use crate::util::rng::Rng;
use crate::{Error, Result};

use super::accel::{AccelHandle, WfPhiInput};

/// An instance padded into one row of the batched kernel input.
pub struct PaddedInstance {
    pub groups: Vec<TaskGroup>,
    pub mu: Vec<u64>,
    pub busy: Vec<u64>,
}

/// Generate a random instance that fits in (K, M) after padding.
pub fn random_padded(rng: &mut Rng, k_max: usize, m_max: usize) -> PaddedInstance {
    let m = 1 + rng.gen_range(m_max as u64) as usize;
    let k = 1 + rng.gen_range(k_max as u64) as usize;
    let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(1, 5)).collect();
    let busy: Vec<u64> = (0..m).map(|_| rng.gen_range(30)).collect();
    let groups: Vec<TaskGroup> = (0..k)
        .map(|_| {
            let ns = 1 + rng.gen_range(m as u64) as usize;
            let mut sv: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut sv);
            sv.truncate(ns);
            TaskGroup::new(rng.gen_range_incl(1, 60), sv)
        })
        .collect();
    PaddedInstance { groups, mu, busy }
}

/// Pack a slice of instances (each with ≤ K groups, ≤ M servers) into one
/// batched kernel input of static shape (B, K, M). Unused batch rows get
/// all-zero sizes (the kernel treats them as no-ops).
pub fn pack_batch(
    instances: &[PaddedInstance],
    b: usize,
    k: usize,
    m: usize,
) -> Result<WfPhiInput> {
    if instances.len() > b {
        return Err(Error::Runtime(format!(
            "{} instances exceed batch {b}",
            instances.len()
        )));
    }
    let mut busy = vec![0i32; b * m];
    let mut mu = vec![1i32; b * m]; // μ ≥ 1 keeps padded servers harmless
    let mut sizes = vec![0i32; b * k];
    let mut avail = vec![0i32; b * k * m];
    for (row, inst) in instances.iter().enumerate() {
        if inst.groups.len() > k || inst.mu.len() > m {
            return Err(Error::Runtime("instance exceeds kernel shape".into()));
        }
        for (j, &x) in inst.busy.iter().enumerate() {
            busy[row * m + j] = x as i32;
        }
        for (j, &x) in inst.mu.iter().enumerate() {
            mu[row * m + j] = x as i32;
        }
        for (g, group) in inst.groups.iter().enumerate() {
            sizes[row * k + g] = group.size as i32;
            for &s in &group.servers {
                avail[row * k * m + g * m + s] = 1;
            }
        }
    }
    Ok(WfPhiInput {
        busy,
        mu,
        sizes,
        avail,
    })
}

/// Verify `cases` random instances against the native WF. Returns
/// (instances checked, batch size used). Errors on any mismatch.
pub fn verify_wf_kernel(artifacts: &Path, cases: usize, seed: u64) -> Result<(usize, usize)> {
    let accel = Arc::new(AccelHandle::spawn(artifacts)?);
    let (b, k, m) = (accel.wf_b, accel.wf_k, accel.wf_m);
    let mut rng = Rng::seed_from(seed);
    let mut checked = 0;
    while checked < cases {
        let n = b.min(cases - checked);
        let instances: Vec<PaddedInstance> = (0..n)
            .map(|_| random_padded(&mut rng, k.min(6), m.min(12)))
            .collect();
        let input = pack_batch(&instances, b, k, m)?;
        let (phi, busy_out) = accel.wf_phi(input)?;
        for (row, inst) in instances.iter().enumerate() {
            let view = Instance {
                groups: &inst.groups,
                mu: &inst.mu,
                busy: &inst.busy,
            };
            let (a, native_busy) = Wf::new().assign_with_busy(&view);
            if phi[row] as u64 != a.phi {
                return Err(Error::Runtime(format!(
                    "phi mismatch on row {row}: kernel {} vs native {} ({inst:?})",
                    phi[row],
                    a.phi,
                    inst = inst.groups
                )));
            }
            for (j, &nb) in native_busy.iter().enumerate() {
                let kb = busy_out[row * m + j] as u64;
                if kb != nb {
                    return Err(Error::Runtime(format!(
                        "busy mismatch row {row} server {j}: kernel {kb} vs native {nb}"
                    )));
                }
            }
        }
        checked += n;
    }
    let _ = Arc::try_unwrap(accel).map(|a| a.shutdown());
    Ok((checked, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_batch_layout() {
        let inst = PaddedInstance {
            groups: vec![TaskGroup::new(5, vec![0, 2])],
            mu: vec![3, 4, 5],
            busy: vec![7, 0, 1],
        };
        let input = pack_batch(&[inst], 2, 2, 4).unwrap();
        // Row 0.
        assert_eq!(&input.busy[..4], &[7, 0, 1, 0]);
        assert_eq!(&input.mu[..4], &[3, 4, 5, 1]);
        assert_eq!(&input.sizes[..2], &[5, 0]);
        assert_eq!(&input.avail[..4], &[1, 0, 1, 0]);
        // Row 1 fully padded.
        assert!(input.sizes[2..].iter().all(|&s| s == 0));
        assert!(input.busy[4..].iter().all(|&x| x == 0));
    }

    #[test]
    fn pack_batch_rejects_overflow() {
        let inst = PaddedInstance {
            groups: vec![TaskGroup::new(1, vec![0])],
            mu: vec![1; 10],
            busy: vec![0; 10],
        };
        assert!(pack_batch(&[inst], 1, 1, 4).is_err());
    }
}
