//! OCWF candidate evaluation offloaded to the AOT water-filling kernel.
//!
//! The reordering round of §IV evaluates the estimated completion time Φ
//! of every not-yet-placed outstanding job against the current busy
//! vector — a batch of independent WF evaluations, which is exactly the
//! shape of the L1 Pallas kernel (`python/compile/kernels/waterfill.py`).
//! This module packs a reorder round into `(B, K, M)` kernel batches,
//! runs them through the accelerator service, and rebuilds the same
//! shortest-estimated-time-first order the native driver produces.
//!
//! The offloaded driver returns the *order* and per-step Φ values; the
//! task allocations are then materialized natively (the kernel computes
//! levels and busy vectors, not per-server task splits — allocation
//! extraction is cheap and stays on the CPU side). Equality with the
//! native [`crate::sched::ocwf::reorder`] is asserted in the
//! `runtime_kernel` integration suite.

use std::sync::Arc;

use crate::assign::wf::Wf;
use crate::assign::Instance;
use crate::job::{Slots, TaskGroup};
use crate::sched::ocwf::{reorder, Outstanding, ReorderOutcome};
use crate::{Error, Result};

use super::accel::{AccelHandle, WfPhiInput};

/// A reorder driver that evaluates candidate Φ values on the accelerator.
pub struct OffloadedReorder {
    accel: Arc<AccelHandle>,
}

impl OffloadedReorder {
    pub fn new(accel: Arc<AccelHandle>) -> Self {
        OffloadedReorder { accel }
    }

    /// Check that every outstanding job fits the kernel's static (K, M)
    /// shape.
    pub fn fits(&self, outstanding: &[Outstanding], num_servers: usize) -> bool {
        num_servers <= self.accel.wf_m
            && outstanding
                .iter()
                .all(|o| o.job.groups.len() <= self.accel.wf_k)
    }

    /// Evaluate Φ for every candidate in one (or a few) kernel calls.
    /// `busy` is the current per-server busy vector of the round.
    fn phi_batch(
        &self,
        cands: &[&Outstanding],
        busy: &[Slots],
        num_servers: usize,
    ) -> Result<Vec<Slots>> {
        let (b, k, m) = (self.accel.wf_b, self.accel.wf_k, self.accel.wf_m);
        let mut phis = Vec::with_capacity(cands.len());
        for chunk in cands.chunks(b) {
            let mut in_busy = vec![0i32; b * m];
            let mut in_mu = vec![1i32; b * m];
            let mut in_sizes = vec![0i32; b * k];
            let mut in_avail = vec![0i32; b * k * m];
            for (row, o) in chunk.iter().enumerate() {
                for s in 0..num_servers {
                    in_busy[row * m + s] = busy[s] as i32;
                    in_mu[row * m + s] = o.job.mu[s] as i32;
                }
                for (g, (group, &rem)) in
                    o.job.groups.iter().zip(&o.remaining).enumerate()
                {
                    in_sizes[row * k + g] = rem as i32;
                    if rem > 0 {
                        for &s in &group.servers {
                            in_avail[row * k * m + g * m + s] = 1;
                        }
                    }
                }
            }
            let (phi, _busy_out) = self.accel.wf_phi(WfPhiInput {
                busy: in_busy,
                mu: in_mu,
                sizes: in_sizes,
                avail: in_avail,
            })?;
            phis.extend(chunk.iter().enumerate().map(|(row, _)| phi[row] as Slots));
        }
        Ok(phis)
    }

    /// Run one full reordering with kernel-evaluated candidates. Produces
    /// the identical order/assignments as the native OCWF driver (the
    /// kernel and native WF are bit-equivalent).
    pub fn reorder(
        &self,
        outstanding: &[Outstanding],
        num_servers: usize,
    ) -> Result<ReorderOutcome> {
        if !self.fits(outstanding, num_servers) {
            return Err(Error::Runtime(format!(
                "outstanding set exceeds kernel shape (K ≤ {}, M ≤ {})",
                self.accel.wf_k, self.accel.wf_m
            )));
        }
        let n = outstanding.len();
        let mut busy: Vec<Slots> = vec![0; num_servers];
        let mut placed = vec![false; n];
        let mut order = Vec::with_capacity(n);
        let mut assignments = Vec::with_capacity(n);
        let mut wf = Wf::new();
        let mut wf_evals = 0u64;

        for _ in 0..n {
            let cands: Vec<usize> = (0..n).filter(|&i| !placed[i]).collect();
            let cand_refs: Vec<&Outstanding> = cands.iter().map(|&i| &outstanding[i]).collect();
            // One PJRT call evaluates the whole candidate set.
            let phis = self.phi_batch(&cand_refs, &busy, num_servers)?;
            wf_evals += cands.len() as u64;
            // Winner: minimal (Φ, arrival index) — the OCWF tie rule.
            let (&winner, &phi) = cands
                .iter()
                .zip(&phis)
                .min_by_key(|(&i, &p)| (p, i))
                .expect("non-empty candidate set");
            let _ = phi;
            // Materialize the winner's allocation natively and advance the
            // busy vector.
            let groups: Vec<TaskGroup> = outstanding[winner]
                .job
                .groups
                .iter()
                .zip(&outstanding[winner].remaining)
                .map(|(g, &r)| TaskGroup {
                    size: r,
                    servers: g.servers.clone(),
                    local: None,
                })
                .collect();
            let inst = Instance {
                groups: &groups,
                mu: &outstanding[winner].job.mu,
                busy: &busy,
            };
            let (a, final_busy) = wf.assign_with_busy(&inst);
            debug_assert_eq!(a.phi, phis[cands.iter().position(|&i| i == winner).unwrap()]);
            placed[winner] = true;
            order.push(winner);
            assignments.push(a);
            busy = final_busy;
        }
        Ok(ReorderOutcome {
            order,
            assignments,
            wf_evals,
        })
    }
}

/// Convenience for tests: native reorder result for comparison.
pub fn native_reorder(outstanding: &[Outstanding], num_servers: usize) -> ReorderOutcome {
    reorder(outstanding, num_servers, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Job;

    #[test]
    fn fits_checks_shapes() {
        // A handle cannot be spawned without artifacts in unit tests; the
        // shape logic is exercised via a stub-free path in the
        // runtime_kernel integration suite. Here: sanity of the
        // Outstanding plumbing only.
        let job = Job {
            id: 0,
            arrival: 0,
            groups: vec![TaskGroup::new(3, vec![0, 1])],
            mu: vec![1, 1],
        };
        let o = Outstanding {
            job: &job,
            remaining: vec![3],
        };
        assert_eq!(o.total_remaining(), 3);
    }
}
