//! The accelerator service: one thread owning the PJRT client and the
//! compiled artifacts, serving batched execution requests over channels.
//!
//! PJRT handles are not `Send`, so all execution funnels through this
//! thread — the same shape as a serving engine's single accelerator
//! stream. Payload requests are *coalesced*: whatever is queued when the
//! thread becomes free is packed into one padded batch per HLO call, up
//! to the artifact's static batch size.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::runtime::{ArtifactIndex, Executable, PjrtRuntime};
use crate::{Error, Result};

/// Inputs for one batched water-filling evaluation (row-major, padded by
/// the caller to the artifact's B/K/M).
#[derive(Clone, Debug)]
pub struct WfPhiInput {
    pub busy: Vec<i32>,
    pub mu: Vec<i32>,
    pub sizes: Vec<i32>,
    pub avail: Vec<i32>,
}

enum Request {
    Payload {
        /// One row of the payload batch (length D).
        row: Vec<f32>,
        resp: Sender<Result<f32>>,
    },
    WfPhi {
        input: WfPhiInput,
        resp: Sender<Result<(Vec<i32>, Vec<i32>)>>,
    },
    Shutdown,
}

/// Handle to the accelerator thread. Cloneable; dropping the last clone
/// does not stop the thread — call [`AccelHandle::shutdown`].
pub struct AccelHandle {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
    /// Payload artifact static shapes.
    pub payload_n: usize,
    pub payload_d: usize,
    /// WF artifact static shapes.
    pub wf_b: usize,
    pub wf_k: usize,
    pub wf_m: usize,
}

impl AccelHandle {
    /// Spawn the service: compiles `payload` and `wf_phi` artifacts from
    /// the manifest in `artifacts_dir`.
    pub fn spawn(artifacts_dir: &Path) -> Result<AccelHandle> {
        let index = ArtifactIndex::load(artifacts_dir)?;
        let payload_n = index.param("payload", "N")? as usize;
        let payload_d = index.param("payload", "D")? as usize;
        let wf_b = index.param("wf_phi", "B")? as usize;
        let wf_k = index.param("wf_phi", "K")? as usize;
        let wf_m = index.param("wf_phi", "M")? as usize;
        let payload_path = index.path_of("payload")?;
        let wf_path = index.path_of("wf_phi")?;

        let (tx, rx) = channel::<Request>();
        // Compile on the service thread (PJRT handles stay there); report
        // startup errors back through a one-shot channel.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("taos-accel".into())
            .spawn(move || {
                let startup = (|| -> Result<(PjrtRuntime, Executable, Executable)> {
                    let rt = PjrtRuntime::cpu()?;
                    let payload = rt.load_hlo_text(&payload_path)?;
                    let wf = rt.load_hlo_text(&wf_path)?;
                    Ok((rt, payload, wf))
                })();
                match startup {
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                    Ok((_rt, payload, wf)) => {
                        let _ = ready_tx.send(Ok(()));
                        serve(rx, payload, wf, payload_n, payload_d, wf_b, wf_k, wf_m);
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn accel thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("accel thread died during startup".into()))??;
        Ok(AccelHandle {
            tx,
            join: Some(join),
            payload_n,
            payload_d,
            wf_b,
            wf_k,
            wf_m,
        })
    }

    /// Execute the payload kernel on one task's chunk row; blocks until
    /// the (possibly coalesced) batch completes.
    pub fn payload(&self, row: Vec<f32>) -> Result<f32> {
        if row.len() != self.payload_d {
            return Err(Error::Runtime(format!(
                "payload row length {} != D {}",
                row.len(),
                self.payload_d
            )));
        }
        let (resp, rx) = channel();
        self.tx
            .send(Request::Payload { row, resp })
            .map_err(|_| Error::Runtime("accel thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("accel dropped response".into()))?
    }

    /// Run the batched WF evaluator; returns (phi[B], busy_out[B·M]).
    pub fn wf_phi(&self, input: WfPhiInput) -> Result<(Vec<i32>, Vec<i32>)> {
        let (b, k, m) = (self.wf_b, self.wf_k, self.wf_m);
        if input.busy.len() != b * m
            || input.mu.len() != b * m
            || input.sizes.len() != b * k
            || input.avail.len() != b * k * m
        {
            return Err(Error::Runtime("wf_phi input shape mismatch".into()));
        }
        let (resp, rx) = channel();
        self.tx
            .send(Request::WfPhi { input, resp })
            .map_err(|_| Error::Runtime("accel thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("accel dropped response".into()))?
    }

    /// Stop the service thread and wait for it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve(
    rx: Receiver<Request>,
    payload: Executable,
    wf: Executable,
    n: usize,
    d: usize,
    _b: usize,
    _k: usize,
    _m: usize,
) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        match first {
            Request::Shutdown => return,
            Request::WfPhi { input, resp } => {
                let out = run_wf(&wf, &input);
                let _ = resp.send(out);
            }
            Request::Payload { row, resp } => {
                // Coalesce whatever else is already queued (payload only).
                let mut rows = vec![row];
                let mut resps = vec![resp];
                let mut deferred = Vec::new();
                while rows.len() < n {
                    match rx.try_recv() {
                        Ok(Request::Payload { row, resp }) => {
                            rows.push(row);
                            resps.push(resp);
                        }
                        Ok(other) => {
                            deferred.push(other);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                let used = rows.len();
                // Pad to the static batch.
                let mut flat = Vec::with_capacity(n * d);
                for r in &rows {
                    flat.extend_from_slice(r);
                }
                flat.resize(n * d, 0.0);
                let out = payload
                    .run_f32(&[(&flat, &[n as i64, d as i64])])
                    .and_then(|mut outs| {
                        if outs.is_empty() {
                            Err(Error::Runtime("payload returned no outputs".into()))
                        } else {
                            Ok(outs.remove(0))
                        }
                    });
                match out {
                    Ok(y) => {
                        for (i, resp) in resps.into_iter().enumerate() {
                            let _ = resp.send(Ok(y[i]));
                        }
                        let _ = used;
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for resp in resps {
                            let _ = resp.send(Err(Error::Runtime(msg.clone())));
                        }
                    }
                }
                // Handle any non-payload request pulled during coalescing.
                for req in deferred {
                    match req {
                        Request::Shutdown => return,
                        Request::WfPhi { input, resp } => {
                            let _ = resp.send(run_wf(&wf, &input));
                        }
                        Request::Payload { .. } => unreachable!("payloads are coalesced"),
                    }
                }
            }
        }
    }
}

fn run_wf(wf: &Executable, input: &WfPhiInput) -> Result<(Vec<i32>, Vec<i32>)> {
    // Shapes are validated by the handle; dims come from the lowered
    // artifact itself, so mismatches surface as PJRT errors too.
    let b = input.sizes.len() / input_k(input);
    let k = input_k(input);
    let m = input.busy.len() / b;
    let outs = wf.run_i32(&[
        (&input.busy, &[b as i64, m as i64]),
        (&input.mu, &[b as i64, m as i64]),
        (&input.sizes, &[b as i64, k as i64]),
        (&input.avail, &[b as i64, k as i64, m as i64]),
    ])?;
    if outs.len() != 2 {
        return Err(Error::Runtime(format!(
            "wf_phi returned {} outputs, want 2",
            outs.len()
        )));
    }
    let mut it = outs.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap()))
}

/// K is recoverable because avail = B·K·M while busy = B·M.
fn input_k(input: &WfPhiInput) -> usize {
    input.avail.len() / input.busy.len().max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_k_recovery() {
        let input = WfPhiInput {
            busy: vec![0; 2 * 4],
            mu: vec![1; 2 * 4],
            sizes: vec![0; 2 * 3],
            avail: vec![0; 2 * 3 * 4],
        };
        assert_eq!(input_k(&input), 3);
    }

    #[test]
    fn payload_row_length_validated() {
        // Construct a handle-shaped validation check without spawning a
        // thread (no artifacts in unit tests): replicate the check.
        let d = 8;
        let row = vec![0.0f32; 5];
        assert_ne!(row.len(), d);
    }
}
