//! The live coordinator: a leader/worker data plane that executes real
//! task payloads through the PJRT runtime.
//!
//! The paper's contribution is the scheduling layer, so the coordinator is
//! organized like a serving router: a **leader** ingests jobs, derives
//! task groups from chunk placement, runs a task-assignment algorithm
//! (§III) against live queue-depth estimates, and dispatches per-server
//! task batches to **workers**; workers execute each batch's data-chunk
//! compute by calling the **accelerator service**, a dedicated thread that
//! owns the PJRT client and the AOT-compiled Pallas payload kernel and
//! coalesces concurrent requests into batched executions. The same
//! service exposes the batched water-filling evaluator used to
//! cross-check the rust WF implementation against the L1 kernel.
//!
//! Python never runs here: the accelerator loads `artifacts/*.hlo.txt`
//! produced once by `make artifacts`.

pub mod accel;
pub mod leader;
pub mod reorder_offload;
pub mod verify;

pub use accel::{AccelHandle, WfPhiInput};
pub use leader::{Leader, LiveJobSpec, LiveReport};
