//! Unified observability layer: structured decision tracing, a metrics
//! registry, and the latency-decomposition substrate (PR 10).
//!
//! Three pieces, all dependency-free and allocation-disciplined:
//!
//! - [`Tracer`] — a pooled, bounded ring buffer of scheduler lifecycle
//!   events ([`TraceEvent`]) stamped with *slot* time (never wall
//!   clock, so a fixed seed yields byte-identical artifacts). Off by
//!   default ([`Tracer::off`]) and strictly zero-cost when off: every
//!   emitter checks one bool before touching anything. When on, the
//!   buffer capacity is frozen at construction (`--trace-limit`), the
//!   ring keeps the *last* N events, and [`Tracer::dropped`] reports
//!   how many older events were overwritten. Exports:
//!   [`to_chrome_json`] (Chrome trace-event JSON, loadable in Perfetto
//!   or `chrome://tracing` — jobs as async spans on the scheduler
//!   track, task executions as complete events on per-server tracks)
//!   and [`to_jsonl`] (one JSON object per line).
//! - [`Hist`] — a log₂-bucketed histogram over `u64` samples with a
//!   fixed 65-bucket array (no heap at all) for slot-valued
//!   distributions: per-server queue depth, per-job wait / service.
//! - [`MetricsRegistry`] — named counters / gauges / histograms with
//!   deterministic JSON ([`MetricsRegistry::to_json`]) and
//!   Prometheus-style text ([`MetricsRegistry::to_prometheus`])
//!   renderings. [`registry_from`] snapshots a
//!   [`SimOutcome`](crate::sim::SimOutcome) plus the run's [`ObsSink`]
//!   into one registry. Only deterministic, slot-derived metrics are
//!   included — wall-clock overhead and pool high-water marks (which
//!   may vary with thread count) stay in the simulate JSON — so
//!   `--metrics-out` files are byte-identical for a fixed seed at any
//!   thread count.
//!
//! [`ObsSink`] bundles the three for threading through the engines:
//! `run_fifo` / `ReorderedRun` / `DesRun` each take one by `&mut` (or
//! own one, for the consuming DES driver) and emit into it. With
//! [`ObsSink::off`] every emission site reduces to a single branch and
//! the schedule arithmetic is untouched — JCT vectors are bit-identical
//! tracing on or off, which `rust/tests/obs_trace.rs` asserts.

use crate::job::Slots;
use crate::util::json::Json;

/// Scheduler lifecycle event vocabulary. The `a`/`b` payload fields of
/// [`TraceEvent`] are kind-specific (documented per variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// A job arrived. `a` = number of task groups, `b` = total tasks.
    JobArrive,
    /// One per-server assignment row. `a` = tasks placed, `b` = tier.
    Assign,
    /// A queue entry began service. `a` = tasks, `b` = duration (slots).
    TaskStart,
    /// A queue entry finished. `a` = tasks, `b` = duration (slots).
    TaskFinish,
    /// A replica fork placed a copy. `a` = tasks, `b` = replica-set id.
    ReplicaFork,
    /// First replica completed and wins. `b` = replica-set id.
    ReplicaWin,
    /// A losing replica was cancelled. `a` = wasted slots (0 if it
    /// never started), `b` = replica-set id.
    ReplicaLose,
    /// An OCWF(-ACC) reorder round ran. `a` = jobs admitted in the
    /// batch, `b` = outstanding jobs considered.
    ReorderRound,
    /// A running entry was preempted. `a` = elapsed slots credited.
    Preempt,
    /// A job's last task finished. `a` = JCT in slots.
    JobComplete,
}

impl TraceKind {
    /// Stable snake_case name used by the JSONL export.
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::JobArrive => "job_arrive",
            TraceKind::Assign => "assign",
            TraceKind::TaskStart => "task_start",
            TraceKind::TaskFinish => "task_finish",
            TraceKind::ReplicaFork => "replica_fork",
            TraceKind::ReplicaWin => "replica_win",
            TraceKind::ReplicaLose => "replica_lose",
            TraceKind::ReorderRound => "reorder_round",
            TraceKind::Preempt => "preempt",
            TraceKind::JobComplete => "job_complete",
        }
    }
}

/// One traced event: slot timestamp, kind, job/server ids and two
/// kind-specific payload words (see [`TraceKind`]). Plain `Copy` data —
/// the ring buffer never allocates per event.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub time: Slots,
    pub kind: TraceKind,
    pub job: u32,
    pub server: u32,
    pub a: u64,
    pub b: u64,
}

/// Bounded ring buffer of [`TraceEvent`]s. Capacity is frozen at
/// construction; once full, new events overwrite the oldest (last-N
/// semantics — the tail of a run is usually the interesting part, and
/// [`dropped`](Tracer::dropped) reports the truncation).
#[derive(Clone, Debug)]
pub struct Tracer {
    buf: Vec<TraceEvent>,
    /// Index of the oldest event once the ring is full.
    head: usize,
    /// Events ever recorded (`total - buf.len()` were dropped).
    total: u64,
    cap: usize,
    enabled: bool,
}

impl Tracer {
    /// The disabled tracer: no heap, every emitter is one branch.
    pub fn off() -> Tracer {
        Tracer {
            buf: Vec::new(),
            head: 0,
            total: 0,
            cap: 0,
            enabled: false,
        }
    }

    /// An enabled tracer holding the last `cap` events (`cap = 0`
    /// degrades to [`Tracer::off`]). The buffer is allocated up front
    /// and never grows — the capacity freeze `alloc_stability` asserts.
    pub fn with_capacity(cap: usize) -> Tracer {
        if cap == 0 {
            return Tracer::off();
        }
        Tracer {
            buf: Vec::with_capacity(cap),
            head: 0,
            total: 0,
            cap,
            enabled: true,
        }
    }

    /// Whether emitters should record. `#[inline]` so the off path
    /// folds to a single predictable branch at every call site.
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events ever recorded, including overwritten ones.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Events lost to ring truncation.
    pub fn dropped(&self) -> u64 {
        self.total - self.buf.len() as u64
    }

    /// Pooled-buffer footprint in events (frozen after construction).
    pub fn footprint(&self) -> usize {
        self.buf.capacity()
    }

    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
        }
        self.total += 1;
    }

    /// Iterate the retained events oldest → newest.
    pub fn iter_in_order(&self) -> impl Iterator<Item = &TraceEvent> {
        let (tail, front) = if self.buf.len() < self.cap {
            (&self.buf[..], &self.buf[..0])
        } else {
            (&self.buf[self.head..], &self.buf[..self.head])
        };
        tail.iter().chain(front.iter())
    }

    // ---- inline emitters (each gated on `enabled` first) ----

    #[inline]
    pub fn job_arrive(&mut self, t: Slots, job: usize, groups: u64, tasks: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::JobArrive,
                job: job as u32,
                server: 0,
                a: groups,
                b: tasks,
            });
        }
    }

    #[inline]
    pub fn assign(&mut self, t: Slots, job: usize, server: usize, tasks: u64, tier: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::Assign,
                job: job as u32,
                server: server as u32,
                a: tasks,
                b: tier,
            });
        }
    }

    #[inline]
    pub fn task_start(&mut self, t: Slots, job: usize, server: usize, tasks: u64, dur: Slots) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::TaskStart,
                job: job as u32,
                server: server as u32,
                a: tasks,
                b: dur,
            });
        }
    }

    #[inline]
    pub fn task_finish(&mut self, t: Slots, job: usize, server: usize, tasks: u64, dur: Slots) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::TaskFinish,
                job: job as u32,
                server: server as u32,
                a: tasks,
                b: dur,
            });
        }
    }

    #[inline]
    pub fn replica_fork(&mut self, t: Slots, job: usize, server: usize, tasks: u64, set: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::ReplicaFork,
                job: job as u32,
                server: server as u32,
                a: tasks,
                b: set,
            });
        }
    }

    #[inline]
    pub fn replica_win(&mut self, t: Slots, job: usize, server: usize, set: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::ReplicaWin,
                job: job as u32,
                server: server as u32,
                a: 0,
                b: set,
            });
        }
    }

    #[inline]
    pub fn replica_lose(&mut self, t: Slots, job: usize, server: usize, wasted: Slots, set: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::ReplicaLose,
                job: job as u32,
                server: server as u32,
                a: wasted,
                b: set,
            });
        }
    }

    #[inline]
    pub fn reorder_round(&mut self, t: Slots, admitted: u64, outstanding: u64) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::ReorderRound,
                job: u32::MAX,
                server: 0,
                a: admitted,
                b: outstanding,
            });
        }
    }

    #[inline]
    pub fn preempt(&mut self, t: Slots, job: usize, server: usize, elapsed: Slots) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::Preempt,
                job: job as u32,
                server: server as u32,
                a: elapsed,
                b: 0,
            });
        }
    }

    #[inline]
    pub fn job_complete(&mut self, t: Slots, job: usize, jct: Slots) {
        if self.enabled {
            self.record(TraceEvent {
                time: t,
                kind: TraceKind::JobComplete,
                job: job as u32,
                server: 0,
                a: jct,
                b: 0,
            });
        }
    }
}

/// Render a trace as Chrome trace-event JSON (the object form, with a
/// `traceEvents` array), loadable in Perfetto / `chrome://tracing`.
///
/// Track layout: one process (`pid` 1); `tid` 0 is the scheduler track
/// (job async spans `b`/`e` keyed by job id, assignment / reorder
/// instants), `tid` m + 1 is server m's track (task executions as `X`
/// complete events, replica / preemption instants). Every event carries
/// `ph`/`ts`/`pid` — the schema CI checks — and timestamps are
/// simulation slots (microseconds to the viewer), never wall clock.
pub fn to_chrome_json(tr: &Tracer, num_servers: usize) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    s.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut sep = |s: &mut String| {
        if first {
            first = false;
        } else {
            s.push(',');
        }
    };
    sep(&mut s);
    s.push_str(
        "{\"ph\":\"M\",\"ts\":0,\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"taos\"}}",
    );
    sep(&mut s);
    s.push_str(
        "{\"ph\":\"M\",\"ts\":0,\"pid\":1,\"tid\":0,\"name\":\"thread_name\",\
         \"args\":{\"name\":\"scheduler\"}}",
    );
    for m in 0..num_servers {
        sep(&mut s);
        let _ = write!(
            s,
            "{{\"ph\":\"M\",\"ts\":0,\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"server {}\"}}}}",
            m + 1,
            m
        );
    }
    for ev in tr.iter_in_order() {
        sep(&mut s);
        let t = ev.time;
        match ev.kind {
            TraceKind::JobArrive => {
                let _ = write!(
                    s,
                    "{{\"ph\":\"b\",\"ts\":{t},\"pid\":1,\"tid\":0,\"cat\":\"job\",\
                     \"id\":{j},\"name\":\"job {j}\",\
                     \"args\":{{\"groups\":{a},\"tasks\":{b}}}}}",
                    j = ev.job,
                    a = ev.a,
                    b = ev.b
                );
            }
            TraceKind::JobComplete => {
                let _ = write!(
                    s,
                    "{{\"ph\":\"e\",\"ts\":{t},\"pid\":1,\"tid\":0,\"cat\":\"job\",\
                     \"id\":{j},\"name\":\"job {j}\",\"args\":{{\"jct\":{a}}}}}",
                    j = ev.job,
                    a = ev.a
                );
            }
            TraceKind::TaskStart => {
                let _ = write!(
                    s,
                    "{{\"ph\":\"X\",\"ts\":{t},\"dur\":{d},\"pid\":1,\"tid\":{tid},\
                     \"name\":\"job {j}\",\"args\":{{\"tasks\":{a}}}}}",
                    d = ev.b.max(1),
                    tid = ev.server + 1,
                    j = ev.job,
                    a = ev.a
                );
            }
            TraceKind::Assign
            | TraceKind::TaskFinish
            | TraceKind::ReplicaFork
            | TraceKind::ReplicaWin
            | TraceKind::ReplicaLose
            | TraceKind::ReorderRound
            | TraceKind::Preempt => {
                let tid = match ev.kind {
                    TraceKind::Assign | TraceKind::ReorderRound => 0,
                    _ => ev.server + 1,
                };
                let _ = write!(
                    s,
                    "{{\"ph\":\"i\",\"ts\":{t},\"pid\":1,\"tid\":{tid},\"s\":\"t\",\
                     \"name\":\"{name}\",\"args\":{{\"job\":{j},\"server\":{m},\
                     \"a\":{a},\"b\":{b}}}}}",
                    name = ev.kind.name(),
                    j = ev.job,
                    m = ev.server,
                    a = ev.a,
                    b = ev.b
                );
            }
        }
    }
    let _ = write!(
        s,
        "],\"otherData\":{{\"total\":{},\"dropped\":{}}}}}",
        tr.total(),
        tr.dropped()
    );
    s
}

/// Render a trace as JSONL: one compact JSON object per line with the
/// raw event fields (`ts`, `kind`, `job`, `server`, `a`, `b` — payload
/// semantics per [`TraceKind`]). Line order is oldest → newest.
pub fn to_jsonl(tr: &Tracer) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for ev in tr.iter_in_order() {
        let _ = writeln!(
            s,
            "{{\"ts\":{},\"kind\":\"{}\",\"job\":{},\"server\":{},\"a\":{},\"b\":{}}}",
            ev.time,
            ev.kind.name(),
            ev.job,
            ev.server,
            ev.a,
            ev.b
        );
    }
    s
}

/// Number of log₂ buckets in [`Hist`]: bucket 0 holds the value 0,
/// bucket i (i ≥ 1) holds values in `[2^(i-1), 2^i)`.
pub const HIST_BUCKETS: usize = 65;

/// Fixed-footprint log₂-bucketed histogram over `u64` samples. No heap
/// at all — safe to embed in pooled engine state without disturbing the
/// capacity-freeze contracts.
#[derive(Clone, Debug)]
pub struct Hist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of a value: 0 for 0, else `64 - leading_zeros`.
    #[inline]
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    #[inline]
    pub fn observe(&mut self, v: u64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn merge(&mut self, other: &Hist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-cumulative `(upper_bound, count)` pairs for every bucket up
    /// to the highest non-empty one. Upper bound of bucket 0 is 0;
    /// bucket i covers up to `2^i - 1`.
    pub fn bounds(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        let last = self
            .buckets
            .iter()
            .rposition(|&c| c > 0)
            .map_or(0, |i| i + 1);
        self.buckets[..last].iter().enumerate().map(|(i, &c)| {
            let ub = if i == 0 {
                0
            } else if i >= 64 {
                u64::MAX
            } else {
                (1u64 << i) - 1
            };
            (ub, c)
        })
    }

    fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .bounds()
            .map(|(ub, c)| Json::Arr(vec![Json::num(ub as f64), Json::num(c as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("min", Json::num(self.min() as f64)),
            ("max", Json::num(self.max as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// A registered metric value.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Hist(Hist),
}

/// Insertion-ordered registry of named metrics with deterministic JSON
/// and Prometheus text renderings. Names follow the Prometheus idiom
/// (`taos_` prefix, `_total` suffix on counters); a name may carry an
/// inline label set (`taos_tier_tasks_total{tier="1"}`), which the
/// text rendering passes through and the JSON rendering keys on.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    entries: Vec<(String, MetricValue)>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&mut self, name: &str, v: u64) {
        self.entries.push((name.to_string(), MetricValue::Counter(v)));
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.entries.push((name.to_string(), MetricValue::Gauge(v)));
    }

    pub fn hist(&mut self, name: &str, h: Hist) {
        self.entries.push((name.to_string(), MetricValue::Hist(h)));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Merge another registry in: counters add, gauges keep the max
    /// (high-water semantics), histograms merge bucket-wise. Metrics
    /// present only in `other` are appended.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (name, val) in &other.entries {
            match self.entries.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => match (mine, val) {
                    (MetricValue::Counter(a), MetricValue::Counter(b)) => *a += b,
                    (MetricValue::Gauge(a), MetricValue::Gauge(b)) => *a = a.max(*b),
                    (MetricValue::Hist(a), MetricValue::Hist(b)) => a.merge(b),
                    (mine, _) => *mine = val.clone(),
                },
                None => self.entries.push((name.clone(), val.clone())),
            }
        }
    }

    /// JSON object keyed by metric name (keys sorted by the `Json`
    /// renderer, so output is deterministic regardless of insertion
    /// order).
    pub fn to_json(&self) -> Json {
        let fields: Vec<(&str, Json)> = self
            .entries
            .iter()
            .map(|(name, val)| {
                let v = match val {
                    MetricValue::Counter(c) => Json::num(*c as f64),
                    MetricValue::Gauge(g) => Json::num(*g),
                    MetricValue::Hist(h) => h.to_json(),
                };
                (name.as_str(), v)
            })
            .collect();
        Json::obj(fields)
    }

    /// Prometheus text exposition: `# TYPE` line per metric family,
    /// `_bucket{le=...}` / `_sum` / `_count` series for histograms.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (name, val) in &self.entries {
            // Labels ride inside the name; the TYPE line wants the
            // bare family name.
            let family = name.split('{').next().unwrap_or(name);
            match val {
                MetricValue::Counter(c) => {
                    let _ = writeln!(s, "# TYPE {family} counter");
                    let _ = writeln!(s, "{name} {c}");
                }
                MetricValue::Gauge(g) => {
                    let _ = writeln!(s, "# TYPE {family} gauge");
                    let _ = writeln!(s, "{name} {g}");
                }
                MetricValue::Hist(h) => {
                    let _ = writeln!(s, "# TYPE {family} histogram");
                    let mut cum = 0u64;
                    for (ub, c) in h.bounds() {
                        cum += c;
                        let _ = writeln!(s, "{family}_bucket{{le=\"{ub}\"}} {cum}");
                    }
                    let _ = writeln!(s, "{family}_bucket{{le=\"+Inf\"}} {}", h.count());
                    let _ = writeln!(s, "{family}_sum {}", h.sum());
                    let _ = writeln!(s, "{family}_count {}", h.count());
                }
            }
        }
        s
    }
}

/// The observability bundle threaded through the engines: decision
/// tracer + metrics toggle + the queue-depth histogram the engines
/// populate. [`ObsSink::off`] is the zero-cost default every existing
/// entry point uses.
#[derive(Clone, Debug)]
pub struct ObsSink {
    pub trace: Tracer,
    /// When set, engines collect the extra distribution samples
    /// (per-server queue depth at each arrival).
    pub metrics: bool,
    /// Per-server backlog (slots until free) sampled at each arrival.
    pub queue_depth: Hist,
}

impl ObsSink {
    /// Everything off: one branch per emission site, no heap.
    pub fn off() -> ObsSink {
        ObsSink {
            trace: Tracer::off(),
            metrics: false,
            queue_depth: Hist::new(),
        }
    }

    pub fn new(trace_cap: usize, metrics: bool) -> ObsSink {
        ObsSink {
            trace: Tracer::with_capacity(trace_cap),
            metrics,
            queue_depth: Hist::new(),
        }
    }

    /// Pooled footprint in buffer elements (the tracer ring; frozen at
    /// construction).
    pub fn footprint(&self) -> usize {
        self.trace.footprint()
    }
}

/// Snapshot a finished run into a [`MetricsRegistry`]. Deterministic
/// metrics only: job counts, slot-time aggregates, event counts, tier
/// hits, and the slot-valued histograms (JCT / wait / service / queue
/// depth). Wall-clock overhead and pool high-water marks are *excluded*
/// so the export is byte-identical for a fixed seed at any thread
/// count (they remain in the simulate JSON, CI-filtered like before).
pub fn registry_from(outcome: &crate::sim::SimOutcome, obs: &ObsSink) -> MetricsRegistry {
    let mut r = MetricsRegistry::new();
    r.counter("taos_jobs_total", outcome.jcts.len() as u64);
    r.gauge("taos_makespan_slots", outcome.makespan as f64);
    r.counter("taos_wf_evals_total", outcome.wf_evals);
    r.counter("taos_des_events_total", outcome.telemetry.events);
    r.gauge("taos_des_peak_events", outcome.telemetry.peak_events as f64);
    r.gauge("taos_stream_peak_window", outcome.telemetry.peak_window as f64);
    r.counter("taos_wasted_work_slots_total", outcome.wasted_work);
    r.counter("taos_busy_work_slots_total", outcome.busy_work);
    for (tier, &n) in outcome.tier_tasks.iter().enumerate() {
        r.counter(&format!("taos_tier_tasks_total{{tier=\"{tier}\"}}"), n);
    }
    let mut jct_h = Hist::new();
    let mut wait_h = Hist::new();
    let mut service_h = Hist::new();
    for &j in &outcome.jcts {
        jct_h.observe(j);
    }
    for (i, &w) in outcome.waits.iter().enumerate() {
        wait_h.observe(w);
        service_h.observe(outcome.jcts[i].saturating_sub(w));
    }
    r.hist("taos_job_jct_slots", jct_h);
    r.hist("taos_job_wait_slots", wait_h);
    r.hist("taos_job_service_slots", service_h);
    r.hist("taos_queue_depth_slots", obs.queue_depth.clone());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: Slots, job: u32) -> TraceEvent {
        TraceEvent {
            time: t,
            kind: TraceKind::TaskStart,
            job,
            server: 0,
            a: 1,
            b: 1,
        }
    }

    #[test]
    fn off_tracer_records_nothing() {
        let mut tr = Tracer::off();
        assert!(!tr.on());
        tr.record(ev(0, 0));
        tr.job_arrive(1, 2, 3, 4);
        assert_eq!(tr.len(), 0);
        assert_eq!(tr.total(), 0);
        assert_eq!(tr.footprint(), 0);
    }

    #[test]
    fn ring_keeps_last_n_and_counts_dropped() {
        let mut tr = Tracer::with_capacity(4);
        for i in 0..10 {
            tr.record(ev(i, i as u32));
        }
        assert_eq!(tr.len(), 4);
        assert_eq!(tr.total(), 10);
        assert_eq!(tr.dropped(), 6);
        let times: Vec<Slots> = tr.iter_in_order().map(|e| e.time).collect();
        assert_eq!(times, vec![6, 7, 8, 9], "last-N, oldest first");
        assert_eq!(tr.footprint(), 4, "capacity frozen");
    }

    #[test]
    fn ring_below_capacity_is_in_order() {
        let mut tr = Tracer::with_capacity(8);
        for i in 0..3 {
            tr.record(ev(i, 0));
        }
        let times: Vec<Slots> = tr.iter_in_order().map(|e| e.time).collect();
        assert_eq!(times, vec![0, 1, 2]);
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn chrome_export_is_valid_json_with_required_fields() {
        let mut tr = Tracer::with_capacity(16);
        tr.job_arrive(0, 0, 2, 10);
        tr.assign(0, 0, 1, 10, 0);
        tr.task_start(0, 0, 1, 10, 5);
        tr.task_finish(5, 0, 1, 10, 5);
        tr.job_complete(5, 0, 5);
        let s = to_chrome_json(&tr, 2);
        let parsed = Json::parse(&s).expect("chrome export parses");
        let evs = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 3 metadata (process + scheduler + 2 servers = 4) + 5 events.
        assert_eq!(evs.len(), 4 + 5);
        for e in evs {
            assert!(e.get("ph").is_some(), "every event has ph");
            assert!(e.get("ts").is_some(), "every event has ts");
            assert!(e.get("pid").is_some(), "every event has pid");
        }
        // Async span pairing: one b and one e with the same id.
        let phs: Vec<&str> = evs.iter().filter_map(|e| e.get("ph")?.as_str()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "b").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "e").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "X").count(), 1);
    }

    #[test]
    fn jsonl_export_one_line_per_event() {
        let mut tr = Tracer::with_capacity(8);
        tr.job_arrive(3, 1, 1, 4);
        tr.reorder_round(5, 2, 7);
        let s = to_jsonl(&tr);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).expect("jsonl line parses");
            assert!(j.get("ts").is_some() && j.get("kind").is_some());
        }
        assert!(lines[0].contains("\"kind\":\"job_arrive\""));
        assert!(lines[1].contains("\"kind\":\"reorder_round\""));
    }

    #[test]
    fn hist_buckets_pow2() {
        let mut h = Hist::new();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.sum(), 1025);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 1000);
        let bounds: Vec<(u64, u64)> = h.bounds().collect();
        // Bucket 0 (ub 0): value 0. Bucket 1 (ub 1): value 1. Bucket 2
        // (ub 3): 2, 3. Bucket 3 (ub 7): 4, 7. Bucket 4 (ub 15): 8.
        // Bucket 10 (ub 1023): 1000.
        assert_eq!(bounds[0], (0, 1));
        assert_eq!(bounds[1], (1, 1));
        assert_eq!(bounds[2], (3, 2));
        assert_eq!(bounds[3], (7, 2));
        assert_eq!(bounds[4], (15, 1));
        assert_eq!(*bounds.last().unwrap(), (1023, 1));
    }

    #[test]
    fn hist_merge_adds() {
        let mut a = Hist::new();
        a.observe(1);
        a.observe(5);
        let mut b = Hist::new();
        b.observe(5);
        b.observe(100);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.sum(), 111);
        assert_eq!(a.min(), 1);
        assert_eq!(a.max(), 100);
    }

    #[test]
    fn empty_hist_renders_cleanly() {
        let h = Hist::new();
        assert_eq!(h.min(), 0);
        assert_eq!(h.bounds().count(), 0);
        let j = h.to_json().to_string();
        assert!(j.contains("\"count\":0"));
    }

    #[test]
    fn registry_json_and_prometheus_are_deterministic() {
        let mut r = MetricsRegistry::new();
        r.counter("taos_jobs_total", 42);
        r.gauge("taos_makespan_slots", 100.0);
        let mut h = Hist::new();
        h.observe(3);
        h.observe(9);
        r.hist("taos_job_jct_slots", h);
        r.counter("taos_tier_tasks_total{tier=\"0\"}", 7);

        let j1 = r.to_json().to_string();
        let j2 = r.clone().to_json().to_string();
        assert_eq!(j1, j2);
        assert!(Json::parse(&j1).is_ok(), "metrics JSON parses");
        assert!(j1.contains("\"taos_jobs_total\":42"));

        let p = r.to_prometheus();
        assert!(p.contains("# TYPE taos_jobs_total counter"));
        assert!(p.contains("taos_jobs_total 42"));
        assert!(p.contains("taos_makespan_slots 100"));
        assert!(p.contains("# TYPE taos_job_jct_slots histogram"));
        assert!(p.contains("taos_job_jct_slots_bucket{le=\"+Inf\"} 2"));
        assert!(p.contains("taos_job_jct_slots_sum 12"));
        assert!(p.contains("taos_tier_tasks_total{tier=\"0\"} 7"));
        // TYPE line strips the inline label set.
        assert!(p.contains("# TYPE taos_tier_tasks_total counter"));
    }

    #[test]
    fn registry_merge_semantics() {
        let mut a = MetricsRegistry::new();
        a.counter("c", 1);
        a.gauge("g", 2.0);
        let mut b = MetricsRegistry::new();
        b.counter("c", 10);
        b.gauge("g", 1.0);
        b.counter("only_b", 5);
        a.merge(&b);
        assert!(matches!(a.get("c"), Some(MetricValue::Counter(11))));
        match a.get("g") {
            Some(MetricValue::Gauge(v)) => assert_eq!(*v, 2.0),
            other => panic!("gauge missing: {other:?}"),
        }
        assert!(matches!(a.get("only_b"), Some(MetricValue::Counter(5))));
    }

    #[test]
    fn obs_sink_off_is_heap_free() {
        let o = ObsSink::off();
        assert_eq!(o.footprint(), 0);
        assert!(!o.trace.on());
        assert!(!o.metrics);
    }
}
