//! Index-addressed parallel fan-outs over the persistent worker pool.
//!
//! The sweep engine fans (policy × setting × trial) cells across cores
//! with [`parallel_map`]: stripes pull indices from a shared atomic
//! counter, compute `f(i)` and stash `(i, value)` pairs; results are
//! re-sorted by index before returning, so the output is **bit-identical
//! to the serial path at any thread count** as long as `f` itself is a
//! pure function of `i` (every sweep cell derives its RNG stream from its
//! own config seed, so it is).
//!
//! Both entry points execute on the process-wide
//! [`Executor`](crate::runtime::executor::Executor) — parked threads with
//! a condvar/ticket handoff — instead of spawning scoped threads per
//! call. That matters most for [`parallel_for_each`], the OCWF reorder
//! driver's fan-out: a reorder round over a small outstanding set used to
//! pay a scoped-spawn per speculative chunk, which dominated the work
//! being fanned out. No rayon / crossbeam: the pool is std-only, and
//! panics inside stripes propagate to the caller when the batch drains.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::runtime::executor::Executor;

/// Number of hardware threads available, with a safe fallback of 1.
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Worker-thread counts exercised by the cross-thread determinism suites
/// (`sweep_determinism` and `reorder_equivalence` read this; the
/// differential/metamorphic suites are thread-independent): the
/// `TAOS_TEST_THREADS` env var as a comma list (e.g. `1,2,8`), or
/// `[1, 2, 8]` when unset. CI runs a matrix leg per count.
///
/// A set-but-unparsable value **panics** instead of falling back: the
/// old silent `[1, 2, 8]` fallback let a typo'd CI matrix leg pass while
/// testing the wrong thread counts.
pub fn test_thread_counts() -> Vec<usize> {
    counts_from(std::env::var("TAOS_TEST_THREADS").ok().as_deref())
}

/// The arms behind [`test_thread_counts`], split out so both are
/// unit-testable without racing on the process-global environment.
fn counts_from(env: Option<&str>) -> Vec<usize> {
    match env {
        None => vec![1, 2, 8],
        Some(s) => match parse_thread_counts(s) {
            Ok(counts) => counts,
            Err(bad) => panic!(
                "TAOS_TEST_THREADS=`{s}`: bad thread count `{bad}` \
                 (expected a comma list of positive integers, e.g. `1,2,8`)"
            ),
        },
    }
}

/// Parse a comma list of positive thread counts; `Err` carries the first
/// offending token. Empty input errors too (`split` yields one empty
/// token): a set-but-empty variable is a misconfigured matrix leg, not a
/// request for defaults.
fn parse_thread_counts(s: &str) -> Result<Vec<usize>, String> {
    let mut counts = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        match tok.parse::<usize>() {
            Ok(n) if n > 0 => counts.push(n),
            _ => return Err(tok.to_string()),
        }
    }
    Ok(counts)
}

/// Map `f` over `0..n` using up to `threads` concurrent stripes and
/// return the results in index order. `threads <= 1` (or `n <= 1`)
/// degenerates to a plain serial loop — the reference path the
/// determinism tests compare against.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.min(n).max(1);
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    let task = |_stripe: usize| {
        // Collect locally, publish once: keeps the mutex out of the
        // per-cell hot path.
        let mut local: Vec<(usize, T)> = Vec::new();
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            local.push((i, f(i)));
        }
        done.lock().unwrap().extend(local);
    };
    Executor::global().run_batch(threads, &task);

    let mut pairs = done.into_inner().unwrap();
    debug_assert_eq!(pairs.len(), n);
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, v)| v).collect()
}

/// Run `f(state, i)` for every `i in 0..n`, fanning the indices across
/// the worker states: stripe `w` (of `W = min(states.len(), n)`) handles
/// exactly the indices `i ≡ w (mod W)`, in ascending order.
///
/// The **static stride** (instead of an atomic work queue) is deliberate:
/// which state handles which index is a pure function of `(n, W)`, so
/// each state evolves identically run-to-run regardless of which pool
/// thread executes its stripe — the property the OCWF reorder driver's
/// allocation-stability test asserts. With one state (or `n ≤ 1`) this
/// degenerates to a plain serial loop, the reference path of the
/// determinism tests.
///
/// Stripes write results into their own `&mut S`; nothing is collected
/// here, so the call itself performs no allocation.
pub fn parallel_for_each<S, F>(n: usize, states: &mut [S], f: F)
where
    S: Send,
    F: Fn(&mut S, usize) + Sync,
{
    if n == 0 {
        return;
    }
    assert!(!states.is_empty(), "parallel_for_each needs >= 1 state");
    let workers = states.len().min(n);
    if workers == 1 {
        let s = &mut states[0];
        for i in 0..n {
            f(&mut *s, i);
        }
        return;
    }

    /// Shared base pointer into the state slice. Each stripe touches only
    /// `states[w]` for its own `w`, and the executor runs every stripe
    /// exactly once, so the `&mut` accesses are disjoint.
    struct StatesPtr<S>(*mut S);
    unsafe impl<S: Send> Send for StatesPtr<S> {}
    unsafe impl<S: Send> Sync for StatesPtr<S> {}

    let base = StatesPtr(states.as_mut_ptr());
    let task = move |w: usize| {
        // SAFETY: w < workers <= states.len(), and stripe w is the only
        // stripe dereferencing offset w (run exactly once per batch).
        let s: &mut S = unsafe { &mut *base.0.add(w) };
        let mut i = w;
        while i < n {
            f(&mut *s, i);
            i += workers;
        }
    };
    Executor::global().run_batch(workers, &task);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_at_every_thread_count() {
        let serial: Vec<u64> = (0..97).map(|i| (i as u64).wrapping_mul(i as u64)).collect();
        for threads in [1, 2, 3, 8, 64] {
            let par = parallel_map(97, threads, |i| (i as u64).wrapping_mul(i as u64));
            assert_eq!(par, serial, "threads = {threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 8, |i| i + 10), vec![10]);
    }

    #[test]
    fn oversubscribed_thread_count_is_clamped() {
        // More threads than items must not deadlock or drop results.
        let out = parallel_map(3, 100, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4]);
    }

    #[test]
    fn for_each_static_stride_partitions_all_indices() {
        // Each worker state collects its indices; together they must cover
        // 0..n exactly once, with worker w owning i ≡ w (mod W).
        let mut states: Vec<Vec<usize>> = vec![Vec::new(); 3];
        parallel_for_each(11, &mut states, |s, i| s.push(i));
        let mut all: Vec<usize> = states.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..11).collect::<Vec<_>>());
        for (w, s) in states.iter().enumerate() {
            assert!(s.iter().all(|&i| i % 3 == w), "worker {w}: {s:?}");
            // Static stride: ascending within a worker.
            assert!(s.windows(2).all(|p| p[0] < p[1]));
        }
    }

    #[test]
    fn for_each_serial_degenerate_cases() {
        let mut states = vec![0u64];
        parallel_for_each(5, &mut states, |s, i| *s += i as u64);
        assert_eq!(states[0], 10);
        // n == 0: untouched even with empty states.
        let mut none: Vec<u64> = Vec::new();
        parallel_for_each(0, &mut none, |_s, _i| unreachable!());
    }

    #[test]
    fn available_threads_is_positive() {
        assert!(available_threads() >= 1);
    }

    #[test]
    fn test_thread_counts_defaults() {
        // The env var is process-global, so exercise the arms through
        // `counts_from` instead of mutating the environment (CI sets the
        // var per matrix leg).
        if std::env::var("TAOS_TEST_THREADS").is_err() {
            assert_eq!(test_thread_counts(), vec![1, 2, 8]);
        } else {
            assert!(test_thread_counts().iter().all(|&t| t > 0));
        }
        assert_eq!(counts_from(None), vec![1, 2, 8], "unset → defaults");
    }

    #[test]
    fn thread_counts_parse_valid_lists() {
        assert_eq!(counts_from(Some("1,2,8")), vec![1, 2, 8]);
        assert_eq!(counts_from(Some(" 4 , 16 ")), vec![4, 16]);
        assert_eq!(counts_from(Some("2")), vec![2]);
    }

    #[test]
    fn thread_counts_reject_bad_tokens_loudly() {
        // A typo'd matrix leg must fail the run, not silently test the
        // default counts. The panic names the offending token.
        for bad in ["1,x,8", "0", "", "1,,2", "eight"] {
            let caught = std::panic::catch_unwind(|| counts_from(Some(bad)));
            assert!(caught.is_err(), "`{bad}` must panic");
        }
        assert_eq!(
            parse_thread_counts("1,x,8").unwrap_err(),
            "x",
            "error carries the offending token"
        );
        assert_eq!(parse_thread_counts("0").unwrap_err(), "0");
        assert_eq!(parse_thread_counts("").unwrap_err(), "");
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(16, 4, |i| {
            if i == 7 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn pool_survives_propagated_panic() {
        // The persistent pool must keep serving after a panicking batch
        // (scoped threads died with their scope; pooled workers may not).
        let caught = std::panic::catch_unwind(|| {
            parallel_map(16, 4, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(caught.is_err());
        let out = parallel_map(8, 4, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }
}
