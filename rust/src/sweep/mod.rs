//! Experiment sweeps that regenerate every table and figure of the
//! paper's evaluation (§V), plus the scenario sweep that drives the named
//! workloads of [`crate::trace::scenarios`]. Shared by the `taos repro`
//! CLI subcommand and the `cargo bench` figure harnesses.
//!
//! | Paper artifact | Function |
//! |---|---|
//! | Fig 10 (25% util) | [`fig_alpha_util`] with `util = 0.25` |
//! | Fig 11 (50% util) | [`fig_alpha_util`] with `util = 0.50` |
//! | Fig 12 (75% util) | [`fig_alpha_util`] with `util = 0.75` |
//! | Fig 13 + Table I | [`fig_servers`] |
//! | Fig 14 | [`fig_capacity`] |
//! | Scenario catalog | [`fig_scenarios`] |
//! | Topology-locality penalty sweep | [`fig_topology`] |
//!
//! ## Parallel execution
//!
//! Every sweep expands into a flat list of [`CellSpec`]s — one per
//! (policy × setting × trial) — and runs them through [`pool`] on the
//! persistent worker-pool executor
//! ([`crate::runtime::executor::Executor`]): parked threads reused across
//! every batch, no per-sweep thread spawns. Each cell's randomness is
//! derived solely from its own spec ([`trial_seed`]), and results are
//! re-ordered by spec index, so a sweep's output is bit-identical at any
//! thread count (asserted by `rust/tests/sweep_determinism.rs`).
//! Wall-clock overhead metrics are the one exception: they time real
//! execution and are never compared bitwise.

pub mod pool;

use crate::assign::feasible::OracleStats;
use crate::benchlib::{fmt_count, TextTable};
use crate::config::ExperimentConfig;
use crate::job::Slots;
use crate::metrics::{jct_cdf_pooled, StatsScratch};
use crate::sched::{PolicySet, SchedPolicy};
use crate::sim::{run_experiment, SimOutcome};
use crate::util::json::Json;

/// Result of one (policy, setting) cell: the paper's two metrics plus the
/// CDF series for the CDF subplots. With `trials > 1` the metrics are
/// averaged over trials and the CDF pools every trial's JCTs.
#[derive(Clone, Debug)]
pub struct Cell {
    pub policy: &'static str,
    pub setting: f64,
    pub mean_jct: f64,
    /// Median JCT over the cell's pooled per-job completion times. Tail
    /// scenarios (`straggler`, `heavy-tail`) move the percentiles long
    /// before they move the mean, so the sweep surfaces them directly.
    pub p50_jct: f64,
    /// 99th-percentile JCT over the cell's pooled completion times.
    pub p99_jct: f64,
    pub overhead_us: f64,
    /// Median per-arrival overhead (µs, streaming P² estimate averaged
    /// over trials) — the overhead *tail* companion of `overhead_us`.
    /// Wall-clock like `overhead_us`: never compared bitwise.
    pub overhead_p50_us: f64,
    /// 99th-percentile per-arrival overhead (µs, P² estimate averaged
    /// over trials).
    pub overhead_p99_us: f64,
    /// Mean queueing wait (slots until a job's first task made progress),
    /// averaged over trials — the wait half of the JCT decomposition.
    pub mean_wait: f64,
    /// Mean service span (`mean JCT − mean wait`), averaged over trials.
    pub mean_service: f64,
    pub cdf: Vec<(f64, f64)>,
    /// Full WF evaluations, summed over the cell's trials (reordered
    /// policies; 0 for the FIFO assigners). Totals — not per-trial means —
    /// so they stay on the same scale as `oracle`.
    pub wf_evals: u64,
    /// Feasibility-oracle tier counters, summed over the cell's trials
    /// (exact assigners only).
    pub oracle: Option<OracleStats>,
    /// Locality-tier hit counts summed over the cell's trials (DES engine
    /// with locality only; index 0 = data-local). Empty for analytic
    /// cells, so historical figure JSON stays byte-identical.
    pub tier_tasks: Vec<u64>,
    /// Service slots burned by replica-race losers, summed over the
    /// cell's trials (DES engine with replication only; 0 otherwise) —
    /// the cost axis of the k-replica frontier.
    pub wasted_work: u64,
    /// Total service slots (useful + wasted), summed over the cell's
    /// trials. 0 for analytic cells, which never track per-slot busy
    /// time; the JSON export keys off this so analytic figures stay
    /// byte-identical.
    pub busy_work: u64,
}

impl Cell {
    /// Compact scheduler-work summary for the telemetry table: WF
    /// evaluations for the reordered policies, oracle tier hits for the
    /// exact assigners, `-` when the cell tracked neither.
    pub fn work_summary(&self) -> String {
        if self.wf_evals > 0 {
            return fmt_count(self.wf_evals);
        }
        match &self.oracle {
            Some(o) => format!(
                "{}/{}/{}/{}",
                fmt_count(o.flow_infeasible),
                fmt_count(o.ceil_feasible),
                fmt_count(o.floor_residual_feasible),
                fmt_count(o.ilp_calls)
            ),
            None => "-".into(),
        }
    }

    /// Wasted-work fraction of the cell's total service slots
    /// (`wasted_work / busy_work`; 0 without replication).
    pub fn wasted_fraction(&self) -> f64 {
        if self.busy_work == 0 {
            0.0
        } else {
            self.wasted_work as f64 / self.busy_work as f64
        }
    }

    /// Wasted-work summary for the replication table (`wasted%` of the
    /// service slots), or `-` when the cell tracked no busy time.
    pub fn wasted_summary(&self) -> String {
        if self.busy_work == 0 {
            return "-".into();
        }
        format!("{:.1}%", self.wasted_fraction() * 100.0)
    }

    /// Tier hit rates as percentages of the cell's total task count, or
    /// `-` when the cell ran without locality telemetry.
    pub fn tier_summary(&self) -> String {
        let total: u64 = self.tier_tasks.iter().sum();
        if total == 0 {
            return "-".into();
        }
        self.tier_tasks
            .iter()
            .map(|&n| format!("{:.0}%", n as f64 * 100.0 / total as f64))
            .collect::<Vec<_>>()
            .join("/")
    }
}

/// A complete figure: one cell per (policy, x-axis setting).
#[derive(Clone, Debug)]
pub struct Figure {
    pub name: String,
    pub x_label: &'static str,
    pub cells: Vec<Cell>,
}

impl Figure {
    /// The x-axis values, deduped in order.
    pub fn settings(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = Vec::new();
        for c in &self.cells {
            if !xs.iter().any(|&x| x == c.setting) {
                xs.push(c.setting);
            }
        }
        xs
    }

    pub fn cell(&self, policy: &str, setting: f64) -> Option<&Cell> {
        self.cells
            .iter()
            .find(|c| c.policy == policy && c.setting == setting)
    }

    /// The policy names actually present in the cells, deduped in
    /// first-appearance order. Rendering iterates this — not a hardcoded
    /// panel — so a narrowed `--policies` sweep prints no ghost rows and
    /// an extended one prints every baseline.
    pub fn policies(&self) -> Vec<&'static str> {
        let mut ps: Vec<&'static str> = Vec::new();
        for c in &self.cells {
            if !ps.contains(&c.policy) {
                ps.push(c.policy);
            }
        }
        ps
    }

    /// Render the figure's headline table: mean JCT (and overhead) per
    /// algorithm × setting, exactly the rows the paper plots.
    pub fn render(&self) -> String {
        let settings = self.settings();
        let mut header: Vec<String> = vec!["algorithm".into()];
        for s in &settings {
            header.push(format!("{}={}", self.x_label, s));
        }
        header.push("avg".into());
        let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();

        let mut out = format!("== {} : mean JCT (slots) ==\n", self.name);
        let mut t = TextTable::new(&hdr_refs);
        for policy in self.policies() {
            let mut row = vec![policy.to_string()];
            let mut sum = 0.0;
            let mut cnt = 0;
            for &s in &settings {
                match self.cell(policy, s) {
                    Some(c) => {
                        row.push(format!("{:.0}", c.mean_jct));
                        sum += c.mean_jct;
                        cnt += 1;
                    }
                    None => row.push("-".into()),
                }
            }
            row.push(if cnt > 0 {
                format!("{:.0}", sum / cnt as f64)
            } else {
                "-".into()
            });
            t.row(row);
        }
        out.push_str(&t.render());

        out.push_str(&format!(
            "\n== {} : JCT percentiles p50/p99 (slots, pooled over trials) ==\n",
            self.name
        ));
        let mut tp = TextTable::new(&hdr_refs);
        for policy in self.policies() {
            let mut row = vec![policy.to_string()];
            for &s in &settings {
                row.push(match self.cell(policy, s) {
                    Some(c) => format!("{:.0}/{:.0}", c.p50_jct, c.p99_jct),
                    None => "-".into(),
                });
            }
            row.push("".into());
            tp.row(row);
        }
        out.push_str(&tp.render());

        out.push_str(&format!(
            "\n== {} : latency decomposition, mean wait/service (slots; wait+service=JCT) ==\n",
            self.name
        ));
        let mut tw = TextTable::new(&hdr_refs);
        for policy in self.policies() {
            let mut row = vec![policy.to_string()];
            for &s in &settings {
                row.push(match self.cell(policy, s) {
                    Some(c) => format!("{:.0}/{:.0}", c.mean_wait, c.mean_service),
                    None => "-".into(),
                });
            }
            row.push("".into());
            tw.row(row);
        }
        out.push_str(&tw.render());

        out.push_str(&format!(
            "\n== {} : overhead per arrival, mean/p50/p99 (us) ==\n",
            self.name
        ));
        let mut t2 = TextTable::new(&hdr_refs);
        for policy in self.policies() {
            let mut row = vec![policy.to_string()];
            let mut sum = 0.0;
            let mut cnt = 0;
            for &s in &settings {
                match self.cell(policy, s) {
                    Some(c) => {
                        row.push(format!(
                            "{:.1}/{:.1}/{:.1}",
                            c.overhead_us, c.overhead_p50_us, c.overhead_p99_us
                        ));
                        sum += c.overhead_us;
                        cnt += 1;
                    }
                    None => row.push("-".into()),
                }
            }
            row.push(if cnt > 0 {
                format!("{:.1}", sum / cnt as f64)
            } else {
                "-".into()
            });
            t2.row(row);
        }
        out.push_str(&t2.render());

        out.push_str(&format!(
            "\n== {} : scheduler work, totals across trials (WF evals; oracle tiers flow-inf/ceil/floor+res/ilp) ==\n",
            self.name
        ));
        let mut t3 = TextTable::new(&hdr_refs);
        for policy in self.policies() {
            let mut row = vec![policy.to_string()];
            let mut any = false;
            for &s in &settings {
                row.push(match self.cell(policy, s) {
                    Some(c) => {
                        let txt = c.work_summary();
                        if txt != "-" {
                            any = true;
                        }
                        txt
                    }
                    None => "-".into(),
                });
            }
            let avg_cell: &str = if any { "" } else { "-" };
            row.push(avg_cell.into());
            t3.row(row);
        }
        out.push_str(&t3.render());

        // Locality-tier hit rates: only rendered when at least one cell
        // ran the DES engine with a locality model, so the analytic
        // figures keep their historical four-table layout.
        if self.cells.iter().any(|c| !c.tier_tasks.is_empty()) {
            out.push_str(&format!(
                "\n== {} : locality tier hit rates (tier0=data-local/../top) ==\n",
                self.name
            ));
            let mut t4 = TextTable::new(&hdr_refs);
            for policy in self.policies() {
                let mut row = vec![policy.to_string()];
                for &s in &settings {
                    row.push(match self.cell(policy, s) {
                        Some(c) => c.tier_summary(),
                        None => "-".into(),
                    });
                }
                row.push("".into());
                t4.row(row);
            }
            out.push_str(&t4.render());
        }

        // Wasted-work fractions: only rendered when at least one cell
        // actually burned replica slots, so replication-free figures keep
        // their historical layout.
        if self.cells.iter().any(|c| c.wasted_work > 0) {
            out.push_str(&format!(
                "\n== {} : wasted work (replica-loser slots, % of service slots) ==\n",
                self.name
            ));
            let mut t5 = TextTable::new(&hdr_refs);
            for policy in self.policies() {
                let mut row = vec![policy.to_string()];
                for &s in &settings {
                    row.push(match self.cell(policy, s) {
                        Some(c) => c.wasted_summary(),
                        None => "-".into(),
                    });
                }
                row.push("".into());
                t5.row(row);
            }
            out.push_str(&t5.render());
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("x_label", Json::str(self.x_label)),
            (
                "cells",
                Json::arr(self.cells.iter().map(|c| {
                    let mut fields = vec![
                        ("policy", Json::str(c.policy)),
                        ("setting", Json::num(c.setting)),
                        ("mean_jct", Json::num(c.mean_jct)),
                        ("p50_jct", Json::num(c.p50_jct)),
                        ("p99_jct", Json::num(c.p99_jct)),
                        ("overhead_us", Json::num(c.overhead_us)),
                        ("overhead_p50_us", Json::num(c.overhead_p50_us)),
                        ("overhead_p99_us", Json::num(c.overhead_p99_us)),
                        ("mean_wait", Json::num(c.mean_wait)),
                        ("mean_service", Json::num(c.mean_service)),
                        ("wf_evals", Json::num(c.wf_evals as f64)),
                        (
                            "cdf",
                            Json::arr(c.cdf.iter().map(|&(x, y)| {
                                Json::arr(vec![Json::num(x), Json::num(y)])
                            })),
                        ),
                    ];
                    if !c.tier_tasks.is_empty() {
                        fields.push((
                            "tier_tasks",
                            Json::arr(c.tier_tasks.iter().map(|&n| Json::num(n as f64))),
                        ));
                    }
                    if c.busy_work > 0 {
                        fields.push(("wasted_work", Json::num(c.wasted_work as f64)));
                        fields.push(("busy_work", Json::num(c.busy_work as f64)));
                        fields.push(("wasted_frac", Json::num(c.wasted_fraction())));
                    }
                    if let Some(o) = &c.oracle {
                        fields.push((
                            "oracle",
                            Json::obj(vec![
                                ("flow_infeasible", Json::num(o.flow_infeasible as f64)),
                                ("ceil_feasible", Json::num(o.ceil_feasible as f64)),
                                (
                                    "floor_residual_feasible",
                                    Json::num(o.floor_residual_feasible as f64),
                                ),
                                ("ilp_calls", Json::num(o.ilp_calls as f64)),
                                ("ilp_unknown", Json::num(o.ilp_unknown as f64)),
                            ]),
                        ));
                    }
                    Json::obj(fields)
                })),
            ),
        ])
    }
}

/// Execution options for a sweep: worker-thread count and independent
/// trials per cell.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepOptions {
    /// Worker threads. `0` means "all available cores"; `1` is the serial
    /// reference path.
    pub threads: usize,
    /// Independent trials per (policy, setting) cell; metrics are averaged
    /// and CDFs pooled. Trial `t` runs with [`trial_seed`]`(seed, t)`.
    pub trials: usize,
    /// The policy panel the sweep runs, in panel order (`--policies`).
    /// Defaults to the paper's six so every historical figure and golden
    /// export stays byte-identical.
    pub policies: PolicySet,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            threads: 1,
            trials: 1,
            policies: PolicySet::paper(),
        }
    }
}

impl SweepOptions {
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_trials(mut self, trials: usize) -> Self {
        self.trials = trials.max(1);
        self
    }

    pub fn with_policies(mut self, policies: PolicySet) -> Self {
        self.policies = policies;
        self
    }

    /// Options for the bench harnesses: worker threads from
    /// `TAOS_BENCH_THREADS` (unset or unparsable → 0 = all cores),
    /// single trial. One definition so the env contract lives in one
    /// place.
    pub fn from_env() -> Self {
        let threads = std::env::var("TAOS_BENCH_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        SweepOptions::default().with_threads(threads)
    }

    /// Resolve `threads == 0` to the hardware parallelism.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            pool::available_threads()
        } else {
            self.threads
        }
    }
}

/// One fully specified sweep cell: everything a worker needs to run it,
/// independent of every other cell.
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub cfg: ExperimentConfig,
    pub policy: SchedPolicy,
    /// The figure's x-axis value this cell belongs to.
    pub setting: f64,
    /// Trial index within the (policy, setting) cell.
    pub trial: u64,
}

/// Deterministic per-trial seed derivation (splitmix64-style mixing).
/// Trial 0 keeps the base seed unchanged so single-trial sweeps reproduce
/// the historical serial results bit for bit.
pub fn trial_seed(base: u64, trial: u64) -> u64 {
    if trial == 0 {
        return base;
    }
    let mut z = base ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run every spec — in parallel when `threads > 1` — and return the
/// outcomes in spec order. The output is bit-identical at any thread
/// count because each cell's simulation is a pure function of its spec.
///
/// A cell that fails (e.g. its utilization config exceeds the simulation
/// horizon, [`crate::Error::Sim`]) no longer aborts the process: the
/// first failing cell — in spec order, so the report is deterministic —
/// is surfaced with its full coordinates (policy, setting, trial, seed).
pub fn run_specs(specs: &[CellSpec], threads: usize) -> crate::Result<Vec<SimOutcome>> {
    let results = pool::parallel_map(specs.len(), threads, |i| {
        let s = &specs[i];
        run_experiment(&s.cfg, s.policy)
    });
    results
        .into_iter()
        .zip(specs)
        .map(|(r, s)| {
            r.map_err(|e| {
                crate::Error::Sim(format!(
                    "sweep cell failed: policy {} at setting {} (trial {}, seed {}): {e}",
                    s.policy.name(),
                    s.setting,
                    s.trial,
                    s.cfg.seed
                ))
            })
        })
        .collect()
}

/// Expand (settings × policies × trials) into a flat spec list. `mutate`
/// applies one x-axis setting to a config clone.
fn specs_for(
    base: &ExperimentConfig,
    settings: &[f64],
    trials: usize,
    policies: &PolicySet,
    mutate: &dyn Fn(&mut ExperimentConfig, f64),
) -> Vec<CellSpec> {
    let trials = trials.max(1);
    let mut specs = Vec::with_capacity(settings.len() * policies.len() * trials);
    for &setting in settings {
        let mut cfg = base.clone();
        mutate(&mut cfg, setting);
        for policy in policies {
            for trial in 0..trials as u64 {
                let mut cell_cfg = cfg.clone();
                cell_cfg.seed = trial_seed(base.seed, trial);
                specs.push(CellSpec {
                    cfg: cell_cfg,
                    policy,
                    setting,
                    trial,
                });
            }
        }
    }
    specs
}

/// Collapse per-trial outcomes (grouped as `trials` consecutive specs per
/// cell) into figure cells.
fn cells_from(specs: &[CellSpec], outcomes: &[SimOutcome], trials: usize) -> Vec<Cell> {
    let trials = trials.max(1);
    debug_assert_eq!(specs.len(), outcomes.len());
    debug_assert_eq!(specs.len() % trials, 0);
    let mut cells = Vec::with_capacity(specs.len() / trials);
    // Pooled per-cell buffers: reused across every cell so the collapse
    // loop stops allocating once they reach the largest trial group.
    let mut jcts: Vec<Slots> = Vec::new();
    let mut scratch = StatsScratch::new();
    let mut i = 0;
    while i < specs.len() {
        let spec = &specs[i];
        let group = &outcomes[i..i + trials];
        jcts.clear();
        let mut jct_sum = 0.0;
        let mut ov_sum = 0.0;
        let mut ov_p50_sum = 0.0;
        let mut ov_p99_sum = 0.0;
        let mut wait_sum = 0.0;
        let mut service_sum = 0.0;
        let mut wf_evals_sum = 0u64;
        let mut oracle: Option<OracleStats> = None;
        let mut tier_tasks: Vec<u64> = Vec::new();
        let mut wasted_work = 0u64;
        let mut busy_work = 0u64;
        for o in group {
            jct_sum += o.mean_jct();
            ov_sum += o.overhead.mean_us();
            ov_p50_sum += o.overhead.p50_us();
            ov_p99_sum += o.overhead.p99_us();
            wait_sum += o.mean_wait();
            service_sum += o.mean_service();
            jcts.extend_from_slice(&o.jcts);
            wf_evals_sum += o.wf_evals;
            wasted_work += o.wasted_work;
            busy_work += o.busy_work;
            if let Some(st) = &o.oracle_stats {
                oracle.get_or_insert_with(OracleStats::default).merge(st);
            }
            if tier_tasks.len() < o.tier_tasks.len() {
                tier_tasks.resize(o.tier_tasks.len(), 0);
            }
            for (acc, &n) in tier_tasks.iter_mut().zip(&o.tier_tasks) {
                *acc += n;
            }
        }
        let pooled = crate::metrics::JctStats::from_jcts_pooled(&jcts, &mut scratch);
        cells.push(Cell {
            policy: spec.policy.name(),
            setting: spec.setting,
            mean_jct: jct_sum / trials as f64,
            p50_jct: pooled.p50,
            p99_jct: pooled.p99,
            overhead_us: ov_sum / trials as f64,
            overhead_p50_us: ov_p50_sum / trials as f64,
            overhead_p99_us: ov_p99_sum / trials as f64,
            mean_wait: wait_sum / trials as f64,
            mean_service: service_sum / trials as f64,
            cdf: jct_cdf_pooled(&jcts, 64, &mut scratch),
            wf_evals: wf_evals_sum,
            oracle,
            tier_tasks,
            wasted_work,
            busy_work,
        });
        i += trials;
    }
    cells
}

fn run_figure(
    name: String,
    x_label: &'static str,
    base: &ExperimentConfig,
    settings: &[f64],
    opts: &SweepOptions,
    mutate: &dyn Fn(&mut ExperimentConfig, f64),
) -> crate::Result<Figure> {
    let specs = specs_for(base, settings, opts.trials, &opts.policies, mutate);
    let outcomes = run_specs(&specs, opts.effective_threads())?;
    Ok(Figure {
        name,
        x_label,
        cells: cells_from(&specs, &outcomes, opts.trials),
    })
}

/// Figs 10–12: sweep Zipf α at fixed utilization, all six algorithms
/// (serial single-trial path; see [`fig_alpha_util_opts`]).
pub fn fig_alpha_util(base: &ExperimentConfig, util: f64, alphas: &[f64]) -> crate::Result<Figure> {
    fig_alpha_util_opts(base, util, alphas, &SweepOptions::default())
}

/// Figs 10–12 with explicit execution options.
pub fn fig_alpha_util_opts(
    base: &ExperimentConfig,
    util: f64,
    alphas: &[f64],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    run_figure(
        format!("fig-alpha-util-{:.0}%", util * 100.0),
        "alpha",
        base,
        alphas,
        opts,
        &|cfg, alpha| {
            cfg.cluster.zipf_alpha = alpha;
            cfg.trace.utilization = util;
        },
    )
}

/// Fig 13 + Table I: sweep the number of available servers p at α = 2,
/// 75% utilization (the paper fixes p per sweep point: avail_lo =
/// avail_hi = p).
pub fn fig_servers(base: &ExperimentConfig, ps: &[usize]) -> crate::Result<Figure> {
    fig_servers_opts(base, ps, &SweepOptions::default())
}

/// Fig 13 + Table I with explicit execution options.
pub fn fig_servers_opts(
    base: &ExperimentConfig,
    ps: &[usize],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    let settings: Vec<f64> = ps.iter().map(|&p| p as f64).collect();
    run_figure(
        "fig13-table1-available-servers".into(),
        "p",
        base,
        &settings,
        opts,
        &|cfg, p| {
            cfg.cluster.zipf_alpha = 2.0;
            cfg.trace.utilization = 0.75;
            cfg.cluster.avail_lo = p as usize;
            cfg.cluster.avail_hi = p as usize;
        },
    )
}

/// Fig 14: sweep computing capacity (μ ranges centred on the x value) at
/// α = 2, 75% utilization.
pub fn fig_capacity(base: &ExperimentConfig, mu_mids: &[u64]) -> crate::Result<Figure> {
    fig_capacity_opts(base, mu_mids, &SweepOptions::default())
}

/// Fig 14 with explicit execution options.
pub fn fig_capacity_opts(
    base: &ExperimentConfig,
    mu_mids: &[u64],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    let settings: Vec<f64> = mu_mids.iter().map(|&m| m as f64).collect();
    run_figure(
        "fig14-computing-capacity".into(),
        "mu",
        base,
        &settings,
        opts,
        &|cfg, mid| {
            let mid = mid as u64;
            cfg.cluster.zipf_alpha = 2.0;
            cfg.trace.utilization = 0.75;
            cfg.cluster.mu_lo = mid - 1;
            cfg.cluster.mu_hi = mid + 1;
        },
    )
}

/// Scenario sweep: every named workload of
/// [`crate::trace::scenarios::Scenario`] × all six algorithms. The x-axis
/// is the scenario index into `Scenario::ALL` (the CLI prints the
/// index → name legend next to the table).
pub fn fig_scenarios(base: &ExperimentConfig, opts: &SweepOptions) -> crate::Result<Figure> {
    use crate::trace::scenarios::Scenario;
    let settings: Vec<f64> = (0..Scenario::ALL.len()).map(|i| i as f64).collect();
    run_figure(
        "fig-scenarios".into(),
        "scenario",
        base,
        &settings,
        opts,
        &|cfg, idx| {
            Scenario::ALL[idx as usize].apply(cfg);
        },
    )
}

/// Topology-locality sweep: mean JCT and per-tier hit rates as the
/// top-tier locality penalty grows, under a hierarchical topology (serial
/// single-trial path; see [`fig_topology_opts`]).
pub fn fig_topology(base: &ExperimentConfig, penalties: &[f64]) -> crate::Result<Figure> {
    fig_topology_opts(base, penalties, &SweepOptions::default())
}

/// Topology-locality sweep with explicit execution options. Forces the
/// DES engine (locality is engine-only) and, when the base config still
/// has the flat topology, a multi-rack hierarchy so the sweep actually
/// exercises intermediate tiers. Penalty 1 reproduces the locality-free
/// baseline; growing penalties show where the OBTA/WF/RD ranking flips.
pub fn fig_topology_opts(
    base: &ExperimentConfig,
    penalties: &[f64],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    use crate::des::service::EngineKind;
    use crate::topology::TopologyKind;
    run_figure(
        "fig-topology-locality".into(),
        "penalty",
        base,
        penalties,
        opts,
        &|cfg, p| {
            cfg.sim.engine = EngineKind::Des;
            if cfg.sim.topology == TopologyKind::Flat {
                cfg.sim.topology = TopologyKind::MultiRack;
            }
            cfg.sim.locality_penalty = p;
        },
    )
}

/// Replication-frontier sweep: mean/p99 JCT and the wasted-work fraction
/// as the replica-set size K grows, under one service model (serial
/// single-trial path; see [`fig_replication_opts`]).
pub fn fig_replication(
    base: &ExperimentConfig,
    service: crate::des::service::ServiceModel,
    ks: &[usize],
) -> crate::Result<Figure> {
    fig_replication_opts(base, service, ks, &SweepOptions::default())
}

/// Replication-frontier sweep with explicit execution options. Forces the
/// DES engine (replication is engine-only), applies the given service
/// model, and — when the base config leaves the tail threshold unarmed
/// under a tail/idle budget — arms `speculate = 1.5` so the sweep
/// actually forks. K = 1 is the racing-off baseline (bit-identical to no
/// speculation); K = 2 is the legacy one-sibling pair engine; higher K
/// trades wasted work for tail latency — the Wang–Joshi–Wornell frontier.
pub fn fig_replication_opts(
    base: &ExperimentConfig,
    service: crate::des::service::ServiceModel,
    ks: &[usize],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    use crate::des::service::{EngineKind, ReplicationBudget, ServiceModel};
    let settings: Vec<f64> = ks.iter().map(|&k| k as f64).collect();
    let tag = match service {
        ServiceModel::Deterministic => "det",
        ServiceModel::Exp { .. } => "exp",
        ServiceModel::ParetoTail { .. } => "pareto",
    };
    run_figure(
        format!("fig-replication-{tag}"),
        "k",
        base,
        &settings,
        opts,
        &|cfg, k| {
            cfg.sim.engine = EngineKind::Des;
            cfg.sim.service = service;
            cfg.sim.replicas = (k as usize).max(1);
            if cfg.sim.speculate == 0.0
                && cfg.sim.replication_budget != ReplicationBudget::Always
            {
                cfg.sim.speculate = 1.5;
            }
        },
    )
}

/// Baseline-panel sweep: mean JCT versus offered load (utilization) at
/// α = 2, canonically over the full extended panel — the paper's six
/// algorithms plus delay scheduling, JSQ, JSQ-affinity and MaxWeight
/// (serial single-trial path; see [`fig_baselines_opts`]).
pub fn fig_baselines(base: &ExperimentConfig, utils: &[f64]) -> crate::Result<Figure> {
    fig_baselines_opts(
        base,
        utils,
        &SweepOptions::default().with_policies(PolicySet::extended()),
    )
}

/// Baseline-panel sweep with explicit execution options. The panel comes
/// from `opts.policies` like every other sweep, so `--policies` can
/// narrow or reorder it.
pub fn fig_baselines_opts(
    base: &ExperimentConfig,
    utils: &[f64],
    opts: &SweepOptions,
) -> crate::Result<Figure> {
    run_figure(
        "fig-baselines-load".into(),
        "util",
        base,
        utils,
        opts,
        &|cfg, util| {
            cfg.cluster.zipf_alpha = 2.0;
            cfg.trace.utilization = util;
        },
    )
}

/// A scaled-down base config for quick runs (CI, `--quick`): same
/// structure as the paper's setup, smaller trace.
pub fn quick_base(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.jobs = 40;
    cfg.trace.total_tasks = 4_000;
    cfg.cluster.servers = 40;
    cfg.cluster.avail_lo = 4;
    cfg.cluster.avail_hi = 6;
    cfg.seed = seed;
    cfg
}

/// The paper-scale base config (250 jobs, 113,653 tasks, 100 servers).
pub fn paper_base(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.seed = seed;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_alpha_sweep_has_all_cells() {
        let base = quick_base(7);
        let fig = fig_alpha_util(&base, 0.5, &[0.0, 2.0]).unwrap();
        assert_eq!(fig.cells.len(), 2 * 6);
        assert_eq!(fig.settings(), vec![0.0, 2.0]);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0);
            assert!(!c.cdf.is_empty());
            // Percentiles ride along from the pooled JCTs.
            assert!(c.p50_jct > 0.0 && c.p50_jct <= c.p99_jct, "{}", c.policy);
        }
        let text = fig.render();
        assert!(text.contains("obta"));
        assert!(text.contains("ocwf-acc"));
        assert!(text.contains("p50/p99"), "percentile table rendered");
    }

    #[test]
    fn reordering_beats_fifo_at_high_skew() {
        // The paper's central qualitative claim (Figs 10-12): at α = 2 the
        // reordered algorithms achieve far lower mean JCT than FIFO WF.
        let base = quick_base(11);
        let fig = fig_alpha_util(&base, 0.75, &[2.0]).unwrap();
        let wf = fig.cell("wf", 2.0).unwrap().mean_jct;
        let ocwf = fig.cell("ocwf", 2.0).unwrap().mean_jct;
        assert!(
            ocwf < wf,
            "reordering must win under skew: ocwf {ocwf} vs wf {wf}"
        );
    }

    #[test]
    fn figure_json_parses() {
        let base = quick_base(5);
        let fig = fig_servers(&base, &[4]).unwrap();
        let j = fig.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert!(cells.len() == 6);
        for c in cells {
            assert!(c.get("p50_jct").is_some(), "percentiles exported");
            assert!(c.get("p99_jct").is_some());
        }
    }

    #[test]
    fn hot_cell_surfaces_its_coordinates_instead_of_aborting() {
        // One cell with an impossible horizon: run_specs must return an
        // Error::Sim naming the cell (policy, setting, trial, seed), not
        // kill the process — and the report must be deterministic (first
        // failing cell in spec order) at any thread count.
        let mut cfg = quick_base(21);
        cfg.sim.max_slots = 1;
        let specs = vec![
            CellSpec {
                cfg: cfg.clone(),
                policy: SchedPolicy::fifo(crate::assign::AssignPolicy::Wf),
                setting: 0.5,
                trial: 3,
            },
            CellSpec {
                cfg,
                policy: SchedPolicy::ocwf(true),
                setting: 0.5,
                trial: 0,
            },
        ];
        for threads in [1, 4] {
            let err = run_specs(&specs, threads).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("sweep cell failed"), "{msg}");
            assert!(msg.contains("policy wf"), "first failing cell: {msg}");
            assert!(msg.contains("trial 3"), "{msg}");
            assert!(msg.contains("seed 21"), "{msg}");
        }
    }

    #[test]
    fn trial_seeds_distinct_and_stable() {
        assert_eq!(trial_seed(42, 0), 42, "trial 0 must keep the base seed");
        let mut seen = std::collections::BTreeSet::new();
        for t in 0..64 {
            assert!(seen.insert(trial_seed(42, t)), "collision at trial {t}");
        }
        assert_eq!(trial_seed(42, 7), trial_seed(42, 7));
        assert_ne!(trial_seed(42, 7), trial_seed(43, 7));
    }

    #[test]
    fn specs_grouped_by_trial_runs() {
        let base = quick_base(3);
        let specs = specs_for(&base, &[0.0, 1.0], 2, &PolicySet::paper(), &|cfg, a| {
            cfg.cluster.zipf_alpha = a;
        });
        assert_eq!(specs.len(), 2 * 6 * 2);
        // Consecutive trials share (setting, policy), differ in seed.
        assert_eq!(specs[0].setting, specs[1].setting);
        assert_eq!(specs[0].policy.name(), specs[1].policy.name());
        assert_eq!(specs[0].trial, 0);
        assert_eq!(specs[1].trial, 1);
        assert_ne!(specs[0].cfg.seed, specs[1].cfg.seed);
        assert_eq!(specs[0].cfg.seed, base.seed);
    }

    #[test]
    fn multi_trial_cells_average() {
        let base = quick_base(9);
        let fig = fig_alpha_util_opts(
            &base,
            0.5,
            &[1.0],
            &SweepOptions::default().with_trials(2).with_threads(2),
        )
        .unwrap();
        assert_eq!(fig.cells.len(), 6);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0);
            // Pooled CDF covers 2 × 40 jobs.
            assert!(!c.cdf.is_empty());
        }
    }

    #[test]
    fn topology_sweep_reports_tier_hit_rates() {
        let base = quick_base(17);
        let fig = fig_topology_opts(
            &base,
            &[1.0, 4.0],
            &SweepOptions::default().with_threads(0),
        )
        .unwrap();
        assert_eq!(fig.cells.len(), 2 * 6);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0, "{}", c.policy);
            if c.setting == 1.0 {
                // Penalty 1 takes the locality-free DES path: no telemetry.
                assert!(c.tier_tasks.is_empty(), "{}", c.policy);
            } else {
                // Multi-rack = 3 tiers, every task credited exactly once.
                assert_eq!(c.tier_tasks.len(), 3, "{}", c.policy);
                assert!(c.tier_tasks.iter().sum::<u64>() > 0, "{}", c.policy);
            }
        }
        let text = fig.render();
        assert!(text.contains("locality tier hit rates"), "{text}");
        let parsed =
            crate::util::json::Json::parse(&fig.to_json().to_string()).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert!(cells
            .iter()
            .any(|c| c.get("tier_tasks").is_some()));
    }

    #[test]
    fn replication_sweep_reports_wasted_work() {
        use crate::des::service::ServiceModel;
        let base = quick_base(19);
        let fig = fig_replication_opts(
            &base,
            ServiceModel::ParetoTail {
                alpha: 0.9,
                cap: 20.0,
            },
            &[1, 3],
            &SweepOptions::default().with_threads(0),
        )
        .unwrap();
        assert_eq!(fig.cells.len(), 2 * 6);
        let mut any_wasted = false;
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0, "{}", c.policy);
            assert!(c.busy_work > 0, "DES cells track busy time: {}", c.policy);
            if c.setting == 1.0 {
                // K = 1 is the racing-off baseline: nothing ever forks.
                assert_eq!(c.wasted_work, 0, "{}", c.policy);
            } else {
                any_wasted |= c.wasted_work > 0;
                assert!(c.wasted_work <= c.busy_work, "{}", c.policy);
            }
        }
        assert!(any_wasted, "a Pareto tail at K = 3 must burn some replicas");
        let text = fig.render();
        assert!(text.contains("wasted work"), "{text}");
        let parsed = crate::util::json::Json::parse(&fig.to_json().to_string()).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert!(cells.iter().all(|c| c.get("wasted_work").is_some()
            && c.get("busy_work").is_some()
            && c.get("wasted_frac").is_some()));
    }

    #[test]
    fn render_iterates_only_policies_present() {
        // A narrowed `--policies` sweep must not render ghost rows for
        // absent policies.
        let base = quick_base(23);
        let opts =
            SweepOptions::default().with_policies(PolicySet::parse("obta,jsq").unwrap());
        let fig = fig_alpha_util_opts(&base, 0.5, &[0.0], &opts).unwrap();
        assert_eq!(fig.cells.len(), 2);
        assert_eq!(fig.policies(), vec!["obta", "jsq"]);
        let text = fig.render();
        assert!(text.contains("obta") && text.contains("jsq"), "{text}");
        assert!(!text.contains("ocwf"), "ghost row for absent policy:\n{text}");
        assert!(!text.contains("nlip"), "ghost row for absent policy:\n{text}");
    }

    #[test]
    fn baselines_sweep_runs_the_extended_panel() {
        let base = quick_base(29);
        let fig = fig_baselines(&base, &[0.5]).unwrap();
        assert_eq!(fig.cells.len(), SchedPolicy::EXTENDED.len());
        // Cells come out in registry panel order, every metric live.
        let names: Vec<_> = fig.cells.iter().map(|c| c.policy).collect();
        let expect: Vec<_> = SchedPolicy::EXTENDED.iter().map(|p| p.name()).collect();
        assert_eq!(names, expect);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0, "{}", c.policy);
            assert!(!c.cdf.is_empty(), "{}", c.policy);
        }
        let text = fig.render();
        assert!(text.contains("maxweight") && text.contains("jsq-affinity"), "{text}");
    }

    #[test]
    fn scenario_sweep_covers_catalog() {
        use crate::trace::scenarios::Scenario;
        let base = quick_base(13);
        let fig = fig_scenarios(&base, &SweepOptions::default().with_threads(0)).unwrap();
        assert_eq!(fig.cells.len(), Scenario::ALL.len() * 6);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0, "{}", c.policy);
        }
    }
}
