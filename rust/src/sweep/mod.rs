//! Experiment sweeps that regenerate every table and figure of the
//! paper's evaluation (§V). Shared by the `taos repro` CLI subcommand and
//! the `cargo bench` figure harnesses.
//!
//! | Paper artifact | Function |
//! |---|---|
//! | Fig 10 (25% util) | [`fig_alpha_util`] with `util = 0.25` |
//! | Fig 11 (50% util) | [`fig_alpha_util`] with `util = 0.50` |
//! | Fig 12 (75% util) | [`fig_alpha_util`] with `util = 0.75` |
//! | Fig 13 + Table I | [`fig_servers`] |
//! | Fig 14 | [`fig_capacity`] |

use crate::benchlib::TextTable;
use crate::config::ExperimentConfig;
use crate::metrics::jct_cdf;
use crate::sched::SchedPolicy;
use crate::sim::run_experiment;
use crate::util::json::Json;

/// Result of one (policy, setting) cell: the paper's two metrics plus the
/// CDF series for the CDF subplots.
#[derive(Clone, Debug)]
pub struct Cell {
    pub policy: &'static str,
    pub setting: f64,
    pub mean_jct: f64,
    pub overhead_us: f64,
    pub cdf: Vec<(f64, f64)>,
}

/// A complete figure: one cell per (policy, x-axis setting).
#[derive(Clone, Debug)]
pub struct Figure {
    pub name: String,
    pub x_label: &'static str,
    pub cells: Vec<Cell>,
}

impl Figure {
    /// The x-axis values, deduped in order.
    pub fn settings(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = Vec::new();
        for c in &self.cells {
            if !xs.iter().any(|&x| x == c.setting) {
                xs.push(c.setting);
            }
        }
        xs
    }

    pub fn cell(&self, policy: &str, setting: f64) -> Option<&Cell> {
        self.cells
            .iter()
            .find(|c| c.policy == policy && c.setting == setting)
    }

    /// Render the figure's headline table: mean JCT (and overhead) per
    /// algorithm × setting, exactly the rows the paper plots.
    pub fn render(&self) -> String {
        let settings = self.settings();
        let mut header: Vec<String> = vec!["algorithm".into()];
        for s in &settings {
            header.push(format!("{}={}", self.x_label, s));
        }
        header.push("avg".into());
        let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();

        let mut out = format!("== {} : mean JCT (slots) ==\n", self.name);
        let mut t = TextTable::new(&hdr_refs);
        for policy in SchedPolicy::ALL {
            let mut row = vec![policy.name().to_string()];
            let mut sum = 0.0;
            let mut cnt = 0;
            for &s in &settings {
                match self.cell(policy.name(), s) {
                    Some(c) => {
                        row.push(format!("{:.0}", c.mean_jct));
                        sum += c.mean_jct;
                        cnt += 1;
                    }
                    None => row.push("-".into()),
                }
            }
            row.push(if cnt > 0 {
                format!("{:.0}", sum / cnt as f64)
            } else {
                "-".into()
            });
            t.row(row);
        }
        out.push_str(&t.render());

        out.push_str(&format!("\n== {} : overhead per arrival (us) ==\n", self.name));
        let mut t2 = TextTable::new(&hdr_refs);
        for policy in SchedPolicy::ALL {
            let mut row = vec![policy.name().to_string()];
            let mut sum = 0.0;
            let mut cnt = 0;
            for &s in &settings {
                match self.cell(policy.name(), s) {
                    Some(c) => {
                        row.push(format!("{:.1}", c.overhead_us));
                        sum += c.overhead_us;
                        cnt += 1;
                    }
                    None => row.push("-".into()),
                }
            }
            row.push(if cnt > 0 {
                format!("{:.1}", sum / cnt as f64)
            } else {
                "-".into()
            });
            t2.row(row);
        }
        out.push_str(&t2.render());
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("x_label", Json::str(self.x_label)),
            (
                "cells",
                Json::arr(self.cells.iter().map(|c| {
                    Json::obj(vec![
                        ("policy", Json::str(c.policy)),
                        ("setting", Json::num(c.setting)),
                        ("mean_jct", Json::num(c.mean_jct)),
                        ("overhead_us", Json::num(c.overhead_us)),
                        (
                            "cdf",
                            Json::arr(c.cdf.iter().map(|&(x, y)| {
                                Json::arr(vec![Json::num(x), Json::num(y)])
                            })),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Run one (config, policy) cell.
fn run_cell(cfg: &ExperimentConfig, policy: SchedPolicy, setting: f64) -> Cell {
    let out = run_experiment(cfg, policy).expect("sweep cell failed");
    Cell {
        policy: policy.name(),
        setting,
        mean_jct: out.mean_jct(),
        overhead_us: out.overhead.mean_us(),
        cdf: jct_cdf(&out.jcts, 64),
    }
}

/// Figs 10–12: sweep Zipf α at fixed utilization, all six algorithms.
pub fn fig_alpha_util(base: &ExperimentConfig, util: f64, alphas: &[f64]) -> Figure {
    let mut cells = Vec::new();
    for &alpha in alphas {
        let mut cfg = base.clone();
        cfg.cluster.zipf_alpha = alpha;
        cfg.trace.utilization = util;
        for policy in SchedPolicy::ALL {
            cells.push(run_cell(&cfg, policy, alpha));
        }
    }
    Figure {
        name: format!("fig-alpha-util-{:.0}%", util * 100.0),
        x_label: "alpha",
        cells,
    }
}

/// Fig 13 + Table I: sweep the number of available servers p at α = 2,
/// 75% utilization (the paper fixes p per sweep point: avail_lo =
/// avail_hi = p).
pub fn fig_servers(base: &ExperimentConfig, ps: &[usize]) -> Figure {
    let mut cells = Vec::new();
    for &p in ps {
        let mut cfg = base.clone();
        cfg.cluster.zipf_alpha = 2.0;
        cfg.trace.utilization = 0.75;
        cfg.cluster.avail_lo = p;
        cfg.cluster.avail_hi = p;
        for policy in SchedPolicy::ALL {
            cells.push(run_cell(&cfg, policy, p as f64));
        }
    }
    Figure {
        name: "fig13-table1-available-servers".into(),
        x_label: "p",
        cells,
    }
}

/// Fig 14: sweep computing capacity (μ ranges centred on the x value) at
/// α = 2, 75% utilization.
pub fn fig_capacity(base: &ExperimentConfig, mu_mids: &[u64]) -> Figure {
    let mut cells = Vec::new();
    for &mid in mu_mids {
        let mut cfg = base.clone();
        cfg.cluster.zipf_alpha = 2.0;
        cfg.trace.utilization = 0.75;
        cfg.cluster.mu_lo = mid - 1;
        cfg.cluster.mu_hi = mid + 1;
        for policy in SchedPolicy::ALL {
            cells.push(run_cell(&cfg, policy, mid as f64));
        }
    }
    Figure {
        name: "fig14-computing-capacity".into(),
        x_label: "mu",
        cells,
    }
}

/// A scaled-down base config for quick runs (CI, `--quick`): same
/// structure as the paper's setup, smaller trace.
pub fn quick_base(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.trace.jobs = 40;
    cfg.trace.total_tasks = 4_000;
    cfg.cluster.servers = 40;
    cfg.cluster.avail_lo = 4;
    cfg.cluster.avail_hi = 6;
    cfg.seed = seed;
    cfg
}

/// The paper-scale base config (250 jobs, 113,653 tasks, 100 servers).
pub fn paper_base(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.seed = seed;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_alpha_sweep_has_all_cells() {
        let base = quick_base(7);
        let fig = fig_alpha_util(&base, 0.5, &[0.0, 2.0]);
        assert_eq!(fig.cells.len(), 2 * 6);
        assert_eq!(fig.settings(), vec![0.0, 2.0]);
        for c in &fig.cells {
            assert!(c.mean_jct.is_finite() && c.mean_jct > 0.0);
            assert!(!c.cdf.is_empty());
        }
        let text = fig.render();
        assert!(text.contains("obta"));
        assert!(text.contains("ocwf-acc"));
    }

    #[test]
    fn reordering_beats_fifo_at_high_skew() {
        // The paper's central qualitative claim (Figs 10-12): at α = 2 the
        // reordered algorithms achieve far lower mean JCT than FIFO WF.
        let base = quick_base(11);
        let fig = fig_alpha_util(&base, 0.75, &[2.0]);
        let wf = fig.cell("wf", 2.0).unwrap().mean_jct;
        let ocwf = fig.cell("ocwf", 2.0).unwrap().mean_jct;
        assert!(
            ocwf < wf,
            "reordering must win under skew: ocwf {ocwf} vs wf {wf}"
        );
    }

    #[test]
    fn figure_json_parses() {
        let base = quick_base(5);
        let fig = fig_servers(&base, &[4]);
        let j = fig.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert!(parsed.get("cells").unwrap().as_arr().unwrap().len() == 6);
    }
}
