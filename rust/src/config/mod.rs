//! Experiment configuration: typed config structs with the paper's default
//! parameters (§V-A), plus a small `key = value` config-file parser (TOML
//! subset) so experiments are scriptable without `serde`/`toml`.

use crate::assign::{AssignParams, DEFAULT_DELAY_BOUND};
use crate::cluster::placement::PlacementMode;
use crate::des::calendar::EventQueueKind;
use crate::des::service::{EngineKind, ReplicationBudget, ServiceModel};
use crate::job::Slots;
use crate::sched::PolicySet;
use crate::topology::TopologyKind;
use crate::trace::scenarios::Scenario;
use crate::{Error, Result};

/// Cluster shape and data placement (paper §II and §V-A).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of servers M. Paper default: 100.
    pub servers: usize,
    /// Zipf skew α ∈ [0, 2] for placing task-group inputs (0 = uniform).
    pub zipf_alpha: f64,
    /// Number of available servers per task group: uniform in
    /// [avail_lo, avail_hi]. Paper default: [8, 12].
    pub avail_lo: usize,
    pub avail_hi: usize,
    /// Per-(server, job) computing capacity μ_m^c: uniform integer in
    /// [mu_lo, mu_hi]. Paper default: [3, 5].
    pub mu_lo: u64,
    pub mu_hi: u64,
    /// Server-speed heterogeneity: 0 (default) gives the paper's i.i.d.
    /// uniform capacities; s > 0 multiplies each server's μ by a fixed
    /// Zipf(s)-shaped speed factor (normalized to mean 1, assigned in a
    /// random server order), so a few servers are fast and the long tail
    /// is slow (`hetero-cap` scenario).
    pub mu_skew: f64,
    /// How available-server sets grow from their Zipf anchor: contiguous
    /// `ring` (paper §V-A) or per-replica `scatter` (`hotspot` scenario).
    pub placement_mode: PlacementMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            servers: 100,
            zipf_alpha: 0.0,
            avail_lo: 8,
            avail_hi: 12,
            mu_lo: 3,
            mu_hi: 5,
            mu_skew: 0.0,
            placement_mode: PlacementMode::Ring,
        }
    }
}

/// Trace generation / loading parameters (paper §V-A).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Number of jobs. Paper default: 250.
    pub jobs: usize,
    /// Target total number of tasks across all jobs. Paper: 113,653.
    pub total_tasks: usize,
    /// Mean task groups per job. Paper: 5.52.
    pub mean_groups: f64,
    /// Target system utilization ρ ∈ (0, 1): the job interarrival times are
    /// scaled so offered load / cluster capacity ≈ ρ. Paper: 0.25–0.75.
    pub utilization: f64,
    /// Optional path to a real `batch_task.csv` segment
    /// (cluster-trace-v2017 schema); when set, jobs/groups come from the
    /// file and only interarrival scaling is synthetic.
    pub csv_path: Option<String>,
    /// Named workload shape for synthetic traces (ignored when `csv_path`
    /// is set). See [`crate::trace::scenarios`] for the catalog.
    pub scenario: Scenario,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            jobs: 250,
            total_tasks: 113_653,
            mean_groups: 5.52,
            utilization: 0.5,
            csv_path: None,
            scenario: Scenario::Alibaba,
        }
    }
}

/// Simulator knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Safety cap on simulated slots (guards against runaway configs).
    pub max_slots: u64,
    /// Record per-job completion times (needed for CDFs).
    pub record_jct: bool,
    /// Worker threads for the OCWF(-ACC) reorder rounds (0 = all cores,
    /// 1 = serial). Schedules are bit-identical at any value; this is a
    /// wall-clock knob only. Composes freely with a sweep's `--threads`:
    /// both levels run on the process-wide executor, whose admission
    /// budget lends a nested reorder fan-out **idle workers only** — a
    /// saturated pool admits zero helpers and the submitting cell drains
    /// its own round — so `threads × reorder_threads` can never
    /// oversubscribe the machine.
    pub reorder_threads: usize,
    /// Fixed OCWF-ACC speculation depth for parallel reorder rounds
    /// (`0` = adaptive, sized per round from the observed early-exit
    /// depth). Like `reorder_threads`, a pure wall-clock knob: schedules
    /// are bit-identical at any value.
    pub acc_spec_chunk: usize,
    /// Which engine replays the trace: the analytic busy-time recursion
    /// (default) or the discrete-event engine (`crate::des`). With
    /// deterministic service and no engine-only mechanisms the two are
    /// bit-identical (`rust/tests/des_equivalence.rs`).
    pub engine: EngineKind,
    /// DES-only event core: the pooled binary heap (default) or the
    /// calendar queue (`--event-queue calendar`), the O(1)-amortized
    /// streaming-scale core. Pop order — and therefore every JCT vector
    /// — is bit-identical under either (`rust/tests/streaming_scale.rs`),
    /// so this is a pure wall-clock knob; `calendar` requires
    /// `engine = des`.
    pub event_queue: EventQueueKind,
    /// DES-only service-time model (`det` | `exp:MEAN` |
    /// `pareto:ALPHA:CAP`). Non-deterministic models require `engine =
    /// des`.
    pub service: ServiceModel,
    /// DES-only multi-level locality: when > 1, every server may run
    /// every task, but tasks executed outside their group's data-local
    /// server set run at `μ / tier_penalty`, where the tier comes from
    /// [`crate::topology`] and the top tier charges the full penalty.
    /// `1.0` disables the mechanism; values > 1 require `engine = des`.
    pub locality_penalty: f64,
    /// Network-cost hierarchy grading the locality penalty (`flat` |
    /// `multi-rack` | `multi-zone` | `fat-tree`). `flat` (default) is
    /// the scalar two-level model; non-flat topologies require
    /// `engine = des` (they only affect the locality mechanism).
    pub topology: TopologyKind,
    /// DES-only straggler speculation threshold (0 = off): the tail
    /// criterion of the replication budget, and — when `replicas` is left
    /// at 0 — the K = 2 alias (one racing replica, first completion
    /// cancels the loser, the pre-k-replica behavior bit for bit).
    /// Values > 0 require `engine = des`.
    pub speculate: f64,
    /// DES-only replica-set size K: 0 (default) derives K from
    /// `speculate` (2 when armed, else 1 = off); 1 disables racing even
    /// with `speculate` set; K >= 2 forks up to K − 1 replicas per
    /// budget-passing entry. Values >= 2 require `engine = des`.
    pub replicas: usize,
    /// DES-only replication budget gating the forks (`tail` | `idle` |
    /// `always`, see [`ReplicationBudget`]). `tail` is the legacy
    /// `speculate` gate; non-default values require `engine = des`.
    pub replication_budget: ReplicationBudget,
    /// Delay-scheduling bound D in slots (`delay` baseline, CLI
    /// `--delay-bound`): a chunk stays on a replica holder while the
    /// holder's estimated queue is <= D, and spills to the shortest
    /// eligible queue past it. Other policies ignore the knob.
    pub delay_bound: Slots,
    /// Heartbeat period for long runs (CLI `--progress`): every N
    /// processed events (DES) or admitted jobs (streaming fold) a
    /// one-line progress report goes to *stderr*. 0 (the default)
    /// disables it; stdout artifacts are never touched.
    pub progress_every: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_slots: 50_000_000,
            record_jct: true,
            reorder_threads: 1,
            acc_spec_chunk: 0,
            engine: EngineKind::Analytic,
            event_queue: EventQueueKind::Heap,
            service: ServiceModel::Deterministic,
            locality_penalty: 1.0,
            topology: TopologyKind::Flat,
            speculate: 0.0,
            replicas: 0,
            replication_budget: ReplicationBudget::Tail,
            delay_bound: DEFAULT_DELAY_BOUND,
            progress_every: 0,
        }
    }
}

impl SimConfig {
    /// Effective replica-set size K: `replicas` when set explicitly,
    /// otherwise the `speculate` K = 2 alias (or 1 = racing off).
    pub fn effective_replicas(&self) -> usize {
        if self.replicas > 0 {
            self.replicas
        } else if self.speculate > 0.0 {
            2
        } else {
            1
        }
    }

    /// Assigner construction parameters carried by this config.
    pub fn assign_params(&self) -> AssignParams {
        AssignParams {
            delay_bound: self.delay_bound,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub trace: TraceConfig,
    pub sim: SimConfig,
    /// Which scheduling policies a sweep runs (`policies` key, CLI
    /// `--policies`). Defaults to the paper's six-policy panel so
    /// existing figures stay byte-identical; see
    /// [`crate::sched::REGISTRY`] for the full catalog.
    pub policies: PolicySet,
    /// Master seed for all randomness.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Validate invariants; call after construction/parsing.
    pub fn validate(&self) -> Result<()> {
        let c = &self.cluster;
        if c.servers == 0 {
            return Err(Error::Config("servers must be > 0".into()));
        }
        if c.avail_lo == 0 || c.avail_lo > c.avail_hi || c.avail_hi > c.servers {
            return Err(Error::Config(format!(
                "available-server range [{}, {}] invalid for {} servers",
                c.avail_lo, c.avail_hi, c.servers
            )));
        }
        if c.mu_lo == 0 || c.mu_lo > c.mu_hi {
            return Err(Error::Config("mu range invalid".into()));
        }
        if !(0.0..=2.0).contains(&c.zipf_alpha) {
            return Err(Error::Config("zipf_alpha must be in [0, 2]".into()));
        }
        if !(0.0..=4.0).contains(&c.mu_skew) {
            return Err(Error::Config("mu_skew must be in [0, 4]".into()));
        }
        let t = &self.trace;
        if t.jobs == 0 || t.total_tasks < t.jobs {
            return Err(Error::Config("trace must have >= 1 task per job".into()));
        }
        if !(t.utilization > 0.0 && t.utilization < 1.0) {
            return Err(Error::Config("utilization must be in (0, 1)".into()));
        }
        if t.mean_groups < 1.0 {
            return Err(Error::Config("mean_groups must be >= 1".into()));
        }
        let s = &self.sim;
        s.service.validate().map_err(Error::Config)?;
        if !(s.locality_penalty.is_finite() && (1.0..=1000.0).contains(&s.locality_penalty)) {
            return Err(Error::Config(format!(
                "locality_penalty must be in [1, 1000], got {}",
                s.locality_penalty
            )));
        }
        if !(s.speculate.is_finite() && (s.speculate == 0.0 || s.speculate >= 1.0)) {
            return Err(Error::Config(format!(
                "speculate must be 0 (off) or >= 1, got {}",
                s.speculate
            )));
        }
        if s.replicas > 16 {
            return Err(Error::Config(format!(
                "replicas must be in [0, 16] (0 = derive from speculate), got {}",
                s.replicas
            )));
        }
        if s.replicas >= 2
            && s.speculate == 0.0
            && s.replication_budget != ReplicationBudget::Always
        {
            return Err(Error::Config(format!(
                "replicas = {} under the `{}` budget never forks: the tail \
                 criterion needs speculate >= 1, or use replication_budget = \
                 always",
                s.replicas,
                s.replication_budget.name()
            )));
        }
        if s.engine == EngineKind::Analytic
            && (!s.service.is_deterministic()
                || s.locality_penalty > 1.0
                || s.topology != TopologyKind::Flat
                || s.speculate > 0.0
                || s.replicas >= 2
                || s.replication_budget != ReplicationBudget::Tail
                || s.event_queue != EventQueueKind::Heap)
        {
            return Err(Error::Config(
                "service models, locality_penalty > 1, non-flat topology, \
                 speculate > 0, replicas >= 2, a non-tail replication budget \
                 and event_queue = calendar are engine-only mechanisms: set \
                 engine = des (--engine des)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments (outside
    /// double-quoted values), section headers `[cluster] [trace] [sim]`
    /// optional (keys are unambiguous).
    pub fn from_str(text: &str) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| Error::TraceParse {
                line: lineno + 1,
                msg: format!("expected key = value, got `{line}`"),
            })?;
            let key = key.trim();
            let val = val.trim().trim_matches('"');
            let perr = |msg: &str| Error::TraceParse {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            match key {
                "servers" => cfg.cluster.servers = val.parse().map_err(|_| perr("bad usize"))?,
                "zipf_alpha" => cfg.cluster.zipf_alpha = val.parse().map_err(|_| perr("bad f64"))?,
                "avail_lo" => cfg.cluster.avail_lo = val.parse().map_err(|_| perr("bad usize"))?,
                "avail_hi" => cfg.cluster.avail_hi = val.parse().map_err(|_| perr("bad usize"))?,
                "mu_lo" => cfg.cluster.mu_lo = val.parse().map_err(|_| perr("bad u64"))?,
                "mu_hi" => cfg.cluster.mu_hi = val.parse().map_err(|_| perr("bad u64"))?,
                "mu_skew" => cfg.cluster.mu_skew = val.parse().map_err(|_| perr("bad f64"))?,
                "placement" => {
                    cfg.cluster.placement_mode = PlacementMode::parse(val)
                        .ok_or_else(|| perr("placement must be `ring` or `scatter`"))?
                }
                // `scenario` applies the named workload's whole knob set
                // (trace shape + cluster skew); later explicit keys still
                // override individual knobs.
                "scenario" => {
                    let sc = Scenario::parse(val)
                        .ok_or_else(|| perr("unknown scenario (see `taos repro --fig scenarios`)"))?;
                    sc.apply(&mut cfg);
                }
                "jobs" => cfg.trace.jobs = val.parse().map_err(|_| perr("bad usize"))?,
                "total_tasks" => cfg.trace.total_tasks = val.parse().map_err(|_| perr("bad usize"))?,
                "mean_groups" => cfg.trace.mean_groups = val.parse().map_err(|_| perr("bad f64"))?,
                "utilization" => cfg.trace.utilization = val.parse().map_err(|_| perr("bad f64"))?,
                "csv_path" => cfg.trace.csv_path = Some(val.to_string()),
                "max_slots" => cfg.sim.max_slots = val.parse().map_err(|_| perr("bad u64"))?,
                "record_jct" => cfg.sim.record_jct = val.parse().map_err(|_| perr("bad bool"))?,
                "reorder_threads" => {
                    cfg.sim.reorder_threads = val.parse().map_err(|_| perr("bad usize"))?
                }
                "acc_spec_chunk" => {
                    cfg.sim.acc_spec_chunk = val.parse().map_err(|_| perr("bad usize"))?
                }
                "engine" => {
                    cfg.sim.engine = EngineKind::parse(val)
                        .ok_or_else(|| perr("engine must be `analytic` or `des`"))?
                }
                "event_queue" => {
                    cfg.sim.event_queue = EventQueueKind::parse(val)
                        .ok_or_else(|| perr("event_queue must be `heap` or `calendar`"))?
                }
                "service" => {
                    cfg.sim.service = ServiceModel::parse(val).ok_or_else(|| {
                        perr("service must be `det`, `exp:MEAN` or `pareto:ALPHA:CAP`")
                    })?
                }
                "locality_penalty" => {
                    cfg.sim.locality_penalty = val.parse().map_err(|_| perr("bad f64"))?
                }
                "topology" => {
                    cfg.sim.topology = TopologyKind::parse(val).ok_or_else(|| {
                        perr("topology must be `flat`, `multi-rack`, `multi-zone` or `fat-tree`")
                    })?
                }
                "delay_bound" => {
                    cfg.sim.delay_bound = val.parse().map_err(|_| perr("bad u64"))?
                }
                "progress_every" => {
                    cfg.sim.progress_every = val.parse().map_err(|_| perr("bad u64"))?
                }
                "policies" => {
                    cfg.policies = PolicySet::parse(val).map_err(|e| perr(&e))?;
                }
                "speculate" => cfg.sim.speculate = val.parse().map_err(|_| perr("bad f64"))?,
                "replicas" => cfg.sim.replicas = val.parse().map_err(|_| perr("bad usize"))?,
                "replication_budget" => {
                    cfg.sim.replication_budget = ReplicationBudget::parse(val).ok_or_else(|| {
                        perr("replication_budget must be `tail`, `idle` or `always`")
                    })?
                }
                "seed" => cfg.seed = val.parse().map_err(|_| perr("bad u64"))?,
                other => {
                    return Err(Error::TraceParse {
                        line: lineno + 1,
                        msg: format!("unknown key `{other}`"),
                    })
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }
}

/// Strip a trailing `#` comment, honoring double quotes: a `#` inside a
/// quoted value (`csv_path = "runs#3/batch_task.csv"`) is data, not a
/// comment. (The old `split('#')` ran before unquoting and silently
/// truncated such values.) After an unbalanced opening quote the rest of
/// the line counts as quoted, so no comment is stripped from it.
fn strip_comment(line: &str) -> &str {
    let mut in_quotes = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_section_5a() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.cluster.servers, 100);
        assert_eq!((cfg.cluster.mu_lo, cfg.cluster.mu_hi), (3, 5));
        assert_eq!((cfg.cluster.avail_lo, cfg.cluster.avail_hi), (8, 12));
        assert_eq!(cfg.trace.jobs, 250);
        assert_eq!(cfg.trace.total_tasks, 113_653);
        assert!((cfg.trace.mean_groups - 5.52).abs() < 1e-9);
        cfg.validate().unwrap();
    }

    #[test]
    fn parses_config_file() {
        let text = r#"
            # experiment: figure 12
            [cluster]
            servers = 50
            zipf_alpha = 2.0
            [trace]
            jobs = 10
            total_tasks = 500
            utilization = 0.75
            seed = 99
        "#;
        let cfg = ExperimentConfig::from_str(text).unwrap();
        assert_eq!(cfg.cluster.servers, 50);
        assert_eq!(cfg.cluster.zipf_alpha, 2.0);
        assert_eq!(cfg.trace.jobs, 10);
        assert_eq!(cfg.seed, 99);
        // Unset keys keep defaults.
        assert_eq!(cfg.cluster.mu_lo, 3);
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(ExperimentConfig::from_str("bogus = 1").is_err());
    }

    #[test]
    fn hash_inside_quoted_value_is_not_a_comment() {
        // Regression: the parser used to split on `#` before unquoting,
        // silently truncating `"runs#3/batch_task.csv"` to `runs`.
        let cfg = ExperimentConfig::from_str(r#"csv_path = "runs#3/batch_task.csv""#).unwrap();
        assert_eq!(cfg.trace.csv_path.as_deref(), Some("runs#3/batch_task.csv"));

        // A real comment after the closing quote is still stripped.
        let cfg =
            ExperimentConfig::from_str(r##"csv_path = "a#b.csv"  # trace with a hash"##).unwrap();
        assert_eq!(cfg.trace.csv_path.as_deref(), Some("a#b.csv"));

        // Unquoted values and full-line comments keep the old behavior.
        let cfg = ExperimentConfig::from_str(
            "# leading comment\nservers = 50 # trailing comment\n",
        )
        .unwrap();
        assert_eq!(cfg.cluster.servers, 50);
    }

    #[test]
    fn rejects_invalid_ranges() {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.avail_lo = 20;
        cfg.cluster.avail_hi = 10;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.cluster.avail_hi = 1000; // > servers
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.trace.utilization = 1.5;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.cluster.mu_lo = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parses_reorder_threads_key() {
        let cfg = ExperimentConfig::from_str("reorder_threads = 4").unwrap();
        assert_eq!(cfg.sim.reorder_threads, 4);
        assert_eq!(SimConfig::default().reorder_threads, 1);
        assert!(ExperimentConfig::from_str("reorder_threads = x").is_err());
    }

    #[test]
    fn parses_acc_spec_chunk_key() {
        let cfg = ExperimentConfig::from_str("acc_spec_chunk = 16").unwrap();
        assert_eq!(cfg.sim.acc_spec_chunk, 16);
        assert_eq!(SimConfig::default().acc_spec_chunk, 0, "adaptive by default");
        assert!(ExperimentConfig::from_str("acc_spec_chunk = x").is_err());
    }

    #[test]
    fn parses_scenario_and_cluster_skew_keys() {
        let cfg = ExperimentConfig::from_str("scenario = hotspot").unwrap();
        assert_eq!(cfg.trace.scenario, Scenario::Hotspot);
        assert_eq!(cfg.cluster.placement_mode, PlacementMode::Scatter);

        let cfg = ExperimentConfig::from_str("mu_skew = 1.5\nplacement = scatter").unwrap();
        assert!((cfg.cluster.mu_skew - 1.5).abs() < 1e-12);
        assert_eq!(cfg.cluster.placement_mode, PlacementMode::Scatter);

        assert!(ExperimentConfig::from_str("scenario = bogus").is_err());
        assert!(ExperimentConfig::from_str("placement = bogus").is_err());
        assert!(ExperimentConfig::from_str("mu_skew = 99").is_err());
    }

    #[test]
    fn parses_des_engine_keys() {
        use crate::des::service::{EngineKind, ServiceModel};
        let cfg = ExperimentConfig::from_str(
            "engine = des\nservice = pareto:1.5:20\nspeculate = 2.0\nlocality_penalty = 2.5",
        )
        .unwrap();
        assert_eq!(cfg.sim.engine, EngineKind::Des);
        assert_eq!(
            cfg.sim.service,
            ServiceModel::ParetoTail {
                alpha: 1.5,
                cap: 20.0
            }
        );
        assert_eq!(cfg.sim.speculate, 2.0);
        assert_eq!(cfg.sim.locality_penalty, 2.5);

        let cfg = ExperimentConfig::from_str("engine = des\nservice = exp:1.25").unwrap();
        assert_eq!(cfg.sim.service, ServiceModel::Exp { mean: 1.25 });

        // Defaults stay analytic/deterministic/off.
        let d = SimConfig::default();
        assert_eq!(d.engine, EngineKind::Analytic);
        assert!(d.service.is_deterministic());
        assert_eq!(d.locality_penalty, 1.0);
        assert_eq!(d.speculate, 0.0);

        assert!(ExperimentConfig::from_str("engine = warp").is_err());
        assert!(ExperimentConfig::from_str("service = weibull:2").is_err());
    }

    #[test]
    fn parses_topology_key() {
        let cfg = ExperimentConfig::from_str("engine = des\ntopology = multi-rack").unwrap();
        assert_eq!(cfg.sim.topology, TopologyKind::MultiRack);
        let cfg = ExperimentConfig::from_str("engine = des\ntopology = fat_tree").unwrap();
        assert_eq!(cfg.sim.topology, TopologyKind::FatTree);
        // `flat` is the default and is valid under the analytic engine.
        assert_eq!(SimConfig::default().topology, TopologyKind::Flat);
        assert!(ExperimentConfig::from_str("topology = flat").is_ok());
        assert!(ExperimentConfig::from_str("topology = torus").is_err());
    }

    #[test]
    fn engine_only_knobs_require_des() {
        // A stochastic service model, a locality penalty, a non-flat
        // topology or speculation without engine = des cannot be honored
        // and must be rejected.
        assert!(ExperimentConfig::from_str("service = exp:1.0").is_err());
        assert!(ExperimentConfig::from_str("locality_penalty = 2.0").is_err());
        assert!(ExperimentConfig::from_str("topology = multi-zone").is_err());
        assert!(ExperimentConfig::from_str("engine = des\ntopology = multi-zone").is_ok());
        assert!(ExperimentConfig::from_str("speculate = 2.0").is_err());
        assert!(ExperimentConfig::from_str("engine = des\nservice = exp:1.0").is_ok());
        // Parameter ranges.
        assert!(ExperimentConfig::from_str("engine = des\nlocality_penalty = 0.5").is_err());
        assert!(ExperimentConfig::from_str("engine = des\nspeculate = 0.5").is_err());
        assert!(ExperimentConfig::from_str("engine = des\nservice = exp:0").is_err());
        assert!(ExperimentConfig::from_str("engine = des\nservice = pareto:1.5:0.5").is_err());
    }

    #[test]
    fn parses_replication_keys() {
        let cfg = ExperimentConfig::from_str(
            "engine = des\nservice = pareto:1.5:20\nspeculate = 2.0\n\
             replicas = 3\nreplication_budget = idle",
        )
        .unwrap();
        assert_eq!(cfg.sim.replicas, 3);
        assert_eq!(cfg.sim.replication_budget, ReplicationBudget::Idle);
        assert_eq!(cfg.sim.effective_replicas(), 3);

        // `always` forks without a tail threshold.
        let cfg =
            ExperimentConfig::from_str("engine = des\nreplicas = 4\nreplication_budget = always")
                .unwrap();
        assert_eq!(cfg.sim.effective_replicas(), 4);

        assert!(ExperimentConfig::from_str("engine = des\nreplication_budget = maybe").is_err());
        assert!(ExperimentConfig::from_str("engine = des\nreplicas = 99").is_err());
    }

    #[test]
    fn effective_replicas_speculate_alias() {
        // The K = 2 alias: speculate alone arms one racing replica;
        // an explicit replicas = 1 disables racing even with the
        // threshold set; replicas > 0 always wins over the alias.
        let mut s = SimConfig::default();
        assert_eq!(s.effective_replicas(), 1);
        s.speculate = 2.0;
        assert_eq!(s.effective_replicas(), 2);
        s.replicas = 1;
        assert_eq!(s.effective_replicas(), 1);
        s.replicas = 4;
        assert_eq!(s.effective_replicas(), 4);
    }

    #[test]
    fn replication_knobs_require_des_and_a_live_budget() {
        // Engine gate: k-replica racing and non-tail budgets are
        // DES-only mechanisms.
        assert!(ExperimentConfig::from_str("replicas = 2").is_err());
        assert!(ExperimentConfig::from_str("replication_budget = idle").is_err());
        // Footgun gate: replicas >= 2 under a tail/idle budget with
        // speculate = 0 would silently never fork.
        assert!(ExperimentConfig::from_str("engine = des\nreplicas = 2").is_err());
        assert!(
            ExperimentConfig::from_str("engine = des\nreplicas = 2\nspeculate = 1.5").is_ok()
        );
        assert!(ExperimentConfig::from_str(
            "engine = des\nreplicas = 2\nreplication_budget = always"
        )
        .is_ok());
        // replicas = 1 is "racing off" and valid anywhere.
        assert!(ExperimentConfig::from_str("replicas = 1").is_ok());
    }

    #[test]
    fn reports_line_numbers() {
        let err = ExperimentConfig::from_str("servers = 100\nbad line").unwrap_err();
        match err {
            Error::TraceParse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected error {other:?}"),
        }
    }
}
