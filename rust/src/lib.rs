//! # TAOS — Task Assignment and Ordering Scheduler
//!
//! A production-shaped reproduction of *"Data-Locality-Aware Task Assignment
//! and Scheduling for Distributed Job Executions"* (Zhao, Tang, Chen, Yin,
//! Deng, 2024).
//!
//! The library implements the paper's six algorithms — NLIP, OBTA, WF, RD,
//! OCWF and OCWF-ACC — together with every substrate they require: a Dinic
//! max-flow solver (standing in for CPLEX), a slotted discrete-event cluster
//! simulator, a Zipf data-placement model, an Alibaba-like trace generator,
//! and a PJRT runtime that executes JAX/Pallas computations AOT-compiled to
//! HLO text (see `python/compile/`).
//!
//! ## Layer map
//! - [`assign`] — per-job task assignment (the paper's §III).
//! - [`sched`] — FIFO and reordered (OCWF/OCWF-ACC, §IV) scheduling drivers.
//! - [`sim`] — the analytic engines that replay a trace at arrival
//!   instants (eq. 2 evaluated in closed form).
//! - [`des`] — the discrete-event fidelity engine: stochastic service
//!   times, straggler replica racing, hierarchical multi-level locality;
//!   its deterministic mode doubles as a bit-exact oracle for [`sim`].
//! - [`topology`] — the rack/zone/region network-cost hierarchy behind
//!   the locality model (tiered penalties, eligible sets, telemetry).
//! - [`cluster`], [`trace`], [`job`] — the system model (§II).
//! - [`flow`], [`util`], [`proptest`], [`benchlib`], [`cli`], [`config`] —
//!   substrates built from scratch (offline environment, no external deps).
//! - [`runtime`] — the persistent worker-pool executor behind every
//!   parallel fan-out, plus (feature `pjrt`) the PJRT artifact engine.
//! - `coordinator` (feature `pjrt`) — the live leader/worker data plane
//!   over the PJRT payload kernel. Both PJRT pieces need the `xla` crate,
//!   which the dependency-free offline build does not vendor, so they are
//!   compiled only when the `pjrt` feature is enabled.
//!
//! ## Quickstart
//! ```no_run
//! use taos::prelude::*;
//! let mut cfg = ExperimentConfig::default();
//! cfg.cluster.zipf_alpha = 1.0;
//! let outcome = taos::sim::run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Wf)).unwrap();
//! println!("avg JCT = {:.1} slots", outcome.jct_stats().mean);
//! ```

pub mod assign;
pub mod benchlib;
pub mod cli;
pub mod cluster;
pub mod config;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod des;
pub mod flow;
pub mod job;
pub mod metrics;
pub mod obs;
pub mod proptest;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sweep;
pub mod topology;
pub mod trace;
pub mod util;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::assign::{AssignPolicy, Assigner, Assignment};
    pub use crate::cluster::Cluster;
    pub use crate::config::ExperimentConfig;
    pub use crate::job::{Job, TaskGroup};
    pub use crate::metrics::JctStats;
    pub use crate::sched::SchedPolicy;
    pub use crate::sim::{run_fifo, run_reordered, SimOutcome};
    pub use crate::trace::Trace;
    pub use crate::util::rng::Rng;
}

/// Library-wide error type. Implemented by hand (this crate builds
/// offline with zero dependencies, so no `thiserror`).
#[derive(Debug)]
pub enum Error {
    Infeasible(String),
    Config(String),
    TraceParse { line: usize, msg: String },
    Runtime(String),
    /// A simulation exceeded its configured horizon (or another run-time
    /// limit); the message identifies the offending run's configuration
    /// so a sweep can report *which* cell was too hot instead of
    /// aborting the process.
    Sim(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Infeasible(msg) => write!(f, "infeasible assignment: {msg}"),
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::TraceParse { line, msg } => {
                write!(f, "trace parse error at line {line}: {msg}")
            }
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Sim(msg) => write!(f, "simulation error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;
