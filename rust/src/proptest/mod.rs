//! A small property-based testing framework (the `proptest` crate is not in
//! the offline vendor set).
//!
//! Design: a [`Gen`] is a function from `(&mut Rng, size)` to a value; a
//! property is checked over `cases` random inputs. On failure the runner
//! performs greedy shrinking using a caller-provided `shrink` function
//! (defaulting to none) and panics with the seed + minimal counterexample,
//! so failures are reproducible by re-running with the printed seed.
//!
//! ```no_run
//! use taos::proptest::{forall, Config};
//! forall(Config::default().cases(64), |rng| rng.gen_range(100) as i64, |&x| x < 100);
//! ```

use crate::util::rng::Rng;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum shrink attempts.
    pub max_shrinks: usize,
    /// Size hint passed through to generators that want it.
    pub size: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed can be overridden via TAOS_PROPTEST_SEED for reproduction.
        let seed = std::env::var("TAOS_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config {
            cases: 128,
            seed,
            max_shrinks: 512,
            size: 16,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn size(mut self, s: usize) -> Self {
        self.size = s;
        self
    }
}

/// Check `prop` on `cfg.cases` values drawn from `gen`. Panics with the
/// failing case (no shrinking) on violation.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::seed_from(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified at case {case}/{} (seed {:#x}):\n{input:#?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Like [`forall`], but with greedy shrinking: `shrink(x)` returns a list of
/// strictly "smaller" candidates; the runner walks down while the property
/// keeps failing, then reports the local minimum.
pub fn forall_shrink<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::seed_from(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // Greedy shrink.
            let mut current = input.clone();
            let mut budget = cfg.max_shrinks;
            'outer: while budget > 0 {
                for cand in shrink(&current) {
                    budget -= 1;
                    if !prop(&cand) {
                        current = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property falsified at case {case}/{} (seed {:#x}):\noriginal: {input:#?}\nshrunk:   {current:#?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Shrinker for a `Vec<T>`: tries removing halves, then single elements,
/// then shrinking individual elements with `elem_shrink`.
pub fn shrink_vec<T: Clone>(xs: &[T], elem_shrink: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    // Halves — only when strictly shorter than the input (n == 1 would
    // reproduce the input itself and stall the greedy walk).
    if n >= 2 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
    }
    // Drop one element.
    for i in 0..n.min(8) {
        let mut v = xs.to_vec();
        v.remove(i);
        out.push(v);
    }
    // Shrink one element.
    for i in 0..n.min(8) {
        for e in elem_shrink(&xs[i]) {
            let mut v = xs.to_vec();
            v[i] = e;
            out.push(v);
        }
    }
    out
}

/// Shrinker for unsigned integers: 0, halves, decrements.
pub fn shrink_u64(x: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if x == 0 {
        return out;
    }
    out.push(0);
    if x > 1 {
        out.push(x / 2);
    }
    out.push(x - 1);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config::default().cases(256),
            |rng| rng.gen_range(1000),
            |&x| x < 1000,
        );
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics() {
        forall(
            Config::default().cases(256),
            |rng| rng.gen_range(1000),
            |&x| x < 500,
        );
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: all vec elements < 50. Generator produces values up to
        // 100, so it fails; the shrunk example should be a short vector
        // whose only element is >= 50 and near-minimal.
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                Config {
                    cases: 64,
                    seed: 42,
                    max_shrinks: 16_384,
                    size: 16,
                },
                |rng| {
                    let n = rng.gen_range(10) as usize + 1;
                    (0..n).map(|_| rng.gen_range(100)).collect::<Vec<u64>>()
                },
                |xs| shrink_vec(xs, |&x| shrink_u64(x)),
                |xs| xs.iter().all(|&x| x < 50),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("expected property to fail"),
        };
        // The shrunk vector should be minimal: exactly one element, = 50.
        let shrunk = msg.split("shrunk:").nth(1).unwrap();
        assert!(shrunk.contains("50"), "shrunk to boundary: {shrunk}");
    }

    #[test]
    fn shrink_u64_decreases() {
        for x in [1u64, 2, 17, 1000] {
            for s in shrink_u64(x) {
                assert!(s < x);
            }
        }
        assert!(shrink_u64(0).is_empty());
    }

    #[test]
    fn same_seed_same_cases() {
        let mut collected1 = Vec::new();
        forall(Config::default().cases(16).seed(7), |rng| rng.next_u64(), |&x| {
            collected1.push(x);
            true
        });
        let mut collected2 = Vec::new();
        forall(Config::default().cases(16).seed(7), |rng| rng.next_u64(), |&x| {
            collected2.push(x);
            true
        });
        assert_eq!(collected1, collected2);
    }
}
