//! Command-line argument parsing (the `clap` crate is not in the offline
//! vendor set). Supports subcommands, `--flag value`, `--flag=value`,
//! boolean switches, and generated help text.

use std::collections::BTreeMap;

/// Declarative spec of one flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// A parsed command line: subcommand + flag values + positionals.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse `{v}`")),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// A subcommand-based CLI.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    subcommands: Vec<(&'static str, &'static str, Vec<FlagSpec>)>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli {
            bin,
            about,
            subcommands: Vec::new(),
        }
    }

    pub fn subcommand(
        mut self,
        name: &'static str,
        help: &'static str,
        flags: Vec<FlagSpec>,
    ) -> Self {
        self.subcommands.push((name, help, flags));
        self
    }

    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <subcommand> [flags]\n\nSUBCOMMANDS:\n", self.bin, self.about, self.bin);
        for (name, help, _) in &self.subcommands {
            out.push_str(&format!("  {name:<14} {help}\n"));
        }
        out.push_str("\nRun with `<subcommand> --help` for flags.\n");
        out
    }

    pub fn help_for(&self, sub: &str) -> Option<String> {
        let (name, help, flags) = self.subcommands.iter().find(|(n, _, _)| *n == sub)?;
        let mut out = format!("{} {name} — {help}\n\nFLAGS:\n", self.bin);
        for f in flags {
            let kind = if f.is_switch { "" } else { " <value>" };
            let def = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{}{kind:<10} {}{def}\n", f.name, f.help));
        }
        Some(out)
    }

    /// Parse args (not including argv[0]). Returns Err(message) on any
    /// problem; the caller prints it and exits.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
            return Err(self.help());
        }
        let sub = args[0].clone();
        let (_, _, flags) = self
            .subcommands
            .iter()
            .find(|(n, _, _)| *n == sub)
            .ok_or_else(|| format!("unknown subcommand `{sub}`\n\n{}", self.help()))?;

        let mut parsed = Parsed {
            subcommand: sub.clone(),
            ..Default::default()
        };
        // Apply defaults.
        for f in flags {
            if let Some(d) = f.default {
                parsed.flags.insert(f.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(self.help_for(&sub).unwrap());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name} for `{sub}`"))?;
                if spec.is_switch {
                    if inline_val.is_some() {
                        return Err(format!("--{name} is a switch, no value allowed"));
                    }
                    parsed.switches.push(name.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .ok_or_else(|| format!("--{name} requires a value"))?
                                .clone()
                        }
                    };
                    parsed.flags.insert(name.to_string(), val);
                }
            } else {
                parsed.positionals.push(arg.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }
}

/// Shorthand constructors for flag specs.
pub fn flag(name: &'static str, help: &'static str, default: &'static str) -> FlagSpec {
    FlagSpec {
        name,
        help,
        default: Some(default),
        is_switch: false,
    }
}

pub fn flag_req(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec {
        name,
        help,
        default: None,
        is_switch: false,
    }
}

pub fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec {
        name,
        help,
        default: None,
        is_switch: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("taos", "test cli")
            .subcommand(
                "simulate",
                "run a simulation",
                vec![
                    flag("alg", "algorithm", "wf"),
                    flag("seed", "rng seed", "42"),
                    switch("verbose", "chatty output"),
                ],
            )
            .subcommand("repro", "reproduce a figure", vec![flag_req("fig", "figure id")])
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let p = cli().parse(&argv(&["simulate", "--seed", "7"])).unwrap();
        assert_eq!(p.subcommand, "simulate");
        assert_eq!(p.get("alg"), Some("wf"));
        assert_eq!(p.get_parse::<u64>("seed").unwrap(), Some(7));
        assert!(!p.has_switch("verbose"));
    }

    #[test]
    fn parses_equals_form_and_switch() {
        let p = cli()
            .parse(&argv(&["simulate", "--alg=obta", "--verbose"]))
            .unwrap();
        assert_eq!(p.get("alg"), Some("obta"));
        assert!(p.has_switch("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cli().parse(&argv(&["simulate", "--nope", "1"])).is_err());
    }

    #[test]
    fn unknown_subcommand_rejected() {
        let err = cli().parse(&argv(&["frobnicate"])).unwrap_err();
        assert!(err.contains("unknown subcommand"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&argv(&["simulate", "--alg"])).is_err());
    }

    #[test]
    fn help_lists_subcommands() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("simulate"));
        assert!(err.contains("repro"));
    }

    #[test]
    fn required_flag_has_no_default() {
        let p = cli().parse(&argv(&["repro"])).unwrap();
        assert_eq!(p.get("fig"), None);
    }

    #[test]
    fn positionals_collected() {
        let p = cli()
            .parse(&argv(&["simulate", "file1", "--alg", "rd", "file2"]))
            .unwrap();
        assert_eq!(p.positionals, vec!["file1", "file2"]);
    }

    #[test]
    fn switch_with_value_rejected() {
        assert!(cli().parse(&argv(&["simulate", "--verbose=yes"])).is_err());
    }
}
