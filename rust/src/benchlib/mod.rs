//! A criterion-lite benchmarking harness (the `criterion` crate is not in
//! the offline vendor set).
//!
//! Provides warmup, timed iterations with adaptive batching, summary
//! statistics, and plain-text/JSON reporting. Bench binaries registered
//! with `harness = false` in `Cargo.toml` use [`Bench`] directly; the
//! figure-reproduction benches additionally emit the data series of the
//! paper's tables/figures.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Prevent the optimizer from eliminating a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format a counter with thousands separators (`1234567` → `1,234,567`).
/// Used by the telemetry tables (`taos repro`, `taos simulate`) so large
/// wf_evals / oracle-tier counts stay readable.
pub fn fmt_count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Configuration for one benchmark run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum measured samples regardless of duration.
    pub min_samples: usize,
    /// Maximum samples (caps very fast functions).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1000),
            min_samples: 10,
            max_samples: 2000,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI/test runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 200,
        }
    }
}

/// Result of one benchmark: per-iteration wall-clock in microseconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub samples_us: Vec<f64>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<40} {:>10.2} us/iter (p50 {:>9.2}, p99 {:>10.2}, n={})",
            self.name, s.mean, s.p50, s.p99, s.n
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("mean_us", Json::num(self.summary.mean)),
            ("p50_us", Json::num(self.summary.p50)),
            ("p99_us", Json::num(self.summary.p99)),
            ("std_us", Json::num(self.summary.std)),
            ("n", Json::num(self.summary.n as f64)),
        ])
    }
}

/// A group of benchmarks that share a config and print a report.
pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Self {
        // `cargo bench -- --quick` or TAOS_BENCH_QUICK=1 switches to the
        // fast profile (used by CI and the Makefile test target).
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("TAOS_BENCH_QUICK").is_ok();
        Bench {
            cfg: if quick { BenchConfig::quick() } else { BenchConfig::default() },
            results: Vec::new(),
        }
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        Bench { cfg, results: Vec::new() }
    }

    /// Run one benchmark. `f` is invoked once per sample; use
    /// [`black_box`] on its result inside the closure.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.cfg.warmup {
            black_box(f());
            warm_iters += 1;
        }
        // Estimate per-iter cost to size batches (aim ~1ms per sample so
        // Instant overhead is negligible for fast functions).
        let per_iter = w0.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((1e-3 / per_iter.max(1e-9)).ceil() as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.cfg.measure || samples.len() < self.cfg.min_samples)
            && samples.len() < self.cfg.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() * 1e6 / batch as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::from(&samples),
            samples_us: samples,
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all results as JSON lines to the given path.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for r in &self.results {
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Render a plain-text table (used by the figure benches to print the same
/// rows the paper reports, e.g. Table I).
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_groups_thousands() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(7), "7");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1_000), "1,000");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
        assert_eq!(fmt_count(12_345), "12,345");
    }

    #[test]
    fn bench_measures_sleep() {
        let mut b = Bench::with_config(BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_samples: 3,
            max_samples: 50,
        });
        let r = b.run("sleep_1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.summary.mean >= 900.0, "mean {} us", r.summary.mean);
        assert!(r.summary.n >= 3);
    }

    #[test]
    fn bench_fast_function_batches() {
        let mut b = Bench::with_config(BenchConfig::quick());
        let r = b.run("add", || black_box(2u64) + black_box(3u64));
        assert!(r.summary.mean < 100.0, "fast fn should be well under 100us");
    }

    #[test]
    fn json_output_roundtrips() {
        let mut b = Bench::with_config(BenchConfig::quick());
        b.run("noop", || ());
        let path = std::env::temp_dir().join("taos_bench_test.jsonl");
        b.write_json(path.to_str().unwrap()).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(content.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("noop"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["alg", "jct"]);
        t.row(vec!["wf".into(), "6042".into()]);
        t.row(vec!["obta".into(), "5870".into()]);
        let s = t.render();
        assert!(s.contains("alg |"), "header present: {s}");
        assert!(s.contains("6042"));
        assert_eq!(s.lines().count(), 4, "header + separator + 2 rows: {s}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
