//! Hierarchical network-cost topology for the multi-level locality model.
//!
//! PR 5's locality was a single scalar: a task either runs on a
//! data-local server at rate `μ` or anywhere else at `μ/penalty`. Real
//! near-data scheduling (Yekkehkhany's multi-level-locality model,
//! arXiv 1702.07802; the affinity model of arXiv 1705.03125) lives in a
//! rack/zone/region hierarchy where remoteness is graded. This module
//! supplies that grading:
//!
//! - [`TopologyKind`]: cluster-shape presets (`flat`, `multi-rack`,
//!   `multi-zone`, `fat-tree`), selectable via `--topology` / the
//!   `topology` config key.
//! - [`Topology`]: the concrete server→server distance function for one
//!   cluster size — every pair of servers maps to a **tier** (0 = the
//!   server itself, rising with network distance), derived from a
//!   deterministic contiguous rack/zone assignment.
//! - [`Locality`]: the precomputed per-(job, group, server) tier table
//!   the DES engine charges execution rates from (`μ / tier_penalty`),
//!   plus the per-tier task telemetry helpers.
//!
//! Tier semantics: for a task *group* (which owns a data-local server
//! set), a server's tier is 0 when it is in the set, otherwise the
//! minimum pair tier from any set member — i.e. "same rack as a replica"
//! beats "same zone as a replica" beats "cross-zone". The top tier of
//! every preset always charges the full configured penalty, and tier 0
//! always charges exactly 1.0, so `flat` reproduces PR 5's two-level
//! model bit for bit and a penalty of `1.0` makes every tier unit-rate
//! (the no-locality fast path).

use crate::job::{Job, ServerId, TaskCount};

/// Servers per rack (contiguous assignment: rack of `s` is `s / 4`).
pub const RACK_SIZE: usize = 4;
/// Racks per zone (`multi-zone`): a zone spans 8 contiguous servers.
pub const RACKS_PER_ZONE: usize = 2;
/// Edge switches per pod (`fat-tree`): a pod spans 16 contiguous
/// servers (4 edges × 4 servers).
pub const EDGES_PER_POD: usize = 4;

/// Cluster-shape preset. Parsed from `--topology` / the `topology`
/// config key; `flat` (the default) is PR 5's two-level model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Two tiers: data-local (rate `μ`) vs anywhere else (`μ/penalty`).
    Flat,
    /// Three tiers: local / same rack / cross-rack.
    MultiRack,
    /// Four tiers: local / same rack / same zone / cross-zone.
    MultiZone,
    /// Four tiers: local / same edge switch / same pod / core.
    FatTree,
}

impl TopologyKind {
    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::Flat,
        TopologyKind::MultiRack,
        TopologyKind::MultiZone,
        TopologyKind::FatTree,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::Flat => "flat",
            TopologyKind::MultiRack => "multi-rack",
            TopologyKind::MultiZone => "multi-zone",
            TopologyKind::FatTree => "fat-tree",
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            TopologyKind::Flat => "two tiers: data-local vs remote (the scalar penalty model)",
            TopologyKind::MultiRack => "three tiers: local / same rack (4 servers) / cross-rack",
            TopologyKind::MultiZone => {
                "four tiers: local / same rack (4) / same zone (8) / cross-zone"
            }
            TopologyKind::FatTree => {
                "four tiers: local / same edge (4) / same pod (16) / core"
            }
        }
    }

    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Some(TopologyKind::Flat),
            "multi-rack" | "multi_rack" | "multirack" | "rack" => Some(TopologyKind::MultiRack),
            "multi-zone" | "multi_zone" | "multizone" | "zone" => Some(TopologyKind::MultiZone),
            "fat-tree" | "fat_tree" | "fattree" => Some(TopologyKind::FatTree),
            _ => None,
        }
    }

    /// Number of distinct tiers (including tier 0, the local tier).
    pub fn num_tiers(self) -> usize {
        match self {
            TopologyKind::Flat => 2,
            TopologyKind::MultiRack => 3,
            TopologyKind::MultiZone | TopologyKind::FatTree => 4,
        }
    }
}

impl Default for TopologyKind {
    fn default() -> Self {
        TopologyKind::Flat
    }
}

/// The concrete hierarchy for one cluster size: a deterministic
/// contiguous rack/zone assignment plus the pair→tier distance function
/// derived from it. Clusters whose size is not a multiple of the
/// rack/zone width simply get a short final rack/zone — the tier
/// function only compares labels.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub num_servers: usize,
    /// Per-server rack label (edge switch for `fat-tree`).
    rack: Vec<u32>,
    /// Per-server zone label (pod for `fat-tree`); unused by
    /// `flat`/`multi-rack` but kept uniform for the tier function.
    zone: Vec<u32>,
}

impl Topology {
    pub fn build(kind: TopologyKind, num_servers: usize) -> Topology {
        let zone_width = match kind {
            TopologyKind::FatTree => RACK_SIZE * EDGES_PER_POD,
            _ => RACK_SIZE * RACKS_PER_ZONE,
        };
        Topology {
            kind,
            num_servers,
            rack: (0..num_servers).map(|s| (s / RACK_SIZE) as u32).collect(),
            zone: (0..num_servers).map(|s| (s / zone_width) as u32).collect(),
        }
    }

    pub fn num_tiers(&self) -> usize {
        self.kind.num_tiers()
    }

    /// The most remote tier (`num_tiers − 1`): always reachable by every
    /// server pair, so it is the expansion bound for the assigners' view.
    pub fn top_tier(&self) -> usize {
        self.num_tiers() - 1
    }

    pub fn rack_of(&self, s: ServerId) -> u32 {
        self.rack[s]
    }

    /// Network tier between two servers: 0 for the server itself, rising
    /// with distance. Every preset's top tier is its cross-everything
    /// tier, so `pair_tier <= top_tier()` always holds.
    pub fn pair_tier(&self, a: ServerId, b: ServerId) -> usize {
        if a == b {
            return 0;
        }
        match self.kind {
            TopologyKind::Flat => 1,
            TopologyKind::MultiRack => {
                if self.rack[a] == self.rack[b] {
                    1
                } else {
                    2
                }
            }
            TopologyKind::MultiZone | TopologyKind::FatTree => {
                if self.rack[a] == self.rack[b] {
                    1
                } else if self.zone[a] == self.zone[b] {
                    2
                } else {
                    3
                }
            }
        }
    }

    /// Tier of `server` relative to a task group's data-local server set
    /// (sorted, as [`crate::job::TaskGroup`] guarantees): 0 when the
    /// server holds the data, otherwise the minimum pair tier to any
    /// replica — the cheapest copy is what a transfer would read.
    pub fn group_tier(&self, local_sorted: &[ServerId], server: ServerId) -> usize {
        if local_sorted.binary_search(&server).is_ok() {
            return 0;
        }
        local_sorted
            .iter()
            .map(|&l| self.pair_tier(l, server))
            .min()
            .unwrap_or(self.top_tier())
    }

    /// Per-tier execution-rate penalties for a configured top-tier
    /// penalty `p`: tier 0 is exactly `1.0`, the top tier exactly `p`,
    /// intermediate tiers interpolate (cheap within-rack hops, expensive
    /// cross-zone ones). At `p = 1.0` every tier is exactly `1.0`.
    pub fn penalties(&self, p: f64) -> Vec<f64> {
        let d = p - 1.0;
        match self.kind {
            TopologyKind::Flat => vec![1.0, p],
            TopologyKind::MultiRack => vec![1.0, 1.0 + d * 0.4, p],
            TopologyKind::MultiZone => vec![1.0, 1.0 + d / 3.0, 1.0 + d * 2.0 / 3.0, p],
            TopologyKind::FatTree => vec![1.0, 1.0 + d * 0.15, 1.0 + d * 0.6, p],
        }
    }

    /// The servers a group may run on when placement is opened up to
    /// `tier`: every server whose [`Self::group_tier`] is at most `tier`.
    /// At `top_tier()` this is the whole cluster (the DES expansion
    /// view); lower tiers give the graded eligible sets (data-local →
    /// same-rack → same-zone → anywhere).
    pub fn eligible_within(&self, local_sorted: &[ServerId], tier: usize) -> Vec<ServerId> {
        (0..self.num_servers)
            .filter(|&s| self.group_tier(local_sorted, s) <= tier)
            .collect()
    }
}

/// Precomputed per-(job, group, server) tier table plus the per-tier
/// penalties: the execution-rate view the DES engine charges from, and
/// the definition of the tier hit-rate telemetry. Built once per run
/// from the **original** (unexpanded) jobs so tier lookups during the
/// event cascade are a flat array index.
#[derive(Clone, Debug)]
pub struct Locality {
    /// Per-job starting row (`offsets[job] + k` is group `k`'s row).
    offsets: Vec<usize>,
    /// Flattened `rows × num_servers` tier table.
    tiers: Vec<u8>,
    penalties: Vec<f64>,
    num_servers: usize,
}

impl Locality {
    pub fn new(jobs: &[Job], topo: &Topology, penalty: f64) -> Locality {
        let m = topo.num_servers;
        let mut offsets = Vec::with_capacity(jobs.len());
        let mut rows = 0usize;
        for j in jobs {
            offsets.push(rows);
            rows += j.groups.len();
        }
        let mut tiers = Vec::with_capacity(rows * m);
        for j in jobs {
            for g in &j.groups {
                for s in 0..m {
                    tiers.push(topo.group_tier(&g.servers, s) as u8);
                }
            }
        }
        Locality {
            offsets,
            tiers,
            penalties: topo.penalties(penalty),
            num_servers: m,
        }
    }

    pub fn num_tiers(&self) -> usize {
        self.penalties.len()
    }

    /// Tier of `server` for group `k` of `job`.
    pub fn tier(&self, job: usize, k: usize, server: ServerId) -> usize {
        self.tiers[(self.offsets[job] + k) * self.num_servers + server] as usize
    }

    pub fn penalty_of(&self, tier: usize) -> f64 {
        self.penalties[tier]
    }

    /// Execution-rate weight of one task of group `k` on `server`.
    pub fn rate_weight(&self, job: usize, k: usize, server: ServerId) -> f64 {
        self.penalties[self.tier(job, k, server)]
    }

    /// True when every part of a batch runs at exactly the local rate on
    /// `server` — the condition under which the duration estimate must be
    /// bit-identical to the no-locality integer path.
    pub fn unit_rate(&self, job: usize, parts: &[(usize, TaskCount)], server: ServerId) -> bool {
        parts
            .iter()
            .all(|&(k, _)| self.rate_weight(job, k, server) == 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;
    use crate::util::rng::Rng;

    #[test]
    fn parse_roundtrip_and_aliases() {
        for kind in TopologyKind::ALL {
            assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
            assert!(!kind.describe().is_empty());
        }
        assert_eq!(TopologyKind::parse("rack"), Some(TopologyKind::MultiRack));
        assert_eq!(TopologyKind::parse("multi_zone"), Some(TopologyKind::MultiZone));
        assert_eq!(TopologyKind::parse("fattree"), Some(TopologyKind::FatTree));
        assert_eq!(TopologyKind::parse("torus"), None);
        assert_eq!(TopologyKind::default(), TopologyKind::Flat);
    }

    #[test]
    fn pair_tiers_follow_the_hierarchy() {
        let t = Topology::build(TopologyKind::MultiZone, 16);
        assert_eq!(t.pair_tier(0, 0), 0);
        assert_eq!(t.pair_tier(0, 3), 1, "same rack");
        assert_eq!(t.pair_tier(0, 4), 2, "same zone, different rack");
        assert_eq!(t.pair_tier(0, 8), 3, "cross-zone");
        // Symmetry.
        for a in 0..16 {
            for b in 0..16 {
                assert_eq!(t.pair_tier(a, b), t.pair_tier(b, a));
            }
        }

        let flat = Topology::build(TopologyKind::Flat, 16);
        assert_eq!(flat.pair_tier(0, 15), 1);
        assert_eq!(flat.num_tiers(), 2);

        let ft = Topology::build(TopologyKind::FatTree, 32);
        assert_eq!(ft.pair_tier(0, 3), 1, "same edge");
        assert_eq!(ft.pair_tier(0, 12), 2, "same pod");
        assert_eq!(ft.pair_tier(0, 16), 3, "core");
    }

    #[test]
    fn group_tier_takes_the_cheapest_replica() {
        let t = Topology::build(TopologyKind::MultiRack, 12);
        // Replicas on servers 0 (rack 0) and 8 (rack 2).
        let local = vec![0usize, 8];
        assert_eq!(t.group_tier(&local, 0), 0);
        assert_eq!(t.group_tier(&local, 8), 0);
        assert_eq!(t.group_tier(&local, 1), 1, "same rack as replica 0");
        assert_eq!(t.group_tier(&local, 9), 1, "same rack as replica 8");
        assert_eq!(t.group_tier(&local, 5), 2, "rack 1 holds no replica");
    }

    #[test]
    fn penalties_are_anchored_and_monotone() {
        for kind in TopologyKind::ALL {
            let t = Topology::build(kind, 16);
            for p in [1.0, 2.0, 8.0] {
                let pen = t.penalties(p);
                assert_eq!(pen.len(), kind.num_tiers());
                assert_eq!(pen[0], 1.0, "{}: tier 0 is exactly local", kind.name());
                assert_eq!(
                    *pen.last().unwrap(),
                    p,
                    "{}: top tier charges the full penalty",
                    kind.name()
                );
                for w in pen.windows(2) {
                    assert!(w[0] <= w[1], "{}: penalties must be monotone", kind.name());
                }
                if p == 1.0 {
                    assert!(pen.iter().all(|&x| x == 1.0), "unit penalty ⇒ unit tiers");
                }
            }
        }
    }

    #[test]
    fn eligible_sets_grow_with_the_tier() {
        let t = Topology::build(TopologyKind::MultiZone, 16);
        let local = vec![1usize];
        assert_eq!(t.eligible_within(&local, 0), vec![1]);
        assert_eq!(t.eligible_within(&local, 1), vec![0, 1, 2, 3], "the rack");
        assert_eq!(
            t.eligible_within(&local, 2),
            (0..8).collect::<Vec<_>>(),
            "the zone"
        );
        assert_eq!(
            t.eligible_within(&local, t.top_tier()),
            (0..16).collect::<Vec<_>>(),
            "top tier is the whole cluster"
        );
    }

    #[test]
    fn relabeling_within_a_rack_commutes_with_the_tier_table() {
        // The metamorphic core of the tier telemetry: permuting servers
        // *within a rack* is a topology automorphism, so the tier of
        // π(server) relative to π(local set) equals the original tier —
        // tier histograms of any fixed schedule are invariant under π.
        let m = 16;
        let mut rng = Rng::seed_from(0x70B0);
        for kind in [
            TopologyKind::MultiRack,
            TopologyKind::MultiZone,
            TopologyKind::FatTree,
        ] {
            let t = Topology::build(kind, m);
            // π: swap two servers inside rack 0 and two inside rack 2.
            let mut perm: Vec<usize> = (0..m).collect();
            perm.swap(1, 3);
            perm.swap(8, 10);
            for _ in 0..40 {
                let ns = 1 + rng.gen_range(m as u64) as usize;
                let mut sv: Vec<usize> = (0..m).collect();
                rng.shuffle(&mut sv);
                sv.truncate(ns);
                let local = TaskGroup::new(1, sv).servers;
                let relabeled =
                    TaskGroup::new(1, local.iter().map(|&s| perm[s]).collect()).servers;
                for s in 0..m {
                    assert_eq!(
                        t.group_tier(&local, s),
                        t.group_tier(&relabeled, perm[s]),
                        "{}: tier must commute with a within-rack relabel",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn locality_table_matches_direct_lookup() {
        let m = 12;
        let topo = Topology::build(TopologyKind::MultiRack, m);
        let jobs = vec![
            Job {
                id: 0,
                arrival: 0,
                groups: vec![
                    TaskGroup::new(5, vec![0, 1]),
                    TaskGroup::new(3, vec![9]),
                ],
                mu: vec![1; m],
            },
            Job {
                id: 1,
                arrival: 2,
                groups: vec![TaskGroup::new(4, vec![4, 5, 6, 7])],
                mu: vec![1; m],
            },
        ];
        let loc = Locality::new(&jobs, &topo, 3.0);
        assert_eq!(loc.num_tiers(), 3);
        for (j, job) in jobs.iter().enumerate() {
            for (k, g) in job.groups.iter().enumerate() {
                for s in 0..m {
                    assert_eq!(loc.tier(j, k, s), topo.group_tier(&g.servers, s));
                }
            }
        }
        // Rate weights anchor to the tier penalties.
        assert_eq!(loc.rate_weight(0, 0, 0), 1.0);
        assert_eq!(loc.rate_weight(0, 0, 2), 1.0 + 2.0 * 0.4, "same rack");
        assert_eq!(loc.rate_weight(0, 0, 11), 3.0, "cross-rack");
        // unit_rate: all-local parts batch vs one remote part.
        assert!(loc.unit_rate(0, &[(0, 5)], 0));
        assert!(!loc.unit_rate(0, &[(0, 5), (1, 3)], 0));
        // At penalty 1.0 every server is unit-rate everywhere.
        let unit = Locality::new(&jobs, &topo, 1.0);
        for s in 0..m {
            assert!(unit.unit_rate(0, &[(0, 5), (1, 3)], s));
        }
    }
}
