//! Named workload scenarios beyond the paper's single Alibaba-matched
//! synthetic trace.
//!
//! The paper's evaluation (§V) drives every figure with one workload
//! shape. Related work shows the regimes that shape cannot express:
//! placement-constrained scheduling degrades under *placement skew*
//! (Shafiee & Ghaderi), and replication/latency tradeoffs hinge on
//! *workload burstiness and tail weight* (Wang, Joshi & Wornell). Each
//! [`Scenario`] twists exactly one axis of the generator so those regimes
//! are reachable from the CLI (`--scenario`, `taos repro --fig
//! scenarios`) and the config file (`scenario = …`):
//!
//! | name | twist |
//! |---|---|
//! | `alibaba` | the paper's baseline (lognormal sizes, Poisson arrivals) |
//! | `bursty` | on/off arrival bursts instead of smooth Poisson |
//! | `heavy-tail` | Pareto(1.5) task-group sizes (infinite variance) |
//! | `hetero-cap` | Zipf-skewed per-server speeds (few fast, many slow) |
//! | `hotspot` | scattered Zipf replica placement onto hot servers |
//! | `bursty-hetero` | compound: bursty arrivals × Zipf server speeds |
//! | `hotspot-heavy-tail` | compound: Pareto sizes × hot-spot placement |
//! | `straggler` | DES engine: Pareto service tails + racing replicas |
//! | `k-replica` | DES engine: Pareto tails + budgeted K = 3 replica races |
//! | `multi-locality` | DES engine: flat two-tier locality, remote at `μ/penalty` |
//! | `multi-rack` | DES engine: rack hierarchy, tiered locality penalties |
//! | `multi-zone` | DES engine: rack+zone hierarchy, tiered locality penalties |
//!
//! The two compound presets close the one-axis-per-scenario gap: stress
//! regimes that only emerge when axes interact (bursts landing on a
//! capacity-skewed cluster; giant groups replicated onto hot servers)
//! are reachable by name instead of requiring a hand-written config. The
//! two engine presets open the axes the analytic engines cannot express
//! at all — they run on the discrete-event engine ([`crate::des`]),
//! selected by `Scenario::apply` setting `SimConfig.engine = des`.
//!
//! Trace-shape scenarios act in [`Scenario::synth`]; cluster-side and
//! engine-side scenarios act through [`Scenario::apply`], which
//! unconditionally sets the matching
//! [`ClusterConfig`](crate::config::ClusterConfig) /
//! [`SimConfig`](crate::config::SimConfig) knobs (`mu_skew`,
//! `placement_mode`, `zipf_alpha = 1.5` for `hotspot`; `engine`,
//! `service`, `speculate`, `locality_penalty`, `topology` for the engine
//! presets) — precedence is by ordering, so callers apply the scenario
//! first and explicit user knobs after.

use crate::cluster::placement::PlacementMode;
use crate::config::{ExperimentConfig, TraceConfig};
use crate::trace::{self, Trace};
use crate::util::rng::Rng;

/// A named workload scenario. `Alibaba` is the paper's baseline; the
/// others each twist one axis of the generator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scenario {
    /// The paper's §V-A synthetic: lognormal group sizes, exponential
    /// interarrivals, homogeneous servers, ring placement.
    #[default]
    Alibaba,
    /// On/off bursty arrivals: trains of closely spaced jobs separated by
    /// long idle gaps (same marginal totals as the baseline).
    Bursty,
    /// Pareto(1.5) task-group sizes — heavier than the baseline's
    /// lognormal; a few giant groups dominate the load.
    HeavyTail,
    /// Heterogeneous server speeds: per-server μ multipliers follow a
    /// Zipf profile (`mu_skew = 1`), so capacity concentrates on a few
    /// fast servers.
    HeteroCap,
    /// Hot-spot replica placement: available-server sets are scattered
    /// Zipf draws (`placement_mode = scatter`), piling the replicas of
    /// most groups onto the same few servers.
    Hotspot,
    /// Compound preset: bursty on/off arrivals landing on a
    /// capacity-skewed cluster (`mu_skew = 1`) — arrival trains pile onto
    /// the few fast servers everyone wants.
    BurstyHetero,
    /// Compound preset: Pareto(1.5) group sizes with scattered Zipf
    /// replica placement — the giant groups' replicas concentrate on the
    /// same hot servers.
    HotspotHeavyTail,
    /// Engine preset (DES only): Pareto-tailed stochastic service times
    /// with straggler speculation — RD-style retained replicas actually
    /// race, first completion cancels the sibling (Wang–Joshi–Wornell's
    /// replication regime).
    Straggler,
    /// Engine preset (DES only): the `straggler` service tail with a
    /// K = 3 replica set under the tail budget — each sampled straggler
    /// forks up to two racing replicas, first completion cancels every
    /// loser, and the burned loser slots surface as wasted-work
    /// telemetry.
    KReplica,
    /// Engine preset (DES only): two-level data locality on the `flat`
    /// topology — every server can run every task, but remote execution
    /// pays a rate penalty (Yekkehkhany's near-data scheduling regime).
    MultiLocality,
    /// Engine preset (DES only): hierarchical locality on the
    /// `multi-rack` topology — remote execution pays a *tiered* penalty
    /// (cheap within the data's rack, full across racks).
    MultiRack,
    /// Engine preset (DES only): hierarchical locality on the
    /// `multi-zone` topology — three remote tiers (rack, zone, beyond)
    /// with graded penalties.
    MultiZone,
}

impl Scenario {
    pub const ALL: [Scenario; 12] = [
        Scenario::Alibaba,
        Scenario::Bursty,
        Scenario::HeavyTail,
        Scenario::HeteroCap,
        Scenario::Hotspot,
        Scenario::BurstyHetero,
        Scenario::HotspotHeavyTail,
        Scenario::Straggler,
        Scenario::KReplica,
        Scenario::MultiLocality,
        Scenario::MultiRack,
        Scenario::MultiZone,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Alibaba => "alibaba",
            Scenario::Bursty => "bursty",
            Scenario::HeavyTail => "heavy-tail",
            Scenario::HeteroCap => "hetero-cap",
            Scenario::Hotspot => "hotspot",
            Scenario::BurstyHetero => "bursty-hetero",
            Scenario::HotspotHeavyTail => "hotspot-heavy-tail",
            Scenario::Straggler => "straggler",
            Scenario::KReplica => "k-replica",
            Scenario::MultiLocality => "multi-locality",
            Scenario::MultiRack => "multi-rack",
            Scenario::MultiZone => "multi-zone",
        }
    }

    /// One-line catalog entry (CLI legend, docs).
    pub fn describe(&self) -> &'static str {
        match self {
            Scenario::Alibaba => "paper baseline: lognormal sizes, Poisson arrivals",
            Scenario::Bursty => "on/off arrival bursts separated by idle gaps",
            Scenario::HeavyTail => "Pareto(1.5) group sizes, infinite variance",
            Scenario::HeteroCap => "Zipf-skewed server speeds (few fast, many slow)",
            Scenario::Hotspot => "scattered Zipf replica placement on hot servers",
            Scenario::BurstyHetero => "compound: arrival bursts x Zipf-skewed speeds",
            Scenario::HotspotHeavyTail => "compound: Pareto sizes x hot-spot placement",
            Scenario::Straggler => "DES: Pareto service tails + racing replica speculation",
            Scenario::KReplica => "DES: Pareto tails + budgeted K=3 replica races",
            Scenario::MultiLocality => "DES: flat locality, remote execution at mu/penalty",
            Scenario::MultiRack => "DES: rack topology, tiered locality penalties",
            Scenario::MultiZone => "DES: rack+zone topology, three graded remote tiers",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "alibaba" | "baseline" | "default" => Some(Scenario::Alibaba),
            "bursty" | "burst" | "onoff" | "on-off" => Some(Scenario::Bursty),
            "heavy-tail" | "heavytail" | "heavy_tail" | "pareto" => Some(Scenario::HeavyTail),
            "hetero-cap" | "heterocap" | "hetero_cap" | "hetero" => Some(Scenario::HeteroCap),
            "hotspot" | "hot-spot" | "zipf-hotspot" => Some(Scenario::Hotspot),
            "bursty-hetero" | "bursty_hetero" | "burstyhetero" => Some(Scenario::BurstyHetero),
            "hotspot-heavy-tail" | "hotspot_heavy_tail" | "hotspotheavytail" => {
                Some(Scenario::HotspotHeavyTail)
            }
            "straggler" | "stragglers" | "straggler-spec" => Some(Scenario::Straggler),
            "k-replica" | "k_replica" | "kreplica" | "replication" => Some(Scenario::KReplica),
            "multi-locality" | "multi_locality" | "multilocality" | "locality" => {
                Some(Scenario::MultiLocality)
            }
            "multi-rack" | "multi_rack" | "multirack" => Some(Scenario::MultiRack),
            "multi-zone" | "multi_zone" | "multizone" => Some(Scenario::MultiZone),
            _ => None,
        }
    }

    /// True for scenarios whose twist lives *entirely* in the cluster
    /// model (their synthetic trace equals the baseline).
    pub fn is_cluster_side(&self) -> bool {
        matches!(self, Scenario::HeteroCap | Scenario::Hotspot)
    }

    /// True when any part of the twist lives in the cluster model — for
    /// compounds this is true even though their trace shape also differs
    /// from the baseline (a CSV export cannot capture the cluster side).
    pub fn has_cluster_twist(&self) -> bool {
        matches!(
            self,
            Scenario::HeteroCap
                | Scenario::Hotspot
                | Scenario::BurstyHetero
                | Scenario::HotspotHeavyTail
        )
    }

    /// True when the twist lives in the execution engine (DES service
    /// model / speculation / locality penalty): the synthetic trace
    /// equals the baseline, so a CSV export captures none of it.
    pub fn has_engine_twist(&self) -> bool {
        matches!(
            self,
            Scenario::Straggler
                | Scenario::KReplica
                | Scenario::MultiLocality
                | Scenario::MultiRack
                | Scenario::MultiZone
        )
    }

    /// Select this scenario on a config: sets `trace.scenario` and fully
    /// determines the scenario-owned cluster knobs — `mu_skew` and
    /// `placement_mode` are reset to their baselines first, so applying
    /// `alibaba` after `hotspot` really restores ring placement instead
    /// of silently keeping the previous twist. `hotspot` additionally
    /// sets `zipf_alpha = 1.5` (its twist needs skew). Precedence is by
    /// ordering, never by guessing whether a current value "looks
    /// explicit": callers that want user knobs to win apply the scenario
    /// first and the explicit overrides after (which is what the CLI and
    /// the config-file parser do).
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        use crate::des::service::{EngineKind, ReplicationBudget, ServiceModel};
        use crate::topology::TopologyKind;
        cfg.trace.scenario = *self;
        cfg.cluster.mu_skew = 0.0;
        cfg.cluster.placement_mode = PlacementMode::Ring;
        // Engine knobs are scenario-owned too: re-selecting the baseline
        // after `straggler` really restores the analytic engine.
        cfg.sim.engine = EngineKind::Analytic;
        cfg.sim.service = ServiceModel::Deterministic;
        cfg.sim.locality_penalty = 1.0;
        cfg.sim.topology = TopologyKind::Flat;
        cfg.sim.speculate = 0.0;
        cfg.sim.replicas = 0;
        cfg.sim.replication_budget = ReplicationBudget::Tail;
        match self {
            Scenario::HeteroCap | Scenario::BurstyHetero => {
                cfg.cluster.mu_skew = 1.0;
            }
            Scenario::Hotspot | Scenario::HotspotHeavyTail => {
                cfg.cluster.placement_mode = PlacementMode::Scatter;
                cfg.cluster.zipf_alpha = 1.5;
            }
            Scenario::Straggler => {
                cfg.sim.engine = EngineKind::Des;
                cfg.sim.service = ServiceModel::ParetoTail {
                    alpha: 1.5,
                    cap: 20.0,
                };
                cfg.sim.speculate = 2.0;
            }
            Scenario::KReplica => {
                cfg.sim.engine = EngineKind::Des;
                cfg.sim.service = ServiceModel::ParetoTail {
                    alpha: 1.5,
                    cap: 20.0,
                };
                cfg.sim.speculate = 2.0;
                cfg.sim.replicas = 3;
            }
            Scenario::MultiLocality => {
                cfg.sim.engine = EngineKind::Des;
                cfg.sim.locality_penalty = 2.0;
            }
            Scenario::MultiRack => {
                cfg.sim.engine = EngineKind::Des;
                cfg.sim.locality_penalty = 2.0;
                cfg.sim.topology = TopologyKind::MultiRack;
            }
            Scenario::MultiZone => {
                cfg.sim.engine = EngineKind::Des;
                cfg.sim.locality_penalty = 3.0;
                cfg.sim.topology = TopologyKind::MultiZone;
            }
            // Trace-shape scenarios (and the baseline) need no cluster
            // twist beyond the reset above. zipf_alpha is deliberately
            // left alone for them: it is a first-class experiment axis,
            // not a scenario-owned knob.
            _ => {}
        }
    }

    /// Generate the scenario's synthetic trace. Cluster-side scenarios
    /// (`hetero-cap`, `hotspot`) and the engine presets (`straggler`,
    /// `multi-locality`, `multi-rack`, `multi-zone`) share the baseline
    /// trace shape — their twists live in [`Scenario::apply`]'s
    /// cluster/engine knobs. The match is deliberately exhaustive so a
    /// future variant cannot compile without declaring its trace shape.
    pub fn synth(&self, cfg: &TraceConfig, rng: &mut Rng) -> Trace {
        match self {
            Scenario::Alibaba
            | Scenario::HeteroCap
            | Scenario::Hotspot
            | Scenario::Straggler
            | Scenario::KReplica
            | Scenario::MultiLocality
            | Scenario::MultiRack
            | Scenario::MultiZone => Trace::synth_alibaba(cfg, rng),
            Scenario::Bursty | Scenario::BurstyHetero => synth_bursty(cfg, rng),
            Scenario::HeavyTail | Scenario::HotspotHeavyTail => synth_heavy_tail(cfg, rng),
        }
    }
}

/// Bursty variant: baseline group structure, on/off arrivals.
fn synth_bursty(cfg: &TraceConfig, rng: &mut Rng) -> Trace {
    assert!(cfg.jobs > 0);
    let group_counts = trace::gen_group_counts(cfg, rng);
    let total_groups: usize = group_counts.iter().sum();
    let raw: Vec<f64> = (0..total_groups)
        .map(|_| rng.gen_lognormal(0.0, 1.6))
        .collect();
    let sizes = trace::calibrate_sizes(&raw, cfg.total_tasks);
    let arrivals = gen_bursty_arrivals(cfg.jobs, rng);
    trace::assemble(&arrivals, &group_counts, &sizes)
}

/// Heavy-tail variant: Pareto(1.5) group sizes, baseline arrivals.
fn synth_heavy_tail(cfg: &TraceConfig, rng: &mut Rng) -> Trace {
    assert!(cfg.jobs > 0);
    let group_counts = trace::gen_group_counts(cfg, rng);
    let total_groups: usize = group_counts.iter().sum();
    let raw: Vec<f64> = (0..total_groups).map(|_| rng.gen_pareto(1.5)).collect();
    let sizes = trace::calibrate_sizes(&raw, cfg.total_tasks);
    let arrivals = trace::gen_exp_arrivals(cfg.jobs, rng);
    trace::assemble(&arrivals, &group_counts, &sizes)
}

/// On/off modulated arrivals: trains of `~1..16` jobs with intra-burst
/// gaps 80× shorter than the idle gaps separating trains. Only the
/// *shape* matters — materialization rescales the whole timeline to hit
/// the target utilization — so no absolute-rate calibration is needed.
fn gen_bursty_arrivals(n: usize, rng: &mut Rng) -> Vec<f64> {
    const IDLE_MEAN: f64 = 8.0;
    const INTRA_MEAN: f64 = 0.1;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    let mut left_in_burst = 0u64;
    for _ in 0..n {
        if left_in_burst == 0 {
            t += rng.gen_exp(1.0 / IDLE_MEAN);
            left_in_burst = 1 + rng.gen_range(15);
        }
        out.push(t);
        t += rng.gen_exp(1.0 / INTRA_MEAN);
        left_in_burst -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(jobs: usize, tasks: usize) -> TraceConfig {
        let mut c = TraceConfig::default();
        c.jobs = jobs;
        c.total_tasks = tasks;
        c
    }

    #[test]
    fn every_scenario_hits_exact_totals() {
        let c = cfg(60, 6_000);
        for sc in Scenario::ALL {
            let mut rng = Rng::seed_from(100);
            let t = sc.synth(&c, &mut rng);
            assert_eq!(t.jobs.len(), 60, "{}", sc.name());
            assert_eq!(t.total_tasks(), 6_000, "{}", sc.name());
            assert!(
                t.jobs.iter().flat_map(|j| &j.group_sizes).all(|&s| s >= 1),
                "{}",
                sc.name()
            );
            for w in t.jobs.windows(2) {
                assert!(w[0].arrival_raw <= w[1].arrival_raw, "{}", sc.name());
            }
        }
    }

    #[test]
    fn bursty_arrivals_are_overdispersed() {
        let mut rng = Rng::seed_from(101);
        let t = Scenario::Bursty.synth(&cfg(400, 20_000), &mut rng);
        let gaps: Vec<f64> = t
            .jobs
            .windows(2)
            .map(|w| w[1].arrival_raw - w[0].arrival_raw)
            .collect();
        let n = gaps.len() as f64;
        let mean = gaps.iter().sum::<f64>() / n;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
        let cv = var.sqrt() / mean;
        // A Poisson process has CV = 1; the on/off mixture is far above.
        assert!(cv > 1.5, "coefficient of variation {cv}");
    }

    #[test]
    fn heavy_tail_has_heavier_max_than_baseline() {
        let c = cfg(100, 50_000);
        let mut r1 = Rng::seed_from(102);
        let t = Scenario::HeavyTail.synth(&c, &mut r1);
        let max = *t.jobs.iter().flat_map(|j| &j.group_sizes).max().unwrap();
        let mean = 50_000.0 / t.total_groups() as f64;
        assert!(
            max as f64 > 5.0 * mean,
            "Pareto tail: max {max} vs mean {mean:.1}"
        );
    }

    #[test]
    fn apply_sets_cluster_knobs() {
        let mut c = ExperimentConfig::default();
        Scenario::HeteroCap.apply(&mut c);
        assert_eq!(c.trace.scenario, Scenario::HeteroCap);
        assert!(c.cluster.mu_skew > 0.0);

        let mut c = ExperimentConfig::default();
        Scenario::Hotspot.apply(&mut c);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Scatter);
        assert_eq!(c.cluster.zipf_alpha, 1.5);

        // apply is unconditional — precedence is by ordering, so a knob
        // set *before* apply is overwritten (callers that want user
        // choices to win apply the scenario first)...
        let mut c = ExperimentConfig::default();
        c.cluster.zipf_alpha = 0.5;
        Scenario::Hotspot.apply(&mut c);
        assert_eq!(c.cluster.zipf_alpha, 1.5);
        // ...and a knob set *after* apply stays — including values equal
        // to the neutral default, like alpha = 0.
        let mut c = ExperimentConfig::default();
        Scenario::Hotspot.apply(&mut c);
        c.cluster.zipf_alpha = 0.0;
        assert_eq!(c.cluster.zipf_alpha, 0.0);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Scatter);

        let mut c = ExperimentConfig::default();
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c, ExperimentConfig::default());

        // Re-selecting the baseline after a cluster-side scenario must
        // restore the baseline cluster knobs, not keep the old twist.
        let mut c = ExperimentConfig::default();
        Scenario::Hotspot.apply(&mut c);
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Ring);
        assert_eq!(c.cluster.mu_skew, 0.0);
        let mut c = ExperimentConfig::default();
        Scenario::HeteroCap.apply(&mut c);
        Scenario::Bursty.apply(&mut c);
        assert_eq!(c.cluster.mu_skew, 0.0);

        // Compound presets set both axes...
        let mut c = ExperimentConfig::default();
        Scenario::BurstyHetero.apply(&mut c);
        assert_eq!(c.trace.scenario, Scenario::BurstyHetero);
        assert!(c.cluster.mu_skew > 0.0);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Ring);

        let mut c = ExperimentConfig::default();
        Scenario::HotspotHeavyTail.apply(&mut c);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Scatter);
        assert_eq!(c.cluster.zipf_alpha, 1.5);
        assert_eq!(c.cluster.mu_skew, 0.0);

        // ...and re-selecting the baseline clears them again.
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c.cluster.placement_mode, PlacementMode::Ring);
    }

    #[test]
    fn compound_scenarios_compose_their_axes() {
        // bursty-hetero: the trace really is bursty (same generator as
        // `bursty` for the same rng stream)...
        let c = cfg(50, 3_000);
        let mut r1 = Rng::seed_from(500);
        let mut r2 = Rng::seed_from(500);
        let a = Scenario::Bursty.synth(&c, &mut r1);
        let b = Scenario::BurstyHetero.synth(&c, &mut r2);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.arrival_raw, y.arrival_raw);
            assert_eq!(x.group_sizes, y.group_sizes);
        }
        // ...and hotspot-heavy-tail shares the heavy-tail generator.
        let mut r1 = Rng::seed_from(501);
        let mut r2 = Rng::seed_from(501);
        let a = Scenario::HeavyTail.synth(&c, &mut r1);
        let b = Scenario::HotspotHeavyTail.synth(&c, &mut r2);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.group_sizes, y.group_sizes);
        }
        // Cluster-twist classification.
        assert!(Scenario::BurstyHetero.has_cluster_twist());
        assert!(Scenario::HotspotHeavyTail.has_cluster_twist());
        assert!(!Scenario::BurstyHetero.is_cluster_side());
        assert!(!Scenario::HotspotHeavyTail.is_cluster_side());
        assert!(!Scenario::Bursty.has_cluster_twist());
    }

    #[test]
    fn engine_presets_set_and_reset_des_knobs() {
        use crate::des::service::{EngineKind, ServiceModel};
        use crate::topology::TopologyKind;
        let mut c = ExperimentConfig::default();
        Scenario::Straggler.apply(&mut c);
        assert_eq!(c.sim.engine, EngineKind::Des);
        assert!(matches!(c.sim.service, ServiceModel::ParetoTail { .. }));
        assert!(c.sim.speculate >= 1.0);
        assert_eq!(c.sim.locality_penalty, 1.0);
        c.validate().unwrap();
        // The trace shape stays baseline...
        assert!(!Scenario::Straggler.has_cluster_twist());
        assert!(Scenario::Straggler.has_engine_twist());
        // ...and re-selecting the baseline restores the analytic engine.
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c, ExperimentConfig::default());

        // The k-replica preset arms a K = 3 tail-budget race and resets
        // cleanly.
        let mut c = ExperimentConfig::default();
        Scenario::KReplica.apply(&mut c);
        assert_eq!(c.sim.engine, EngineKind::Des);
        assert!(matches!(c.sim.service, ServiceModel::ParetoTail { .. }));
        assert_eq!(c.sim.replicas, 3);
        assert_eq!(c.sim.effective_replicas(), 3);
        assert!(c.sim.speculate >= 1.0);
        c.validate().unwrap();
        assert!(Scenario::KReplica.has_engine_twist());
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c, ExperimentConfig::default());

        let mut c = ExperimentConfig::default();
        Scenario::MultiLocality.apply(&mut c);
        assert_eq!(c.sim.engine, EngineKind::Des);
        assert!(c.sim.locality_penalty > 1.0);
        assert!(c.sim.service.is_deterministic());
        assert_eq!(
            c.sim.topology,
            TopologyKind::Flat,
            "multi-locality is the flat two-tier topology alias"
        );
        c.validate().unwrap();
        assert!(Scenario::MultiLocality.has_engine_twist());

        // The hierarchical presets select their topology, and
        // re-selecting the baseline resets it with the other engine
        // knobs.
        let mut c = ExperimentConfig::default();
        Scenario::MultiRack.apply(&mut c);
        assert_eq!(c.sim.engine, EngineKind::Des);
        assert_eq!(c.sim.topology, TopologyKind::MultiRack);
        assert!(c.sim.locality_penalty > 1.0);
        c.validate().unwrap();
        assert!(Scenario::MultiRack.has_engine_twist());
        Scenario::Alibaba.apply(&mut c);
        assert_eq!(c, ExperimentConfig::default());

        let mut c = ExperimentConfig::default();
        Scenario::MultiZone.apply(&mut c);
        assert_eq!(c.sim.topology, TopologyKind::MultiZone);
        c.validate().unwrap();

        // A topology key after the scenario still wins (ordering rule).
        let parsed = ExperimentConfig::from_str(
            "scenario = multi-rack\ntopology = multi-zone",
        )
        .unwrap();
        assert_eq!(parsed.sim.topology, TopologyKind::MultiZone);
        // ...and a scenario key after the knob resets it.
        let parsed = ExperimentConfig::from_str(
            "engine = des\ntopology = multi-zone\nscenario = multi-rack",
        )
        .unwrap();
        assert_eq!(parsed.sim.topology, TopologyKind::MultiRack);
        // Explicit knobs after the scenario still win (ordering rule) —
        // asserted through the real config-file path.
        let parsed = ExperimentConfig::from_str(
            "scenario = multi-locality\nlocality_penalty = 3.0",
        )
        .unwrap();
        assert_eq!(parsed.sim.locality_penalty, 3.0);
        assert_eq!(parsed.sim.engine, EngineKind::Des);
        // ...and a scenario key after the knob resets it (scenario owns
        // the engine knobs).
        let parsed = ExperimentConfig::from_str(
            "engine = des\nlocality_penalty = 3.0\nscenario = multi-locality",
        )
        .unwrap();
        assert_eq!(parsed.sim.locality_penalty, 2.0);
        // Engine presets share the baseline trace generator.
        let tc = cfg(30, 900);
        let mut r1 = Rng::seed_from(700);
        let mut r2 = Rng::seed_from(700);
        let a = Scenario::Alibaba.synth(&tc, &mut r1);
        let b = Scenario::Straggler.synth(&tc, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()), Some(sc));
        }
        assert_eq!(Scenario::parse("pareto"), Some(Scenario::HeavyTail));
        assert_eq!(Scenario::parse("hetero"), Some(Scenario::HeteroCap));
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn scenarios_runnable_end_to_end() {
        use crate::sched::SchedPolicy;
        use crate::sim::run_experiment;
        for sc in Scenario::ALL {
            let mut c = crate::sweep::quick_base(7);
            c.trace.jobs = 15;
            c.trace.total_tasks = 900;
            sc.apply(&mut c);
            let out = run_experiment(&c, SchedPolicy::ocwf(true))
                .unwrap_or_else(|e| panic!("{}: {e}", sc.name()));
            assert_eq!(out.jcts.len(), 15, "{}", sc.name());
        }
    }
}
