//! Job traces: the Alibaba-like synthetic generator and a loader for the
//! real `cluster-trace-v2017 batch_task.csv` schema.
//!
//! The paper drives its simulation with a 250-job segment of the Alibaba
//! 2017 batch trace (113,653 tasks; 5.52 task groups per job on average),
//! treating every trace entry (task event) as one task group. That dataset
//! is not redistributable and is not present in this offline environment,
//! so [`Trace::synth_alibaba`] generates a statistically matched workload:
//! the same job count, total task count, mean group count, heavy-tailed
//! (lognormal) group sizes and exponential interarrivals. The evaluation
//! consumes only (arrival order, group counts, group sizes), so matching
//! those marginals preserves the behaviours the paper measures; users with
//! the real CSV can pass it through [`Trace::from_csv`] instead.

pub mod csv;
pub mod scenarios;

use crate::cluster::placement::Placement;
use crate::cluster::Cluster;
use crate::config::TraceConfig;
use crate::job::{Job, Slots, TaskGroup};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// One job as recorded in a trace: an abstract arrival time (arbitrary
/// units, rescaled at materialization) and the task count of each group.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceJob {
    pub arrival_raw: f64,
    pub group_sizes: Vec<u64>,
}

/// An ordered collection of trace jobs.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub jobs: Vec<TraceJob>,
}

/// Per-job group counts: shifted geometric with mean `cfg.mean_groups`.
/// `P(K = 1 + g) = (1-q) q^g` has mean `1 + q/(1-q)`; solve for q.
/// Shared by the baseline generator and every scenario variant.
pub(crate) fn gen_group_counts(cfg: &TraceConfig, rng: &mut Rng) -> Vec<usize> {
    let extra = (cfg.mean_groups - 1.0).max(0.0);
    let q = extra / (extra + 1.0);
    (0..cfg.jobs)
        .map(|_| {
            let mut k = 1usize;
            while rng.gen_f64() < q && k < 200 {
                k += 1;
            }
            k
        })
        .collect()
}

/// Turn raw positive size draws into integer group sizes whose grand
/// total is exactly `max(total_tasks, #groups)` (min 1 task per group):
/// rescale, round, then distribute the rounding residue over the largest
/// groups. When `total_tasks < #groups` the target is unreachable with
/// 1-task minimums; the loop detects the stall (a full pass with no
/// progress) and settles on one task per group instead of spinning.
pub(crate) fn calibrate_sizes(raw: &[f64], total_tasks: usize) -> Vec<u64> {
    let raw_sum: f64 = raw.iter().sum();
    let scale = total_tasks as f64 / raw_sum;
    let mut sizes: Vec<u64> = raw
        .iter()
        .map(|&x| (x * scale).max(1.0).round().max(1.0) as u64)
        .collect();
    let mut current: i64 = sizes.iter().map(|&s| s as i64).sum();
    let target = total_tasks as i64;
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(sizes[i]));
    let mut oi = 0;
    let mut stalled = 0;
    while current != target && stalled < order.len() {
        let i = order[oi % order.len()];
        if current < target {
            sizes[i] += 1;
            current += 1;
            stalled = 0;
        } else if sizes[i] > 1 {
            sizes[i] -= 1;
            current -= 1;
            stalled = 0;
        } else {
            stalled += 1;
        }
        oi += 1;
    }
    sizes
}

/// Poisson arrivals: exponential(1) interarrivals, abstract units
/// (materialization rescales the timeline).
pub(crate) fn gen_exp_arrivals(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        arrivals.push(t);
        t += rng.gen_exp(1.0);
    }
    arrivals
}

/// Stitch arrivals + per-job group counts + flat group sizes into a
/// [`Trace`].
pub(crate) fn assemble(arrivals: &[f64], group_counts: &[usize], sizes: &[u64]) -> Trace {
    debug_assert_eq!(arrivals.len(), group_counts.len());
    debug_assert_eq!(group_counts.iter().sum::<usize>(), sizes.len());
    let mut jobs = Vec::with_capacity(arrivals.len());
    let mut cursor = 0;
    for (j, &k) in group_counts.iter().enumerate() {
        jobs.push(TraceJob {
            arrival_raw: arrivals[j],
            group_sizes: sizes[cursor..cursor + k].to_vec(),
        });
        cursor += k;
    }
    Trace { jobs }
}

impl Trace {
    /// Generate a synthetic trace matched to the aggregate statistics the
    /// paper reports for its Alibaba segment (§V-A). See module docs.
    pub fn synth_alibaba(cfg: &TraceConfig, rng: &mut Rng) -> Trace {
        assert!(cfg.jobs > 0);
        let group_counts = gen_group_counts(cfg, rng);
        let total_groups: usize = group_counts.iter().sum();
        // Group sizes: lognormal(μ=0, σ=1.6) — heavy-tailed like batch
        // instance counts — calibrated to hit cfg.total_tasks exactly.
        let raw: Vec<f64> = (0..total_groups)
            .map(|_| rng.gen_lognormal(0.0, 1.6))
            .collect();
        let sizes = calibrate_sizes(&raw, cfg.total_tasks);
        let arrivals = gen_exp_arrivals(cfg.jobs, rng);
        assemble(&arrivals, &group_counts, &sizes)
    }

    /// Load a trace from a `batch_task.csv`-schema file (see [`csv`]).
    pub fn from_csv_file(path: &str) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        csv::parse_batch_task(&text)
    }

    /// Build a trace per config: from CSV when `csv_path` is set, else
    /// synthetic in the configured scenario's shape.
    pub fn build(cfg: &TraceConfig, rng: &mut Rng) -> Result<Trace> {
        match &cfg.csv_path {
            Some(p) => Trace::from_csv_file(p),
            None => Ok(cfg.scenario.synth(cfg, rng)),
        }
    }

    pub fn total_tasks(&self) -> u64 {
        self.jobs.iter().flat_map(|j| j.group_sizes.iter()).sum()
    }

    pub fn total_groups(&self) -> usize {
        self.jobs.iter().map(|j| j.group_sizes.len()).sum()
    }

    /// Materialize the trace into concrete [`Job`]s against a cluster:
    /// samples each group's available-server set (Zipf placement) and each
    /// job's per-server capacity μ, and rescales arrivals so the offered
    /// load is `utilization` (paper §V-A: "we scale the interarrival times
    /// of the jobs to simulate different levels of system utilization").
    ///
    /// Offered-load calibration: total work ≈ Σ_c |T_c| / E[μ] server-slots
    /// must equal `utilization · M · span`, so
    /// `span = total_tasks / (utilization · M · E[μ])`.
    ///
    /// Internally this is a loop over [`materialize_one`] — the same
    /// per-job step the streaming ingestion path
    /// ([`crate::sim::stream::JobStream`]) drives one job at a time, so
    /// the two paths draw the identical RNG sequence by construction.
    pub fn materialize(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        utilization: f64,
        rng: &mut Rng,
    ) -> Result<Vec<Job>> {
        let span = arrival_span(self.total_tasks(), utilization, cluster)?;
        let raw_last = raw_last(self.jobs.last().map(|j| j.arrival_raw));
        let mut jobs = Vec::with_capacity(self.jobs.len());
        for (id, tj) in self.jobs.iter().enumerate() {
            jobs.push(materialize_one(
                id, tj, cluster, placement, span, raw_last, rng,
            ));
        }
        // Arrival order must be non-decreasing (trace order is chronological).
        for w in jobs.windows(2) {
            debug_assert!(w[0].arrival <= w[1].arrival);
        }
        Ok(jobs)
    }
}

/// The arrival-timeline span (in slots) that realizes an offered load of
/// `utilization`: `total_tasks / (utilization · M · E[μ])`. Shared by
/// [`Trace::materialize`] and the streaming materializer so the rescaling
/// cannot drift between the two paths.
pub fn arrival_span(total_tasks: u64, utilization: f64, cluster: &Cluster) -> Result<f64> {
    if !(utilization > 0.0 && utilization < 1.0) {
        return Err(Error::Config("utilization must be in (0,1)".into()));
    }
    let m = cluster.num_servers() as f64;
    Ok(total_tasks as f64 / (utilization * m * cluster.mean_mu()))
}

/// The raw-arrival normalizer: the *last* job's `arrival_raw` (trace
/// order is chronological), floored at 1e-9 so an empty or single-instant
/// trace still divides cleanly.
pub fn raw_last(last_arrival_raw: Option<f64>) -> f64 {
    last_arrival_raw.unwrap_or(0.0).max(1e-9)
}

/// Materialize a single trace job: rescale its arrival onto the slot
/// timeline and sample its per-group server sets and per-server μ. The
/// RNG draws happen in a fixed order (each group's placement, then the μ
/// vector), so a sequential scan over trace jobs — whether batch
/// ([`Trace::materialize`]) or streaming — produces bit-identical jobs.
pub fn materialize_one(
    id: usize,
    tj: &TraceJob,
    cluster: &Cluster,
    placement: &Placement,
    span: f64,
    raw_last: f64,
    rng: &mut Rng,
) -> Job {
    let cfg = cluster.config();
    let arrival = ((tj.arrival_raw / raw_last) * span).floor() as Slots;
    let groups = tj
        .group_sizes
        .iter()
        .map(|&size| {
            TaskGroup::new(
                size,
                placement.sample_group_servers(rng, cfg.avail_lo, cfg.avail_hi),
            )
        })
        .collect();
    Job {
        id,
        arrival,
        groups,
        mu: cluster.sample_mu(rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, TraceConfig};

    fn small_cfg() -> TraceConfig {
        TraceConfig {
            jobs: 50,
            total_tasks: 5_000,
            mean_groups: 5.52,
            utilization: 0.5,
            ..Default::default()
        }
    }

    #[test]
    fn synth_matches_marginals_exactly_and_on_average() {
        let cfg = small_cfg();
        let mut rng = Rng::seed_from(30);
        let t = Trace::synth_alibaba(&cfg, &mut rng);
        assert_eq!(t.jobs.len(), 50);
        assert_eq!(t.total_tasks(), 5_000, "exact total-task calibration");
        let mean_groups = t.total_groups() as f64 / 50.0;
        assert!(
            (mean_groups - 5.52).abs() < 2.0,
            "mean groups {mean_groups} should be near 5.52"
        );
        // Arrivals strictly ordered.
        for w in t.jobs.windows(2) {
            assert!(w[0].arrival_raw <= w[1].arrival_raw);
        }
        // Heavy tail: largest group well above the mean size.
        let max = t.jobs.iter().flat_map(|j| &j.group_sizes).max().unwrap();
        let mean_size = 5000.0 / t.total_groups() as f64;
        assert!(*max as f64 > 3.0 * mean_size, "max {max}, mean {mean_size}");
    }

    #[test]
    fn synth_paper_scale_defaults() {
        let cfg = TraceConfig::default();
        let mut rng = Rng::seed_from(31);
        let t = Trace::synth_alibaba(&cfg, &mut rng);
        assert_eq!(t.jobs.len(), 250);
        assert_eq!(t.total_tasks(), 113_653);
        let mg = t.total_groups() as f64 / 250.0;
        assert!((mg - 5.52).abs() < 1.0, "mean groups {mg}");
    }

    #[test]
    fn materialize_scales_span_with_utilization() {
        let tcfg = small_cfg();
        let ccfg = ClusterConfig::default();
        let mut rng = Rng::seed_from(32);
        let trace = Trace::synth_alibaba(&tcfg, &mut rng);
        let cluster = Cluster::generate(&ccfg, &mut rng);
        let placement = Placement::new(100, 0.0, &mut rng);

        let jobs_lo = trace
            .materialize(&cluster, &placement, 0.25, &mut rng.fork(1))
            .unwrap();
        let jobs_hi = trace
            .materialize(&cluster, &placement, 0.75, &mut rng.fork(2))
            .unwrap();
        let span_lo = jobs_lo.last().unwrap().arrival;
        let span_hi = jobs_hi.last().unwrap().arrival;
        // 3x utilization => ~1/3 the span (integer-slot flooring of the
        // short span adds a little quantization error).
        let ratio = span_lo as f64 / span_hi as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
        // Task counts preserved.
        let n: u64 = jobs_lo.iter().map(|j| j.total_tasks()).sum();
        assert_eq!(n, 5_000);
    }

    #[test]
    fn materialize_respects_cluster_ranges() {
        let tcfg = small_cfg();
        let ccfg = ClusterConfig::default();
        let mut rng = Rng::seed_from(33);
        let trace = Trace::synth_alibaba(&tcfg, &mut rng);
        let cluster = Cluster::generate(&ccfg, &mut rng);
        let placement = Placement::new(100, 2.0, &mut rng);
        let jobs = trace
            .materialize(&cluster, &placement, 0.5, &mut rng)
            .unwrap();
        for j in &jobs {
            assert_eq!(j.mu.len(), 100);
            assert!(j.mu.iter().all(|&x| (3..=5).contains(&x)));
            for g in &j.groups {
                assert!(g.servers.len() >= 8 && g.servers.len() <= 12);
                assert!(g.servers.iter().all(|&s| s < 100));
                assert!(g.size >= 1);
            }
        }
    }

    #[test]
    fn materialize_rejects_bad_utilization() {
        let tcfg = small_cfg();
        let mut rng = Rng::seed_from(34);
        let trace = Trace::synth_alibaba(&tcfg, &mut rng);
        let cluster = Cluster::generate(&ClusterConfig::default(), &mut rng);
        let placement = Placement::new(100, 0.0, &mut rng);
        assert!(trace.materialize(&cluster, &placement, 0.0, &mut rng).is_err());
        assert!(trace.materialize(&cluster, &placement, 1.0, &mut rng).is_err());
    }
}
