//! Parser for the Alibaba `cluster-trace-v2017 batch_task.csv` schema.
//!
//! Columns (no header):
//! `create_timestamp, modify_timestamp, job_id, task_id, instance_num,
//!  status, plan_cpu, plan_mem`
//!
//! Following the paper (§V-A): each row (task event) becomes one task
//! group of its job with `instance_num` tasks; a job's arrival time is the
//! minimum `create_timestamp` over its rows. Jobs are emitted in arrival
//! order. Rows with `instance_num <= 0` or unparsable fields are rejected
//! with a line number so trace problems are debuggable.
//!
//! Two readers share one row parser:
//!
//! - [`parse_batch_task`] — the batch path: the whole text in memory, a
//!   `BTreeMap` keyed by job id, a final global sort. Exact for any row
//!   order; the differential oracle for the streaming reader.
//! - [`CsvWindowReader`] — the streaming path: rows are consumed through
//!   a bounded lookahead window (trace-time units), jobs are emitted in
//!   the same `(arrival, job_id)` order with O(window) resident rows. A
//!   row further than `lookahead` behind the stream head is an error
//!   (raise the lookahead or fall back to the batch parser), which is
//!   exactly the bound that makes bounded memory safe.

use std::collections::VecDeque;
use std::io::{self, BufRead, Write};

use super::{Trace, TraceJob};
use crate::{Error, Result};

/// Default streaming lookahead, in raw trace-time units (seconds for the
/// Alibaba trace): how far out of order rows may arrive.
pub const DEFAULT_LOOKAHEAD: f64 = 3600.0;

/// One parsed row: borrowed job id, so the contiguous-job fast path can
/// compare ids without allocating.
struct Row<'l> {
    ts: f64,
    job_id: &'l str,
    instances: u64,
}

/// Parse one line into a [`Row`], `None` for blank/comment lines.
/// `lineno` is zero-based; errors report it one-based. All three readers
/// (batch, fast path, windowed) go through here, so field validation and
/// line-numbered errors cannot drift between them.
fn parse_row(raw: &str, lineno: usize) -> Result<Option<Row<'_>>> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut fields = [""; 5];
    let mut n = 0usize;
    for f in line.split(',') {
        if n < 5 {
            fields[n] = f.trim();
        }
        n += 1;
    }
    if n < 5 {
        return Err(Error::TraceParse {
            line: lineno + 1,
            msg: format!("expected >= 5 comma-separated fields, got {n}"),
        });
    }
    let ts: f64 = fields[0].parse().map_err(|_| Error::TraceParse {
        line: lineno + 1,
        msg: format!("bad create_timestamp `{}`", fields[0]),
    })?;
    let job_id = fields[2];
    if job_id.is_empty() {
        return Err(Error::TraceParse {
            line: lineno + 1,
            msg: "empty job_id".into(),
        });
    }
    let instances: i64 = fields[4].parse().map_err(|_| Error::TraceParse {
        line: lineno + 1,
        msg: format!("bad instance_num `{}`", fields[4]),
    })?;
    if instances <= 0 {
        return Err(Error::TraceParse {
            line: lineno + 1,
            msg: format!("instance_num must be positive, got {instances}"),
        });
    }
    Ok(Some(Row {
        ts,
        job_id,
        instances: instances as u64,
    }))
}

/// Parse CSV text in the `batch_task.csv` schema into a [`Trace`].
///
/// Trace rows for one job are typically contiguous, so the accumulator
/// for the *last-seen* job id is kept outside the map and matched against
/// the borrowed id of each row — the contiguous case touches neither the
/// map nor the allocator. On a job switch the accumulator is flushed into
/// the map (merging with any earlier burst of the same job, preserving
/// row order within the job).
pub fn parse_batch_task(text: &str) -> Result<Trace> {
    use std::collections::BTreeMap;
    // job key -> (min create ts, group sizes in row order)
    let mut jobs: BTreeMap<String, (f64, Vec<u64>)> = BTreeMap::new();
    let mut last: Option<(String, (f64, Vec<u64>))> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let Some(row) = parse_row(raw, lineno)? else {
            continue;
        };
        match &mut last {
            Some((id, acc)) if id.as_str() == row.job_id => {
                acc.0 = acc.0.min(row.ts);
                acc.1.push(row.instances);
            }
            _ => {
                if let Some((id, acc)) = last.take() {
                    merge_into(&mut jobs, id, acc);
                }
                // Resume an earlier non-contiguous burst of this job so
                // group order stays row order.
                let mut acc = jobs
                    .remove(row.job_id)
                    .unwrap_or_else(|| (f64::INFINITY, Vec::new()));
                acc.0 = acc.0.min(row.ts);
                acc.1.push(row.instances);
                last = Some((row.job_id.to_string(), acc));
            }
        }
    }
    if let Some((id, acc)) = last.take() {
        merge_into(&mut jobs, id, acc);
    }
    if jobs.is_empty() {
        return Err(Error::TraceParse {
            line: 0,
            msg: "trace contains no rows".into(),
        });
    }
    let mut ordered: Vec<(f64, Vec<u64>)> = jobs.into_values().collect();
    ordered.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let t0 = ordered[0].0;
    Ok(Trace {
        jobs: ordered
            .into_iter()
            .map(|(ts, group_sizes)| TraceJob {
                arrival_raw: ts - t0,
                group_sizes,
            })
            .collect(),
    })
}

fn merge_into(
    jobs: &mut std::collections::BTreeMap<String, (f64, Vec<u64>)>,
    id: String,
    acc: (f64, Vec<u64>),
) {
    let entry = jobs.entry(id).or_insert((f64::INFINITY, Vec::new()));
    entry.0 = entry.0.min(acc.0);
    entry.1.extend_from_slice(&acc.1);
}

/// Serialize a [`Trace`] into the `batch_task.csv` schema through any
/// writer — the exact inverse of [`parse_batch_task`] up to timestamp
/// quantization (raw arrivals are emitted in milliseconds with 3
/// decimals). Job ids are zero-padded so ties in the quantized timestamp
/// keep the original job order through the parser's stable sort. Rows are
/// formatted into one recycled line buffer, so exporting a large trace
/// streams through the writer instead of building it in memory; wrap the
/// target in a `BufWriter` for file output.
pub fn write_batch_task_csv(trace: &Trace, out: &mut impl Write) -> io::Result<()> {
    let mut line = String::with_capacity(64);
    for (j, job) in trace.jobs.iter().enumerate() {
        let ts = job.arrival_raw * 1000.0;
        for (g, size) in job.group_sizes.iter().enumerate() {
            line.clear();
            use std::fmt::Write as _;
            let _ = writeln!(
                line,
                "{ts:.3},{:.3},j_{j:06},t_{g},{size},Terminated,100,0.5",
                ts + 1.0,
            );
            out.write_all(line.as_bytes())?;
        }
    }
    Ok(())
}

/// [`write_batch_task_csv`] collected into a `String` — small traces and
/// tests.
pub fn to_batch_task_csv(trace: &Trace) -> String {
    let mut out = Vec::new();
    write_batch_task_csv(trace, &mut out).expect("Vec<u8> writes are infallible");
    String::from_utf8(out).expect("csv rows are ASCII")
}

/// Aggregates of one CSV pass the materializer needs *before* the first
/// job can be emitted: produced by [`scan_batch_task`] (pass 1 of the
/// streaming reader) with the same windowed state as pass 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CsvStreamStats {
    /// Number of distinct jobs.
    pub jobs: usize,
    /// Σ instance_num over every row.
    pub total_tasks: u64,
    /// Smallest create_timestamp (the arrival-zero anchor).
    pub t0: f64,
    /// Largest per-job arrival, already normalized: `max_j min-ts(j) - t0`,
    /// floored at 1e-9 ([`super::raw_last`]).
    pub raw_last: f64,
}

/// One open (or closed-but-unemitted) job in the streaming window.
#[derive(Debug)]
struct WinJob {
    id: String,
    min_ts: f64,
    groups: Vec<u64>,
}

fn window_err(lineno: usize, ts: f64, head: f64, lookahead: f64) -> Error {
    Error::TraceParse {
        line: lineno + 1,
        msg: format!(
            "row at create_timestamp {ts} is {:.3} behind the stream head {head}; \
             the streaming reader's lookahead window is {lookahead} — raise it or \
             use the batch parser",
            head - ts
        ),
    }
}

/// Pass 1 of the streaming reader: windowed scan computing
/// [`CsvStreamStats`]. Enforces the same lookahead invariant as pass 2,
/// so a trace that scans cleanly also streams cleanly.
pub fn scan_batch_task(reader: impl BufRead, lookahead: f64) -> Result<CsvStreamStats> {
    let mut open: Vec<(String, f64)> = Vec::new();
    let mut head = f64::NEG_INFINITY;
    let mut t0 = f64::INFINITY;
    let mut max_min = f64::NEG_INFINITY;
    let mut jobs = 0usize;
    let mut total_tasks = 0u64;
    let mut buf = String::new();
    let mut lineno = 0usize;
    let mut r = reader;
    loop {
        buf.clear();
        if r.read_line(&mut buf).map_err(Error::Io)? == 0 {
            break;
        }
        let Some(row) = parse_row(&buf, lineno)? else {
            lineno += 1;
            continue;
        };
        if row.ts < head - lookahead {
            return Err(window_err(lineno, row.ts, head, lookahead));
        }
        head = head.max(row.ts);
        t0 = t0.min(row.ts);
        total_tasks += row.instances;
        match open.iter_mut().find(|(id, _)| id == row.job_id) {
            Some((_, min_ts)) => *min_ts = min_ts.min(row.ts),
            None => {
                jobs += 1;
                open.push((row.job_id.to_string(), row.ts));
            }
        }
        // A job whose first row is more than 2·lookahead behind the head
        // can receive no further rows (any row for it would be > lookahead
        // late), so its min is final — retire it from the window.
        open.retain(|&(_, min_ts)| {
            if head > min_ts + 2.0 * lookahead {
                max_min = max_min.max(min_ts);
                false
            } else {
                true
            }
        });
        lineno += 1;
    }
    if jobs == 0 {
        return Err(Error::TraceParse {
            line: 0,
            msg: "trace contains no rows".into(),
        });
    }
    for (_, min_ts) in open {
        max_min = max_min.max(min_ts);
    }
    Ok(CsvStreamStats {
        jobs,
        total_tasks,
        t0,
        raw_last: super::raw_last(Some(max_min - t0)),
    })
}

/// Pass 2 of the streaming reader: emits [`TraceJob`]s in the exact
/// `(arrival_raw, job_id)` order of [`parse_batch_task`], holding only
/// the jobs within `2 × lookahead` of the stream head.
///
/// Emission rule: the window's smallest `(min_ts, id)` job is emitted
/// once it is *closed* (`head > min_ts + 2·lookahead`, so no further row
/// can belong to it) — and closure also guarantees no later row can open
/// a job that sorts before it (a new job's first row is within
/// `lookahead` of the head, hence strictly after the closed job's min).
pub struct CsvWindowReader {
    reader: Box<dyn BufRead>,
    lookahead: f64,
    t0: f64,
    window: Vec<WinJob>,
    ready: VecDeque<TraceJob>,
    head: f64,
    buf: String,
    lineno: usize,
    eof: bool,
    peak_window: usize,
}

impl CsvWindowReader {
    pub fn new(reader: Box<dyn BufRead>, stats: &CsvStreamStats, lookahead: f64) -> Self {
        CsvWindowReader {
            reader,
            lookahead,
            t0: stats.t0,
            window: Vec::new(),
            ready: VecDeque::new(),
            head: f64::NEG_INFINITY,
            buf: String::new(),
            lineno: 0,
            eof: false,
            peak_window: 0,
        }
    }

    /// Open a CSV file for streaming: pass 1 ([`scan_batch_task`]) then a
    /// reader positioned for pass 2. The file is opened twice; only
    /// O(window) state is ever resident.
    pub fn open(path: &str, lookahead: f64) -> Result<(Self, CsvStreamStats)> {
        let stats = scan_batch_task(
            io::BufReader::new(std::fs::File::open(path).map_err(Error::Io)?),
            lookahead,
        )?;
        let reader = io::BufReader::new(std::fs::File::open(path).map_err(Error::Io)?);
        Ok((Self::new(Box::new(reader), &stats, lookahead), stats))
    }

    /// High-water mark of jobs resident in the window (the O(window)
    /// residency claim, observable).
    pub fn peak_window(&self) -> usize {
        self.peak_window
    }

    /// Move every closed window job that sorts before all others into the
    /// ready queue, in `(min_ts, id)` order.
    fn drain_closed(&mut self) {
        loop {
            let Some(best) = self
                .window
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    a.min_ts
                        .partial_cmp(&b.min_ts)
                        .unwrap()
                        .then_with(|| a.id.cmp(&b.id))
                })
                .map(|(i, _)| i)
            else {
                return;
            };
            let closed = self.head > self.window[best].min_ts + 2.0 * self.lookahead;
            if !closed {
                return;
            }
            let wj = self.window.swap_remove(best);
            self.ready.push_back(TraceJob {
                arrival_raw: wj.min_ts - self.t0,
                group_sizes: wj.groups,
            });
        }
    }

    /// Flush the whole window at EOF, sorted.
    fn drain_all(&mut self) {
        self.window.sort_by(|a, b| {
            a.min_ts
                .partial_cmp(&b.min_ts)
                .unwrap()
                .then_with(|| a.id.cmp(&b.id))
        });
        for wj in self.window.drain(..) {
            self.ready.push_back(TraceJob {
                arrival_raw: wj.min_ts - self.t0,
                group_sizes: wj.groups,
            });
        }
    }

    /// The next trace job in arrival order, `None` at end of trace.
    pub fn next_trace_job(&mut self) -> Result<Option<TraceJob>> {
        loop {
            if let Some(tj) = self.ready.pop_front() {
                return Ok(Some(tj));
            }
            if self.eof {
                return Ok(None);
            }
            self.buf.clear();
            if self.reader.read_line(&mut self.buf).map_err(Error::Io)? == 0 {
                self.eof = true;
                self.drain_all();
                continue;
            }
            let lineno = self.lineno;
            self.lineno += 1;
            let Some(row) = parse_row(&self.buf, lineno)? else {
                continue;
            };
            if row.ts < self.head - self.lookahead {
                return Err(window_err(lineno, row.ts, self.head, self.lookahead));
            }
            self.head = self.head.max(row.ts);
            match self.window.iter_mut().find(|w| w.id == row.job_id) {
                Some(w) => {
                    w.min_ts = w.min_ts.min(row.ts);
                    w.groups.push(row.instances);
                }
                None => {
                    self.window.push(WinJob {
                        id: row.job_id.to_string(),
                        min_ts: row.ts,
                        groups: vec![row.instances],
                    });
                    self.peak_window = self.peak_window.max(self.window.len());
                }
            }
            self.drain_closed();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
100,200,j_42,t_1,16,Terminated,100,0.5
120,220,j_42,t_2,4,Terminated,100,0.5
90,300,j_7,t_1,8,Terminated,50,0.25
150,400,j_99,t_1,1,Terminated,50,0.25
";

    #[test]
    fn parses_jobs_groups_and_arrival_order() {
        let t = parse_batch_task(SAMPLE).unwrap();
        assert_eq!(t.jobs.len(), 3);
        // j_7 arrives first (ts 90), then j_42 (min ts 100), then j_99.
        assert_eq!(t.jobs[0].group_sizes, vec![8]);
        assert_eq!(t.jobs[1].group_sizes, vec![16, 4]);
        assert_eq!(t.jobs[2].group_sizes, vec![1]);
        // Arrivals normalized to start at 0.
        assert_eq!(t.jobs[0].arrival_raw, 0.0);
        assert_eq!(t.jobs[1].arrival_raw, 10.0);
        assert_eq!(t.jobs[2].arrival_raw, 60.0);
        assert_eq!(t.total_tasks(), 29);
    }

    #[test]
    fn noncontiguous_job_rows_keep_row_order() {
        // j_1's bursts are split by j_2; the fast path must merge them
        // in row order, like the plain map did.
        let t = parse_batch_task(
            "10,0,j_1,t_1,1,T,1,1\n\
             12,0,j_2,t_1,2,T,1,1\n\
             11,0,j_1,t_2,3,T,1,1\n",
        )
        .unwrap();
        assert_eq!(t.jobs.len(), 2);
        assert_eq!(t.jobs[0].group_sizes, vec![1, 3]);
        assert_eq!(t.jobs[1].group_sizes, vec![2]);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let t = parse_batch_task("# header\n\n1,2,j_1,t_1,3,T,1,1\n").unwrap();
        assert_eq!(t.jobs.len(), 1);
        assert_eq!(t.jobs[0].group_sizes, vec![3]);
    }

    #[test]
    fn rejects_bad_instance_count() {
        let err = parse_batch_task("1,2,j_1,t_1,0,T,1,1").unwrap_err();
        assert!(matches!(err, Error::TraceParse { line: 1, .. }), "{err}");
        assert!(parse_batch_task("1,2,j_1,t_1,abc,T,1,1").is_err());
    }

    #[test]
    fn rejects_short_rows_with_line_number() {
        let err = parse_batch_task("1,2,j_1,t_1,3,T,1,1\n1,2,j_2").unwrap_err();
        match err {
            Error::TraceParse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rejects_empty_trace() {
        assert!(parse_batch_task("\n\n").is_err());
        assert!(scan_batch_task("\n\n".as_bytes(), 10.0).is_err());
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let t = parse_batch_task(SAMPLE).unwrap();
        let mut out = Vec::new();
        write_batch_task_csv(&t, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text, to_batch_task_csv(&t), "string wrapper is the writer");
        let back = parse_batch_task(&text).unwrap();
        assert_eq!(back.jobs.len(), t.jobs.len());
        for (a, b) in back.jobs.iter().zip(&t.jobs) {
            assert_eq!(a.group_sizes, b.group_sizes);
        }
    }

    fn stream_all(text: &str, lookahead: f64) -> Result<(Vec<TraceJob>, CsvStreamStats)> {
        let stats = scan_batch_task(text.as_bytes(), lookahead)?;
        let mut r = CsvWindowReader::new(
            Box::new(io::Cursor::new(text.as_bytes().to_vec())),
            &stats,
            lookahead,
        );
        let mut jobs = Vec::new();
        while let Some(tj) = r.next_trace_job()? {
            jobs.push(tj);
        }
        Ok((jobs, stats))
    }

    #[test]
    fn windowed_reader_matches_batch_parser() {
        for lookahead in [30.0, 100.0, 1e6] {
            let (jobs, stats) = stream_all(SAMPLE, lookahead).unwrap();
            let t = parse_batch_task(SAMPLE).unwrap();
            assert_eq!(jobs, t.jobs, "lookahead {lookahead}");
            assert_eq!(stats.jobs, 3);
            assert_eq!(stats.total_tasks, 29);
            assert_eq!(stats.t0, 90.0);
            assert_eq!(stats.raw_last, 60.0);
        }
    }

    #[test]
    fn windowed_reader_emits_before_eof_with_bounded_window() {
        // 100 single-row jobs spaced 10 apart, lookahead 10: closure at
        // head > min + 20, so the window never holds more than a few jobs.
        let mut text = String::new();
        for j in 0..100 {
            text.push_str(&format!("{},0,j_{j:03},t_0,1,T,1,1\n", j * 10));
        }
        let (jobs, stats) = stream_all(&text, 10.0).unwrap();
        assert_eq!(jobs.len(), 100);
        assert_eq!(stats.jobs, 100);
        let t = parse_batch_task(&text).unwrap();
        assert_eq!(jobs, t.jobs);
        let stats2 = scan_batch_task(text.as_bytes(), 10.0).unwrap();
        let mut r = CsvWindowReader::new(
            Box::new(io::Cursor::new(text.as_bytes().to_vec())),
            &stats2,
            10.0,
        );
        while r.next_trace_job().unwrap().is_some() {}
        assert!(
            r.peak_window() <= 4,
            "O(window) residency: {}",
            r.peak_window()
        );
    }

    #[test]
    fn windowed_reader_rejects_rows_beyond_lookahead() {
        let text = "1000,0,j_1,t_0,1,T,1,1\n10,0,j_2,t_0,1,T,1,1\n";
        let err = stream_all(text, 100.0).unwrap_err();
        match err {
            Error::TraceParse { line, msg } => {
                assert_eq!(line, 2);
                assert!(msg.contains("lookahead"), "{msg}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // A big enough window accepts the same text.
        assert!(stream_all(text, 1000.0).is_ok());
    }
}
