//! Parser for the Alibaba `cluster-trace-v2017 batch_task.csv` schema.
//!
//! Columns (no header):
//! `create_timestamp, modify_timestamp, job_id, task_id, instance_num,
//!  status, plan_cpu, plan_mem`
//!
//! Following the paper (§V-A): each row (task event) becomes one task
//! group of its job with `instance_num` tasks; a job's arrival time is the
//! minimum `create_timestamp` over its rows. Jobs are emitted in arrival
//! order. Rows with `instance_num <= 0` or unparsable fields are rejected
//! with a line number so trace problems are debuggable.

use std::collections::BTreeMap;

use super::{Trace, TraceJob};
use crate::{Error, Result};

/// Parse CSV text in the `batch_task.csv` schema into a [`Trace`].
pub fn parse_batch_task(text: &str) -> Result<Trace> {
    // job key -> (min create ts, group sizes in row order)
    let mut jobs: BTreeMap<String, (f64, Vec<u64>)> = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
        if fields.len() < 5 {
            return Err(Error::TraceParse {
                line: lineno + 1,
                msg: format!("expected >= 5 comma-separated fields, got {}", fields.len()),
            });
        }
        let create_ts: f64 = fields[0].parse().map_err(|_| Error::TraceParse {
            line: lineno + 1,
            msg: format!("bad create_timestamp `{}`", fields[0]),
        })?;
        let job_id = fields[2].to_string();
        if job_id.is_empty() {
            return Err(Error::TraceParse {
                line: lineno + 1,
                msg: "empty job_id".into(),
            });
        }
        let instances: i64 = fields[4].parse().map_err(|_| Error::TraceParse {
            line: lineno + 1,
            msg: format!("bad instance_num `{}`", fields[4]),
        })?;
        if instances <= 0 {
            return Err(Error::TraceParse {
                line: lineno + 1,
                msg: format!("instance_num must be positive, got {instances}"),
            });
        }
        let entry = jobs.entry(job_id).or_insert((f64::INFINITY, Vec::new()));
        entry.0 = entry.0.min(create_ts);
        entry.1.push(instances as u64);
    }
    if jobs.is_empty() {
        return Err(Error::TraceParse {
            line: 0,
            msg: "trace contains no rows".into(),
        });
    }
    let mut ordered: Vec<(f64, Vec<u64>)> = jobs.into_values().collect();
    ordered.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let t0 = ordered[0].0;
    Ok(Trace {
        jobs: ordered
            .into_iter()
            .map(|(ts, group_sizes)| TraceJob {
                arrival_raw: ts - t0,
                group_sizes,
            })
            .collect(),
    })
}

/// Serialize a [`Trace`] back into the `batch_task.csv` schema — the
/// exact inverse of [`parse_batch_task`] up to timestamp quantization
/// (raw arrivals are emitted in milliseconds with 3 decimals). Job ids
/// are zero-padded so ties in the quantized timestamp keep the original
/// job order through the parser's stable sort.
pub fn to_batch_task_csv(trace: &Trace) -> String {
    let mut out = String::new();
    for (j, job) in trace.jobs.iter().enumerate() {
        let ts = job.arrival_raw * 1000.0;
        for (g, size) in job.group_sizes.iter().enumerate() {
            out.push_str(&format!(
                "{ts:.3},{:.3},j_{j:06},t_{g},{size},Terminated,100,0.5\n",
                ts + 1.0,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
100,200,j_42,t_1,16,Terminated,100,0.5
120,220,j_42,t_2,4,Terminated,100,0.5
90,300,j_7,t_1,8,Terminated,50,0.25
150,400,j_99,t_1,1,Terminated,50,0.25
";

    #[test]
    fn parses_jobs_groups_and_arrival_order() {
        let t = parse_batch_task(SAMPLE).unwrap();
        assert_eq!(t.jobs.len(), 3);
        // j_7 arrives first (ts 90), then j_42 (min ts 100), then j_99.
        assert_eq!(t.jobs[0].group_sizes, vec![8]);
        assert_eq!(t.jobs[1].group_sizes, vec![16, 4]);
        assert_eq!(t.jobs[2].group_sizes, vec![1]);
        // Arrivals normalized to start at 0.
        assert_eq!(t.jobs[0].arrival_raw, 0.0);
        assert_eq!(t.jobs[1].arrival_raw, 10.0);
        assert_eq!(t.jobs[2].arrival_raw, 60.0);
        assert_eq!(t.total_tasks(), 29);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let t = parse_batch_task("# header\n\n1,2,j_1,t_1,3,T,1,1\n").unwrap();
        assert_eq!(t.jobs.len(), 1);
        assert_eq!(t.jobs[0].group_sizes, vec![3]);
    }

    #[test]
    fn rejects_bad_instance_count() {
        let err = parse_batch_task("1,2,j_1,t_1,0,T,1,1").unwrap_err();
        assert!(matches!(err, Error::TraceParse { line: 1, .. }), "{err}");
        assert!(parse_batch_task("1,2,j_1,t_1,abc,T,1,1").is_err());
    }

    #[test]
    fn rejects_short_rows_with_line_number() {
        let err = parse_batch_task("1,2,j_1,t_1,3,T,1,1\n1,2,j_2").unwrap_err();
        match err {
            Error::TraceParse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rejects_empty_trace() {
        assert!(parse_batch_task("\n\n").is_err());
    }
}
