//! Wall-clock timers for measuring per-arrival computation overhead —
//! the paper's efficiency metric (§V-A "Metrics").

use std::time::{Duration, Instant};

use super::stats::{P2Quantile, Welford};

/// Accumulates wall-clock durations of a repeated operation (e.g. the task
/// assignment performed on each job arrival) and reports the average
/// overhead per invocation in microseconds — the left y-axis of the first
/// subplot of Figs 10–12. Besides mean/std, the meter tracks streaming
/// p50/p99 estimates (P² quantiles, O(1) state) so the overhead *tail*
/// is visible without retaining per-invocation samples.
#[derive(Clone, Debug)]
pub struct OverheadMeter {
    acc: Welford,
    p50: P2Quantile,
    p99: P2Quantile,
    total: Duration,
}

impl Default for OverheadMeter {
    fn default() -> Self {
        OverheadMeter {
            acc: Welford::default(),
            p50: P2Quantile::new(0.50),
            p99: P2Quantile::new(0.99),
            total: Duration::ZERO,
        }
    }
}

impl OverheadMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record its duration; returns the closure result.
    pub fn measure<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn record(&mut self, d: Duration) {
        self.total += d;
        let us = d.as_secs_f64() * 1e6;
        self.acc.push(us);
        self.p50.push(us);
        self.p99.push(us);
    }

    /// Number of recorded invocations.
    pub fn count(&self) -> u64 {
        self.acc.n()
    }

    /// Mean overhead per invocation, microseconds.
    pub fn mean_us(&self) -> f64 {
        self.acc.mean()
    }

    /// Standard deviation of per-invocation overhead, microseconds.
    pub fn std_us(&self) -> f64 {
        self.acc.std()
    }

    /// Streaming median overhead per invocation, microseconds (P²
    /// estimate; exact for the first five samples). NaN when empty.
    pub fn p50_us(&self) -> f64 {
        self.p50.value()
    }

    /// Streaming 99th-percentile overhead per invocation, microseconds
    /// (P² estimate). NaN when empty.
    pub fn p99_us(&self) -> f64 {
        self.p99.value()
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_counts() {
        let mut m = OverheadMeter::new();
        let v = m.measure(|| {
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        m.measure(|| ());
        assert_eq!(m.count(), 2);
        assert!(m.mean_us() >= 900.0, "mean {}", m.mean_us());
        assert!(m.total() >= Duration::from_millis(2));
    }

    #[test]
    fn empty_meter_is_nan() {
        let m = OverheadMeter::new();
        assert_eq!(m.count(), 0);
        assert!(m.mean_us().is_nan());
        assert!(m.p50_us().is_nan());
        assert!(m.p99_us().is_nan());
    }

    #[test]
    fn quantiles_track_recorded_durations() {
        let mut m = OverheadMeter::new();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i));
        }
        // Values 1..=100 µs: the median estimate must land mid-range
        // and the p99 estimate near the top; both within the observed
        // min/max by the P² invariants.
        let p50 = m.p50_us();
        let p99 = m.p99_us();
        assert!(p50 >= 1.0 && p50 <= 100.0, "p50 {p50}");
        assert!(p99 >= 1.0 && p99 <= 100.0, "p99 {p99}");
        assert!((p50 - 50.0).abs() <= 15.0, "p50 {p50}");
        assert!(p99 >= 80.0, "p99 {p99}");
        assert!(p99 >= p50, "tail above median");
    }
}
