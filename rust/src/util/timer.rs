//! Wall-clock timers for measuring per-arrival computation overhead —
//! the paper's efficiency metric (§V-A "Metrics").

use std::time::{Duration, Instant};

use super::stats::Welford;

/// Accumulates wall-clock durations of a repeated operation (e.g. the task
/// assignment performed on each job arrival) and reports the average
/// overhead per invocation in microseconds — the left y-axis of the first
/// subplot of Figs 10–12.
#[derive(Clone, Debug, Default)]
pub struct OverheadMeter {
    acc: Welford,
    total: Duration,
}

impl OverheadMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record its duration; returns the closure result.
    pub fn measure<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn record(&mut self, d: Duration) {
        self.total += d;
        self.acc.push(d.as_secs_f64() * 1e6);
    }

    /// Number of recorded invocations.
    pub fn count(&self) -> u64 {
        self.acc.n()
    }

    /// Mean overhead per invocation, microseconds.
    pub fn mean_us(&self) -> f64 {
        self.acc.mean()
    }

    /// Standard deviation of per-invocation overhead, microseconds.
    pub fn std_us(&self) -> f64 {
        self.acc.std()
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_counts() {
        let mut m = OverheadMeter::new();
        let v = m.measure(|| {
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        m.measure(|| ());
        assert_eq!(m.count(), 2);
        assert!(m.mean_us() >= 900.0, "mean {}", m.mean_us());
        assert!(m.total() >= Duration::from_millis(2));
    }

    #[test]
    fn empty_meter_is_nan() {
        let m = OverheadMeter::new();
        assert_eq!(m.count(), 0);
        assert!(m.mean_us().is_nan());
    }
}
