//! Small self-contained utilities: deterministic PRNG, statistics, timers,
//! JSON emission, and integer math helpers.
//!
//! The build environment is fully offline with no crates vendored at all
//! (even the PJRT stack's `xla` dependency is feature-gated out), so
//! everything that would normally come from `rand`, `serde_json` or
//! `statrs` is implemented here (and unit-tested).

pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

/// Ceiling division for non-negative integers: `ceil(a / b)`.
///
/// Used pervasively for the busy-time estimate of eq. (2) in the paper,
/// `b_m^c = Σ_h ceil(o_m^h / μ_m^h)`.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Argmax over a slice of `u64`, returning the first maximal index.
/// Returns `None` on an empty slice.
pub fn argmax_u64(xs: &[u64]) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        match best {
            Some((_, bx)) if bx >= x => {}
            _ => best = Some((i, x)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_remainder() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(1, 1), 1);
        assert_eq!(ceil_div(u64::MAX - 1, u64::MAX), 1);
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax_u64(&[]), None);
        assert_eq!(argmax_u64(&[5]), Some(0));
        assert_eq!(argmax_u64(&[1, 7, 7, 3]), Some(1));
        assert_eq!(argmax_u64(&[9, 1, 9]), Some(0));
    }
}
