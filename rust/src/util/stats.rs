//! Descriptive statistics and empirical CDFs, used by the metrics layer and
//! the bench harness.

/// Summary statistics over a sample of `f64`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns an all-NaN summary for empty input
    /// (`n == 0` signals it).
    pub fn from(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::from_sorted(xs);
        }
        let mut sorted = xs.to_vec();
        // total_cmp, not partial_cmp().unwrap(): a single NaN sample (an
        // empty trial's mean, a 0/0 ratio) must degrade the statistics,
        // not panic the whole sweep. NaNs sort last under the IEEE total
        // order, so finite percentiles stay correct.
        sorted.sort_by(f64::total_cmp);
        Summary::from_sorted(&sorted)
    }

    /// [`Summary::from`] over an *already sorted* slice (ascending under
    /// `f64::total_cmp`): no copy, no allocation — the hot path for
    /// pooled callers ([`crate::metrics::StatsScratch`]) that reuse one
    /// sort buffer across cells. Returns an all-NaN summary for empty
    /// input (`n == 0` signals it).
    pub fn from_sorted(sorted: &[f64]) -> Summary {
        if sorted.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p99: f64::NAN,
            };
        }
        debug_assert!(
            sorted.windows(2).all(|w| w[0].total_cmp(&w[1]).is_le()),
            "from_sorted requires ascending total_cmp order"
        );
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(sorted, 0.50),
            p90: percentile_sorted(sorted, 0.90),
            p99: percentile_sorted(sorted, 0.99),
        }
    }
}

/// Linear-interpolation percentile over a pre-sorted slice, `q ∈ [0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// An empirical CDF: for plotting the job-completion-time distributions
/// shown in the paper's Figs 10–14 (four CDF subplots per figure).
#[derive(Clone, Debug)]
pub struct Ecdf {
    /// Sorted sample values.
    pub xs: Vec<f64>,
}

impl Ecdf {
    pub fn from(sample: &[f64]) -> Ecdf {
        let mut xs = sample.to_vec();
        // NaN-safe for the same reason as `Summary::from`.
        xs.sort_by(f64::total_cmp);
        Ecdf { xs }
    }

    /// P(X <= x).
    pub fn eval(&self, x: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let idx = self.xs.partition_point(|&v| v <= x);
        idx as f64 / self.xs.len() as f64
    }

    /// Evaluate the CDF at `k` evenly spaced points spanning the sample
    /// range; returns `(x, F(x))` pairs — the series a plot consumes.
    /// Degenerate requests degrade instead of asserting: `k = 0` yields
    /// an empty series, `k = 1` the single point at the sample minimum.
    pub fn series(&self, k: usize) -> Vec<(f64, f64)> {
        ecdf_series_sorted(&self.xs, k)
    }
}

/// [`Ecdf::series`] over an *already sorted* slice (ascending under
/// `f64::total_cmp`), without constructing an [`Ecdf`] — the pooled
/// companion of [`Summary::from_sorted`]. Only the returned series
/// allocates (it is the caller's output value).
pub fn ecdf_series_sorted(sorted: &[f64], k: usize) -> Vec<(f64, f64)> {
    if sorted.is_empty() || k == 0 {
        return vec![];
    }
    let eval = |x: f64| sorted.partition_point(|&v| v <= x) as f64 / sorted.len() as f64;
    if k == 1 {
        let lo = sorted[0];
        return vec![(lo, eval(lo))];
    }
    let (lo, hi) = (sorted[0], *sorted.last().unwrap());
    (0..k)
        .map(|i| {
            let x = lo + (hi - lo) * i as f64 / (k - 1) as f64;
            (x, eval(x))
        })
        .collect()
}

/// Online mean/variance accumulator (Welford) for streaming timers.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Online quantile estimator with a fixed footprint: the P² algorithm
/// (Jain & Chlamtac, CACM 1985). Five markers track the target quantile,
/// its half-way neighbours and the extremes; marker heights move by
/// piecewise-parabolic interpolation as observations stream in. No heap
/// storage at all — `size_of::<P2Quantile>()` bytes regardless of the
/// sample size — which is what lets a million-job streaming run report
/// p50/p99 without retaining (or sorting) the sample.
///
/// Accuracy is approximate (the classic trade for O(1) memory); exact
/// percentiles stay on [`Summary::from`] wherever tests assert exactness.
#[derive(Clone, Copy, Debug)]
pub struct P2Quantile {
    /// Target quantile in [0, 1]; 0 and 1 degenerate to exact min/max.
    p: f64,
    /// Marker heights.
    q: [f64; 5],
    /// Marker positions (1-based observation counts).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2Quantile {
    /// Build a sketch for quantile `p ∈ [0, 1]`. The interior range
    /// (0, 1) runs the five-marker P² estimator; the extremes are
    /// special-cased to exact running min (`p = 0`) / max (`p = 1`)
    /// tracking — the marker dance degenerates there (its desired-position
    /// increments collapse onto the extreme markers, and the old
    /// constructor rejected both). Out-of-range and NaN `p` panic.
    pub fn new(p: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&p),
            "quantile must be in [0, 1], got {p}"
        );
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn push(&mut self, x: f64) {
        // Extreme quantiles track exactly: q[0] is the running minimum,
        // q[4] the running maximum (no marker adjustment ever runs).
        if self.p == 0.0 || self.p == 1.0 {
            if self.count == 0 {
                self.q = [x; 5];
            } else {
                self.q[0] = self.q[0].min(x);
                self.q[4] = self.q[4].max(x);
            }
            self.count += 1;
            return;
        }
        if self.count < 5 {
            self.q[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(f64::total_cmp);
            }
            return;
        }
        self.count += 1;
        // Cell the observation falls in (clamping the extremes).
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = self.q[4].max(x);
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Adjust interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let s = d.signum();
                let parabolic = self.parabolic(i, s);
                if self.q[i - 1] < parabolic && parabolic < self.q[i + 1] {
                    self.q[i] = parabolic;
                } else {
                    self.q[i] = self.linear(i, s);
                }
                self.n[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + s / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = (i as f64 + s) as usize;
        self.q[i] + s * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// The current quantile estimate; exact while fewer than five
    /// observations arrived, NaN when empty.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.p == 0.0 {
            return self.q[0];
        }
        if self.p == 1.0 {
            return self.q[4];
        }
        if self.count < 5 {
            let mut head = self.q;
            let head = &mut head[..self.count as usize];
            head.sort_by(f64::total_cmp);
            return percentile_sorted(head, self.p);
        }
        self.q[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn summary_survives_nan_samples() {
        // Regression: `partial_cmp(..).unwrap()` used to panic on the
        // first NaN sample. NaNs now sort last (total order), so the
        // finite order statistics stay meaningful.
        let s = Summary::from(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert!(s.mean.is_nan(), "NaN poisons the mean, as it must");
        assert!((s.min - 1.0).abs() < 1e-12, "min is the finite minimum");
        assert!(s.max.is_nan(), "NaN sorts last, so max reports it");
        assert!((s.p50 - 2.5).abs() < 1e-12, "p50 interpolates 2.0..3.0");

        // All-NaN input: everything NaN, nothing panics.
        let s = Summary::from(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n, 2);
        assert!(s.p50.is_nan() && s.min.is_nan());
    }

    #[test]
    fn ecdf_survives_nan_samples() {
        let e = Ecdf::from(&[1.0, f64::NAN, 2.0]);
        // Finite prefix behaves normally; the NaN occupies the tail slot.
        assert!((e.eval(1.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((e.eval(2.0) - 2.0 / 3.0).abs() < 1e-12);
        let s = e.series(4);
        assert_eq!(s.len(), 4, "series still renders");
    }

    #[test]
    fn ecdf_series_degenerate_k() {
        let e = Ecdf::from(&[3.0, 1.0, 2.0]);
        assert!(e.series(0).is_empty());
        let one = e.series(1);
        assert_eq!(one.len(), 1);
        assert!((one[0].0 - 1.0).abs() < 1e-12);
        assert!((one[0].1 - 1.0 / 3.0).abs() < 1e-12);
        // Empty sample stays empty at any k.
        assert!(Ecdf::from(&[]).series(1).is_empty());
        assert!(Ecdf::from(&[]).series(16).is_empty());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0];
        assert!((percentile_sorted(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.5) - 20.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 1.0) - 30.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.25) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_eval() {
        let e = Ecdf::from(&[1.0, 2.0, 2.0, 4.0]);
        assert!((e.eval(0.5) - 0.0).abs() < 1e-12);
        assert!((e.eval(1.0) - 0.25).abs() < 1e-12);
        assert!((e.eval(2.0) - 0.75).abs() < 1e-12);
        assert!((e.eval(5.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_series_monotone() {
        let e = Ecdf::from(&[3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let s = e.series(16);
        assert_eq!(s.len(), 16);
        for w in s.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 <= w[1].0);
        }
        assert!((s.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p2_tracks_uniform_and_heavy_tail_quantiles() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0x92);
        for &p in &[0.5, 0.9, 0.99] {
            let mut sketch = P2Quantile::new(p);
            let mut xs = Vec::new();
            for _ in 0..20_000 {
                // Mix of uniform and a lognormal-ish tail.
                let x = if rng.gen_range(4) == 0 {
                    rng.gen_lognormal(0.0, 1.0) * 50.0
                } else {
                    rng.gen_f64() * 100.0
                };
                sketch.push(x);
                xs.push(x);
            }
            xs.sort_by(f64::total_cmp);
            let exact = percentile_sorted(&xs, p);
            let got = sketch.value();
            let spread = xs[xs.len() - 1] - xs[0];
            assert!(
                (got - exact).abs() < 0.05 * spread.max(exact.abs()),
                "p{p}: sketch {got} vs exact {exact}"
            );
        }
    }

    #[test]
    fn p2_is_exact_below_five_samples_and_fixed_size() {
        let mut s = P2Quantile::new(0.5);
        assert!(s.value().is_nan());
        for &x in &[3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert!((s.value() - 2.0).abs() < 1e-12, "exact median of 3 samples");
        assert_eq!(s.count(), 3);
        // The fixed-footprint contract: a plain Copy struct, no heap.
        let _copy: P2Quantile = s;
        assert!(std::mem::size_of::<P2Quantile>() <= 200);
    }

    #[test]
    fn p2_monotone_input_lands_on_exact_quantile_region() {
        let mut s = P2Quantile::new(0.9);
        for i in 0..10_000 {
            s.push(i as f64);
        }
        let v = s.value();
        assert!((v - 9000.0).abs() < 150.0, "p90 of 0..10000 ≈ 9000, got {v}");
    }

    #[test]
    fn p2_extreme_quantiles_track_exact_min_max() {
        use crate::util::rng::Rng;
        // Property: for p = 0 and p = 1 the sketch is not an estimate —
        // it equals the exact running min / max at every prefix length,
        // including lengths below the five-sample warm-up.
        for seed in [0x11u64, 0x22, 0x33] {
            let mut rng = Rng::seed_from(seed);
            let mut lo = P2Quantile::new(0.0);
            let mut hi = P2Quantile::new(1.0);
            let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
            for _ in 0..2_000 {
                let x = rng.gen_lognormal(0.0, 1.5) - 2.0;
                lo.push(x);
                hi.push(x);
                min = min.min(x);
                max = max.max(x);
                assert!((lo.value() - min).abs() < 1e-12, "p0 == running min");
                assert!((hi.value() - max).abs() < 1e-12, "p1 == running max");
            }
        }
        // Empty sketches still report NaN at the extremes.
        assert!(P2Quantile::new(0.0).value().is_nan());
        assert!(P2Quantile::new(1.0).value().is_nan());
    }

    #[test]
    fn p2_constant_stream_is_exact_for_any_p() {
        // Property: a constant stream has every quantile equal to the
        // constant; marker adjustment must not drift off it (the
        // parabolic/linear updates see zero height everywhere).
        for &p in &[0.0, 0.01, 0.25, 0.5, 0.75, 0.99, 1.0] {
            for &c in &[-3.5, 0.0, 7.25] {
                let mut s = P2Quantile::new(p);
                for n in 1..=500u64 {
                    s.push(c);
                    assert_eq!(s.count(), n);
                    assert!(
                        (s.value() - c).abs() < 1e-12,
                        "p{p} of constant {c} drifted to {} at n={n}",
                        s.value()
                    );
                }
            }
        }
    }

    #[test]
    fn p2_value_stays_within_observed_range() {
        use crate::util::rng::Rng;
        // Property: for any p and any stream, the sketch never reports a
        // value outside the observed [min, max] envelope.
        for seed in [0xa1u64, 0xb2, 0xc3] {
            for &p in &[0.0, 0.1, 0.5, 0.9, 0.999, 1.0] {
                let mut rng = Rng::seed_from(seed ^ p.to_bits());
                let mut s = P2Quantile::new(p);
                let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
                for _ in 0..3_000 {
                    let x = rng.gen_f64() * 200.0 - 100.0;
                    s.push(x);
                    min = min.min(x);
                    max = max.max(x);
                }
                let v = s.value();
                assert!(
                    v >= min - 1e-9 && v <= max + 1e-9,
                    "p{p} reported {v} outside [{min}, {max}]"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "quantile must be in [0, 1]")]
    fn p2_rejects_out_of_range_quantile() {
        let _ = P2Quantile::new(1.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic dataset = 32/7.
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
    }
}
