//! Minimal JSON value model + writer (and a small parser for reading saved
//! experiment rows back in tests). Replaces `serde_json`, which is not
//! available in the offline vendor set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON string (strict enough for round-tripping our own
    /// output; accepts standard JSON).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut items = vec![];
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    map.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_compact_object() {
        let j = Json::obj(vec![
            ("alg", Json::str("wf")),
            ("jct", Json::num(123.5)),
            ("n", Json::num(250.0)),
        ]);
        assert_eq!(j.to_string(), r#"{"alg":"wf","jct":123.5,"n":250}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::str("a\"b\\c\nd");
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn infinities_become_null() {
        // JSON has no Inf either — both signs serialize as null, and the
        // result stays parseable (a bare `inf` token would not be).
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let j = Json::obj(vec![
            ("hi", Json::num(f64::INFINITY)),
            ("lo", Json::num(f64::NEG_INFINITY)),
        ]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("hi"), Some(&Json::Null));
        assert_eq!(parsed.get("lo"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1.0), Json::num(2.5), Json::Null])),
            ("b", Json::Bool(true)),
            ("s", Json::str("hé\"llo")),
            ("neg", Json::num(-3.25)),
        ]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn parses_standard_json() {
        let v = Json::parse(r#" { "x" : [1, 2e1, -0.5], "y": {"z": null} } "#).unwrap();
        assert_eq!(v.get("x").unwrap().as_arr().unwrap()[1].as_f64(), Some(20.0));
        assert_eq!(v.get("y").unwrap().get("z"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,").is_err());
    }
}
