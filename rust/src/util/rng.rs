//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — the standard, well-tested
//! construction (Blackman & Vigna). Every experiment in the repo is driven
//! by an explicit seed so all tables and figures are exactly reproducible.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically from a single `u64`.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method
    /// to avoid modulo bias. `n` must be > 0.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range_incl(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic, throughput is irrelevant here).
    pub fn gen_normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gen_normal()).exp()
    }

    /// Pareto with shape `alpha` and minimum 1 (heavy-tailed; the mean is
    /// `alpha/(alpha-1)` for `alpha > 1`, infinite otherwise). Used by the
    /// heavy-tail workload scenario for task-group sizes.
    pub fn gen_pareto(&mut self, alpha: f64) -> f64 {
        debug_assert!(alpha > 0.0);
        let u = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        u.powf(-1.0 / alpha)
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from the (unnormalized, non-negative) weight vector.
    /// Panics if all weights are zero or the slice is empty.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "sample_weighted: zero total weight");
        let mut u = self.gen_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1 // floating-point tail
    }

    /// Fork a new independent generator (for parallel workers), keyed by a
    /// stream id so forks are reproducible regardless of call order.
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[3] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }
}

/// A Zipf(α) sampler over ranks `1..=n`, built once (precomputed CDF) and
/// sampled many times. α = 0 degenerates to the uniform distribution —
/// exactly the convention the paper uses for data placement (§V-A).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)` (0-based).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Rng::seed_from(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn gen_range_incl_bounds() {
        let mut rng = Rng::seed_from(4);
        for _ in 0..1000 {
            let v = rng.gen_range_incl(3, 5);
            assert!((3..=5).contains(&v));
        }
        // Degenerate range.
        assert_eq!(rng.gen_range_incl(9, 9), 9);
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut rng = Rng::seed_from(5);
        let mut sum = 0.0;
        let n = 10_000;
        for _ in 0..n {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean ~ 0.5, got {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(6);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn pareto_min_and_mean() {
        let mut rng = Rng::seed_from(14);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_pareto(2.5)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0), "Pareto support is [1, inf)");
        let mean = xs.iter().sum::<f64>() / n as f64;
        // E[X] = alpha/(alpha-1) = 2.5/1.5 ~ 1.667.
        assert!((mean - 5.0 / 3.0).abs() < 0.1, "mean {mean}");
        // Heavy tail: the max dwarfs the mean.
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 10.0, "max {max}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seed_from(8);
        let n = 20_000;
        let mean = (0..n).map(|_| rng.gen_exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>(), "50! >> chance of identity");
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut rng = Rng::seed_from(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[rng.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn zipf_alpha_zero_is_uniform() {
        let z = Zipf::new(4, 0.0);
        let mut rng = Rng::seed_from(11);
        let mut counts = [0usize; 4];
        for _ in 0..8000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 200.0, "counts {counts:?}");
        }
    }

    #[test]
    fn zipf_skew_orders_ranks() {
        let z = Zipf::new(10, 2.0);
        let mut rng = Rng::seed_from(12);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[3]);
        // Rank 1 should dominate: p(1) = 1/H ~ 0.645 for α=2, n=10.
        assert!(counts[0] > 5500, "counts {counts:?}");
    }

    #[test]
    fn fork_streams_independent_and_reproducible() {
        let root = Rng::seed_from(13);
        let mut a1 = root.fork(1);
        let mut a2 = root.fork(1);
        let mut b = root.fork(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }
}
