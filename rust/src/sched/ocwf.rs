//! OCWF / OCWF-ACC job reordering (paper §IV, Algorithm 3).
//!
//! On every job arrival, all outstanding jobs (including the new one) are
//! re-ordered shortest-estimated-time-first: starting from empty servers
//! (Alg. 3 line 4 — every remaining task will be reassigned), the driver
//! repeatedly evaluates each not-yet-placed job's estimated completion
//! time Φ with WF against the busy times accumulated by the jobs already
//! placed, and appends the job with the smallest Φ.
//!
//! OCWF-ACC adds the *early-exit* technique: candidates are explored in
//! ascending order of the cheap lower bound Φ⁻ (eqs. 6–7); once the next
//! candidate's Φ⁻ exceeds the best full-WF Φ found so far, no remaining
//! candidate can win and the round stops. One deliberate deviation from
//! Algorithm 3's `Φ⁻ ≥ Φ_l` test: we break only on the *strict* `>`, so
//! equal-Φ ties resolve identically in OCWF and OCWF-ACC (by earliest
//! arrival) and the two schedulers produce bit-identical schedules — the
//! equivalence the paper's Table I reports. The weaker test gives up a
//! negligible amount of pruning.
//!
//! ## Two-phase driver, parallel rounds, zero-allocation steady state
//!
//! [`reorder_into`] splits every round into an **evaluate** phase — the
//! candidate WF evaluations, which all score against the *same* busy
//! vector and are therefore independent — and a serial **replay** phase
//! that walks the candidates in the exact order the sequential algorithm
//! would and applies its acceptance/early-exit rules. With `threads > 1`
//! the evaluate phase fans out across
//! [`pool::parallel_for_each`](crate::sweep::pool::parallel_for_each)
//! workers, each owning a private [`Wf`] + outcome arena.
//!
//! Because replay re-applies the serial decision rules verbatim, the
//! outcome (`order`, `assignments`, `wf_evals`) is **bit-identical at any
//! thread count**:
//!
//! - plain OCWF evaluates every unplaced candidate anyway, so the fan-out
//!   wastes nothing;
//! - OCWF-ACC evaluates *speculatively* in small chunks. Replay consumes
//!   a chunk under the serial rules — candidates the serial path would
//!   have skipped are simply discarded (not counted in `wf_evals`, their
//!   stale bounds untouched), and the strict-`>` early exit abandons the
//!   rest of the chunk exactly where the serial scan would break.
//!   Speculation can waste up to one chunk of evaluations per round.
//!
//! ## Adaptive speculation depth
//!
//! The ACC chunk size is **adaptive**: each round records how many
//! candidates the serial rules actually consumed before the early exit
//! (the *observed exit depth*), and the next round speculates exactly
//! that many, clamped to `[2, 256]`. The predictor is derived only from
//! prior-round outcomes of the same call, so it is deterministic; the
//! only thread-dependent choice is the first round's seed value
//! (`2×threads`, the historical fixed depth), and *no* chunk choice can
//! affect the outcome — replay re-applies the serial rules regardless of
//! how far speculation ran. A fixed depth (honored exactly, down to 1)
//! can be forced for experiments via
//! [`ReorderWorkspace::set_spec_chunk`] (config key `acc_spec_chunk`,
//! CLI `--acc-spec-chunk`).
//!
//! All per-call state — materialized remaining-groups, stale bounds, the
//! accumulated [`ClusterState`], candidate lists, per-worker WF arenas —
//! lives in a caller-pooled [`ReorderWorkspace`], and results are written
//! into a reusable [`ReorderOutcome`], so the steady-state driver touches
//! the allocator zero times per call (asserted by
//! `rust/tests/alloc_stability.rs`).

use crate::assign::bounds::phi_lower;
use crate::assign::wf::{Wf, WfOutcome};
use crate::assign::{Assignment, Instance};
use crate::cluster::state::ClusterState;
use crate::job::{Job, Slots, TaskCount, TaskGroup};
use crate::sweep::pool;

/// An outstanding job at a reorder point: the original job plus the
/// per-group counts of not-yet-processed tasks.
#[derive(Clone, Debug)]
pub struct Outstanding<'a> {
    pub job: &'a Job,
    /// Remaining tasks per group (aligned with `job.groups`).
    pub remaining: Vec<TaskCount>,
}

impl<'a> Outstanding<'a> {
    pub fn total_remaining(&self) -> TaskCount {
        self.remaining.iter().sum()
    }
}

/// Pooled builder for the per-arrival outstanding set.
///
/// `run_reordered` used to collect a fresh `Vec<Outstanding>` — cloning
/// every job's remaining-counts vector — on **every arrival**, the last
/// per-arrival allocations outside the reorder hot path. The set is a row
/// pool in the style of [`WfOutcome`]: rows `0..live` are the current
/// set, [`OutstandingSet::clear`] only resets the live count, and
/// [`OutstandingSet::push`] rebuilds row `live` in place (job reference
/// overwritten, remaining buffer cleared and refilled). Row *i* always
/// serves the *i*-th pushed job, so identical arrival cycles touch
/// identical buffers and the footprint freezes after one warmup cycle —
/// asserted by `rust/tests/alloc_stability.rs`.
#[derive(Clone, Debug, Default)]
pub struct OutstandingSet<'a> {
    /// Physical row pool; rows `0..live` are the current set.
    rows: Vec<Outstanding<'a>>,
    live: usize,
}

impl<'a> OutstandingSet<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the live count; every row (and its buffer) stays pooled.
    pub fn clear(&mut self) {
        self.live = 0;
    }

    /// Append one outstanding job, copying `remaining` into the next
    /// pooled row.
    pub fn push(&mut self, job: &'a Job, remaining: &[TaskCount]) {
        if self.live < self.rows.len() {
            let row = &mut self.rows[self.live];
            row.job = job;
            row.remaining.clear();
            row.remaining.extend_from_slice(remaining);
        } else {
            self.rows.push(Outstanding {
                job,
                remaining: remaining.to_vec(),
            });
        }
        self.live += 1;
    }

    pub fn as_slice(&self) -> &[Outstanding<'a>] {
        &self.rows[..self.live]
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Reserved capacity across the pooled buffers (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.rows.capacity()
            + self
                .rows
                .iter()
                .map(|o| o.remaining.capacity())
                .sum::<usize>()
    }
}

/// The outcome of one reorder: for each position in the new order, the
/// index into the `outstanding` slice and the WF assignment of that job's
/// remaining tasks (computed against the busy times of its predecessors).
/// Reused across calls by [`reorder_into`] (buffers are recycled).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReorderOutcome {
    pub order: Vec<usize>,
    pub assignments: Vec<Assignment>,
    /// Number of full WF evaluations performed (telemetry: the early-exit
    /// savings OCWF-ACC claims are measured as this counter's reduction).
    pub wf_evals: u64,
}

impl ReorderOutcome {
    fn begin(&mut self, n: usize) {
        self.order.clear();
        self.wf_evals = 0;
        // Keep up to n assignment buffers for in-place reuse; each round
        // overwrites (or appends) exactly one.
        self.assignments.truncate(n);
    }

    /// Reserved capacity across the reusable buffers
    /// (allocation-stability tests).
    pub fn footprint(&self) -> usize {
        self.order.capacity()
            + self.assignments.capacity()
            + self
                .assignments
                .iter()
                .map(|a| {
                    a.per_group.capacity()
                        + a.per_group.iter().map(|g| g.capacity()).sum::<usize>()
                })
                .sum::<usize>()
    }
}

/// One evaluation worker: a private WF instance plus an arena of outcome
/// slots it fills during a round. Workers are index-striped over the
/// candidates (see [`pool::parallel_for_each`]), so each worker's arena
/// evolves deterministically.
#[derive(Clone, Debug, Default)]
struct EvalSlot {
    wf: Wf,
    /// Live outcome count this round (`outs[..used]`).
    used: usize,
    /// Round-global scan position of each live outcome.
    pos: Vec<usize>,
    /// Outcome arena; never shrinks.
    outs: Vec<WfOutcome>,
}

impl EvalSlot {
    fn begin(&mut self) {
        self.used = 0;
        self.pos.clear();
    }

    /// Evaluate one candidate into the next arena slot. Recording
    /// `scan_pos` explicitly (rather than deriving it from the striping
    /// arithmetic) keeps the replay's lookup independent of
    /// `parallel_for_each`'s scheduling contract.
    fn eval(&mut self, scan_pos: usize, inst: &Instance) {
        if self.outs.len() == self.used {
            self.outs.push(WfOutcome::default());
        }
        self.wf.assign_into(inst, &mut self.outs[self.used]);
        self.pos.push(scan_pos);
        self.used += 1;
    }

    fn footprint(&self) -> usize {
        self.wf.scratch_footprint()
            + self.pos.capacity()
            + self.outs.capacity()
            + self.outs.iter().map(|o| o.footprint()).sum::<usize>()
    }
}

/// Caller-pooled scratch for [`reorder_into`]: everything a reordering
/// needs beyond the outstanding set itself. One workspace per simulation
/// (or per thread of a sweep cell); reuse across arrivals makes the
/// steady-state driver allocation-free.
#[derive(Clone, Debug, Default)]
pub struct ReorderWorkspace {
    /// Per-worker evaluation state (≥ the thread count of the call).
    slots: Vec<EvalSlot>,
    /// Materialized remaining-task groups per outstanding job (row pool;
    /// rows `0..n` are live). Server lists are copied from the jobs, so
    /// rows only reallocate when a larger job lands on them.
    groups: Vec<Vec<TaskGroup>>,
    /// OCWF-ACC lazily maintained lower bounds (see `reorder_into`).
    stale_bounds: Vec<Slots>,
    placed: Vec<bool>,
    /// Candidate scan order of the current round.
    cands: Vec<usize>,
    /// Per-slot arena watermarks at the start of the current chunk.
    marks: Vec<usize>,
    /// Scan position → (slot, arena index) of its evaluation.
    lookup: Vec<(u32, u32)>,
    /// Busy times accumulated by the jobs placed so far this reordering.
    state: ClusterState,
    /// Fixed ACC speculation depth override; `0` (default) = adaptive.
    /// Configuration, not scratch: survives [`ReorderWorkspace::ensure`].
    spec_chunk: usize,
}

impl ReorderWorkspace {
    /// Force a fixed ACC speculation depth (`0` restores the adaptive
    /// default). The choice never affects the reorder outcome — only how
    /// much parallel speculation may be wasted per round.
    pub fn set_spec_chunk(&mut self, chunk: usize) {
        self.spec_chunk = chunk;
    }

    fn ensure(&mut self, n: usize, num_servers: usize, threads: usize) {
        while self.slots.len() < threads.max(1) {
            self.slots.push(EvalSlot::default());
        }
        while self.groups.len() < n {
            self.groups.push(Vec::new());
        }
        self.stale_bounds.clear();
        self.stale_bounds.resize(n, 0);
        self.placed.clear();
        self.placed.resize(n, false);
        self.cands.clear();
        self.marks.clear();
        self.marks.resize(self.slots.len(), 0);
        self.lookup.clear();
        self.lookup.resize(n, (0, 0));
        self.state.reset(num_servers);
    }

    /// Rebuild row `i` in place: sizes from the outstanding job's
    /// remaining counts, server lists copied (capacity reused).
    fn materialize(&mut self, outstanding: &[Outstanding]) {
        for (i, o) in outstanding.iter().enumerate() {
            let row = &mut self.groups[i];
            row.truncate(o.job.groups.len());
            for (j, g) in o.job.groups.iter().enumerate() {
                if j < row.len() {
                    let tg = &mut row[j];
                    tg.size = o.remaining[j];
                    tg.servers.clear();
                    tg.servers.extend_from_slice(&g.servers);
                } else {
                    // Direct construction: the job's groups are already
                    // sorted/deduped by `TaskGroup::new`.
                    row.push(TaskGroup {
                        size: o.remaining[j],
                        servers: g.servers.clone(),
                        local: None,
                    });
                }
            }
        }
    }

    /// Reserved capacity across every pooled buffer
    /// (allocation-stability tests).
    pub fn footprint(&self) -> usize {
        self.slots.capacity()
            + self.slots.iter().map(|s| s.footprint()).sum::<usize>()
            + self.groups.capacity()
            + self
                .groups
                .iter()
                .map(|row| {
                    row.capacity()
                        + row.iter().map(|tg| tg.servers.capacity()).sum::<usize>()
                })
                .sum::<usize>()
            + self.stale_bounds.capacity()
            + self.placed.capacity()
            + self.cands.capacity()
            + self.marks.capacity()
            + self.lookup.capacity()
            + self.state.footprint()
    }
}

/// Run one OCWF(-ACC) reordering round over the outstanding jobs,
/// allocating fresh workspace and outcome (convenience path for tests and
/// one-shot callers; simulations pool both via [`reorder_into`]).
pub fn reorder(outstanding: &[Outstanding], num_servers: usize, acc: bool) -> ReorderOutcome {
    let mut ws = ReorderWorkspace::default();
    let mut out = ReorderOutcome::default();
    reorder_into(outstanding, num_servers, acc, 1, &mut ws, &mut out);
    out
}

/// Run one OCWF(-ACC) reordering into pooled buffers, fanning candidate Φ
/// evaluations across `threads` workers (`0` = all cores, `1` = the
/// serial reference path). The outcome is bit-identical at every thread
/// count (see the module docs); `num_servers` is M; each outstanding job
/// carries its own μ vector.
pub fn reorder_into(
    outstanding: &[Outstanding],
    num_servers: usize,
    acc: bool,
    threads: usize,
    ws: &mut ReorderWorkspace,
    out: &mut ReorderOutcome,
) {
    let n = outstanding.len();
    let threads = if threads == 0 {
        pool::available_threads()
    } else {
        threads.max(1)
    };
    ws.ensure(n, num_servers, threads);
    ws.materialize(outstanding);
    out.begin(n);

    let ReorderWorkspace {
        slots,
        groups,
        stale_bounds,
        placed,
        cands,
        marks,
        lookup,
        state,
        spec_chunk,
    } = ws;
    let spec_chunk = *spec_chunk;
    // Adaptive speculation never explores more than this many candidates
    // ahead of the serial scan in one chunk.
    const MAX_ADAPTIVE_CHUNK: usize = 256;
    // Observed serial consumption depth of the previous round (0 = no
    // observation yet this call).
    let mut exit_depth: usize = 0;

    // OCWF-ACC: lazily maintained lower bounds. Busy times only grow as
    // jobs are placed, so a bound computed against an older busy vector
    // remains a valid (stale) lower bound — the Minoux lazy-greedy trick.
    // Bounds are refreshed only when a stale value survives the early-
    // exit test, which cuts both the Φ⁻ recomputations and the full WF
    // evaluations.
    if acc {
        for i in 0..n {
            let inst = state.instance(&groups[i], &outstanding[i].job.mu);
            stale_bounds[i] = phi_lower(&inst);
        }
    }

    for _round in 0..n {
        // Candidate exploration order: arrival order for OCWF; ascending
        // stale Φ⁻ for OCWF-ACC (enables the early exit). Keys are unique
        // (index tiebreak), so the unstable sort is deterministic.
        cands.clear();
        cands.extend((0..n).filter(|&i| !placed[i]));
        if acc {
            cands.sort_unstable_by_key(|&i| (stale_bounds[i], i));
        }
        let total = cands.len();

        for s in slots.iter_mut() {
            s.begin();
        }
        // best = (Φ, candidate, slot, arena index of its evaluation).
        let mut best: Option<(Slots, usize, usize, usize)> = None;

        if threads == 1 {
            // Serial reference path: evaluate lazily, one candidate at a
            // time, with the bound checks *before* each evaluation — the
            // exact sequential Algorithm 3 (+ strict-`>` ACC early exit).
            let s0 = &mut slots[0];
            for (scan, &i) in cands.iter().enumerate() {
                if acc {
                    if let Some((best_phi, _, _, _)) = best {
                        // Early exit: Φ⁻ is a valid lower bound on Φ, so
                        // once the (ascending) stale bounds exceed the
                        // incumbent no later candidate can strictly
                        // improve. Strict `>` keeps tie handling identical
                        // to OCWF (module docs).
                        if stale_bounds[i] > best_phi {
                            break;
                        }
                        // Refresh the bound against the current busy
                        // vector; skip the full WF evaluation if it now
                        // disqualifies.
                        let inst = state.instance(&groups[i], &outstanding[i].job.mu);
                        let fresh = phi_lower(&inst);
                        stale_bounds[i] = fresh;
                        if fresh > best_phi {
                            continue;
                        }
                    }
                }
                let inst = state.instance(&groups[i], &outstanding[i].job.mu);
                s0.eval(scan, &inst);
                out.wf_evals += 1;
                let idx = s0.used - 1;
                let phi = s0.outs[idx].phi;
                // WF's estimate is itself a valid (tighter) lower bound
                // for later rounds.
                if acc {
                    stale_bounds[i] = phi;
                }
                let accept = match best {
                    None => true,
                    // Strict improvement, ties to the earliest arrival.
                    Some((bphi, bi, _, _)) => phi < bphi || (phi == bphi && i < bi),
                };
                if accept {
                    best = Some((phi, i, 0, idx));
                }
            }
        } else {
            // Two-phase path: speculative chunked evaluation + serial
            // replay. Plain OCWF evaluates everything, so the chunk is
            // the whole candidate list; ACC speculates ahead by the
            // adaptive depth observed in the previous round (module
            // docs), or by the fixed `spec_chunk` override.
            let chunk_cap = if !acc {
                usize::MAX
            } else if spec_chunk > 0 {
                // Honored exactly (a depth of 1 is the zero-waste,
                // serialized-scan extreme).
                spec_chunk
            } else if exit_depth == 0 {
                // No observation yet (first ACC round of this call):
                // seed with the historical 2×threads depth. This is the
                // only thread-dependent choice, and chunking can never
                // change the outcome — only the amount of wasted
                // speculation.
                (threads * 2).max(2)
            } else {
                exit_depth.clamp(2, MAX_ADAPTIVE_CHUNK)
            };
            // Candidates the serial rules consumed this round (the
            // early-exit depth the next round's chunk is sized from).
            let mut examined = 0usize;
            let mut scan = 0;
            'scan: while scan < total {
                let clen = chunk_cap.min(total - scan);
                for (si, s) in slots.iter().enumerate() {
                    marks[si] = s.used;
                }
                {
                    // Evaluate phase: all candidates of the chunk score
                    // against the same (frozen) busy vector.
                    let busy = state.busy();
                    let groups_ref: &[Vec<TaskGroup>] = groups;
                    let chunk: &[usize] = &cands[scan..scan + clen];
                    pool::parallel_for_each(clen, &mut slots[..threads], |slot, j| {
                        let i = chunk[j];
                        let inst = Instance {
                            groups: &groups_ref[i],
                            mu: &outstanding[i].job.mu,
                            busy,
                        };
                        slot.eval(scan + j, &inst);
                    });
                }
                for (si, s) in slots.iter().enumerate() {
                    for t in marks[si]..s.used {
                        lookup[s.pos[t]] = (si as u32, t as u32);
                    }
                }
                // Replay phase: the serial decision rules, consuming the
                // precomputed evaluations. Discarded speculation leaves no
                // trace (no count, no bound update).
                for j in 0..clen {
                    let i = cands[scan + j];
                    examined = scan + j + 1;
                    if acc {
                        if let Some((best_phi, _, _, _)) = best {
                            if stale_bounds[i] > best_phi {
                                break 'scan;
                            }
                            let inst = state.instance(&groups[i], &outstanding[i].job.mu);
                            let fresh = phi_lower(&inst);
                            stale_bounds[i] = fresh;
                            if fresh > best_phi {
                                continue;
                            }
                        }
                    }
                    let (si, ti) = lookup[scan + j];
                    let phi = slots[si as usize].outs[ti as usize].phi;
                    out.wf_evals += 1;
                    if acc {
                        stale_bounds[i] = phi;
                    }
                    let accept = match best {
                        None => true,
                        Some((bphi, bi, _, _)) => phi < bphi || (phi == bphi && i < bi),
                    };
                    if accept {
                        best = Some((phi, i, si as usize, ti as usize));
                    }
                }
                scan += clen;
            }
            exit_depth = examined.max(1);
        }

        let (_, bi, si, ti) = best.expect("reorder round must place one job");
        placed[bi] = true;
        out.order.push(bi);
        let chosen = &slots[si].outs[ti];
        let pos = out.order.len() - 1;
        if pos < out.assignments.len() {
            chosen.write_assignment(&mut out.assignments[pos]);
        } else {
            out.assignments.push(chosen.to_assignment());
        }
        state.copy_from(chosen.final_busy());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;
    use crate::util::rng::Rng;

    fn mk_job(id: usize, sizes: &[u64], servers: &[&[usize]], m: usize) -> Job {
        Job {
            id,
            arrival: id as u64,
            groups: sizes
                .iter()
                .zip(servers)
                .map(|(&s, &sv)| TaskGroup::new(s, sv.to_vec()))
                .collect(),
            mu: vec![1; m],
        }
    }

    fn outstanding(jobs: &[Job]) -> Vec<Outstanding<'_>> {
        jobs.iter()
            .map(|j| Outstanding {
                job: j,
                remaining: j.groups.iter().map(|g| g.size).collect(),
            })
            .collect()
    }

    fn random_jobs(rng: &mut Rng, m: usize, max_jobs: u64) -> Vec<Job> {
        let njobs = 1 + rng.gen_range(max_jobs) as usize;
        (0..njobs)
            .map(|id| {
                let k = 1 + rng.gen_range(3) as usize;
                let groups: Vec<TaskGroup> = (0..k)
                    .map(|_| {
                        let ns = 1 + rng.gen_range(m as u64) as usize;
                        let mut sv: Vec<usize> = (0..m).collect();
                        rng.shuffle(&mut sv);
                        sv.truncate(ns);
                        TaskGroup::new(rng.gen_range_incl(1, 20), sv)
                    })
                    .collect();
                Job {
                    id,
                    arrival: id as u64,
                    groups,
                    mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
                }
            })
            .collect()
    }

    #[test]
    fn shortest_job_first() {
        // Big job arrived first, small job second; reorder should put the
        // small one first (shorter estimated completion).
        let m = 2;
        let jobs = vec![
            mk_job(0, &[10], &[&[0, 1]], m),
            mk_job(1, &[2], &[&[0, 1]], m),
        ];
        let out = outstanding(&jobs);
        let r = reorder(&out, m, false);
        assert_eq!(r.order, vec![1, 0]);
    }

    #[test]
    fn acc_and_plain_agree_exactly() {
        let m = 6;
        let mut rng = Rng::seed_from(300);
        for _ in 0..30 {
            let jobs = random_jobs(&mut rng, m, 6);
            let out = outstanding(&jobs);
            let plain = reorder(&out, m, false);
            let accd = reorder(&out, m, true);
            assert_eq!(plain.order, accd.order, "order must match");
            assert_eq!(
                plain.assignments, accd.assignments,
                "assignments must match"
            );
            assert!(
                accd.wf_evals <= plain.wf_evals,
                "ACC must not evaluate more: {} vs {}",
                accd.wf_evals,
                plain.wf_evals
            );
        }
    }

    #[test]
    fn acc_skips_evaluations() {
        // Many jobs with very different sizes: the early exit must prune.
        let m = 4;
        let jobs: Vec<Job> = (0..8)
            .map(|id| mk_job(id, &[(id as u64 + 1) * 10], &[&[0, 1, 2, 3]], m))
            .collect();
        let out = outstanding(&jobs);
        let plain = reorder(&out, m, false);
        let accd = reorder(&out, m, true);
        assert_eq!(plain.order, accd.order);
        assert!(
            accd.wf_evals < plain.wf_evals,
            "expected pruning: {} vs {}",
            accd.wf_evals,
            plain.wf_evals
        );
    }

    #[test]
    fn parallel_rounds_bit_identical_to_serial() {
        // The tentpole invariant: same ReorderOutcome (order, assignments,
        // wf_evals) at 1 / 2 / 8 reorder threads, for both OCWF variants.
        let m = 6;
        let mut rng = Rng::seed_from(301);
        for case in 0..20 {
            let jobs = random_jobs(&mut rng, m, 10);
            let out = outstanding(&jobs);
            for acc in [false, true] {
                let mut ws = ReorderWorkspace::default();
                let mut serial = ReorderOutcome::default();
                reorder_into(&out, m, acc, 1, &mut ws, &mut serial);
                for threads in [2, 8] {
                    let mut wsp = ReorderWorkspace::default();
                    let mut par = ReorderOutcome::default();
                    reorder_into(&out, m, acc, threads, &mut wsp, &mut par);
                    assert_eq!(
                        serial, par,
                        "case {case} acc={acc} threads={threads} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn workspace_reuse_across_calls_is_stable() {
        // Re-running the same reordering through one pooled workspace must
        // give identical outcomes and, after warmup, a frozen footprint.
        let m = 5;
        let mut rng = Rng::seed_from(302);
        let jobs = random_jobs(&mut rng, m, 8);
        let out = outstanding(&jobs);
        let mut ws = ReorderWorkspace::default();
        let mut buf = ReorderOutcome::default();
        reorder_into(&out, m, true, 1, &mut ws, &mut buf);
        let reference = buf.clone();
        let fp = ws.footprint() + buf.footprint();
        for _ in 0..5 {
            reorder_into(&out, m, true, 1, &mut ws, &mut buf);
            assert_eq!(reference, buf);
            assert_eq!(fp, ws.footprint() + buf.footprint(), "allocation crept in");
        }
    }

    #[test]
    fn assignments_cover_remaining_tasks() {
        let m = 3;
        let jobs = vec![
            mk_job(0, &[6, 3], &[&[0, 1], &[2]], m),
            mk_job(1, &[4], &[&[1, 2]], m),
        ];
        let mut out = outstanding(&jobs);
        out[0].remaining = vec![4, 1]; // partially processed
        let r = reorder(&out, m, true);
        for (pos, &i) in r.order.iter().enumerate() {
            let total: u64 = r.assignments[pos].total_assigned();
            assert_eq!(total, out[i].total_remaining());
        }
    }

    #[test]
    fn empty_outstanding_set() {
        let r = reorder(&[], 4, true);
        assert!(r.order.is_empty());
        // Parallel path with nothing to do is also fine.
        let mut ws = ReorderWorkspace::default();
        let mut out = ReorderOutcome::default();
        reorder_into(&[], 4, true, 8, &mut ws, &mut out);
        assert!(out.order.is_empty());
        assert_eq!(out.wf_evals, 0);
    }

    #[test]
    fn speculation_depth_never_changes_outcome() {
        // Adaptive (0) and every fixed override must reproduce the serial
        // reference bit for bit — chunking only affects wasted work.
        let m = 6;
        let mut rng = Rng::seed_from(303);
        for _ in 0..10 {
            let jobs = random_jobs(&mut rng, m, 9);
            let out = outstanding(&jobs);
            let mut serial = ReorderOutcome::default();
            reorder_into(
                &out,
                m,
                true,
                1,
                &mut ReorderWorkspace::default(),
                &mut serial,
            );
            for chunk in [0usize, 1, 2, 3, 5, 64] {
                let mut ws = ReorderWorkspace::default();
                ws.set_spec_chunk(chunk);
                let mut par = ReorderOutcome::default();
                reorder_into(&out, m, true, 4, &mut ws, &mut par);
                assert_eq!(serial, par, "spec_chunk={chunk} diverged");
            }
        }
    }

    #[test]
    fn outstanding_set_copies_and_recycles() {
        let m = 3;
        let jobs = vec![
            mk_job(0, &[6, 3], &[&[0, 1], &[2]], m),
            mk_job(1, &[4], &[&[1, 2]], m),
        ];
        let mut set = OutstandingSet::new();
        set.push(&jobs[0], &[4, 1]);
        set.push(&jobs[1], &[4]);
        assert_eq!(set.len(), 2);
        assert_eq!(set.as_slice()[0].remaining, vec![4, 1]);
        assert_eq!(set.as_slice()[0].total_remaining(), 5);
        // Rebuilding through the pool gives the same contents and, once
        // warmed, a frozen footprint.
        let fp = set.footprint();
        for _ in 0..3 {
            set.clear();
            assert!(set.is_empty());
            set.push(&jobs[0], &[4, 1]);
            set.push(&jobs[1], &[4]);
            assert_eq!(set.as_slice()[1].remaining, vec![4]);
            assert_eq!(fp, set.footprint(), "pool churned");
        }
        // The pooled set feeds reorder like a hand-built slice does.
        let r = reorder(set.as_slice(), m, true);
        assert_eq!(r.order.len(), 2);
    }
}
