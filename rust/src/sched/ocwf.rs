//! OCWF / OCWF-ACC job reordering (paper §IV, Algorithm 3).
//!
//! On every job arrival, all outstanding jobs (including the new one) are
//! re-ordered shortest-estimated-time-first: starting from empty servers
//! (Alg. 3 line 4 — every remaining task will be reassigned), the driver
//! repeatedly evaluates each not-yet-placed job's estimated completion
//! time Φ with WF against the busy times accumulated by the jobs already
//! placed, and appends the job with the smallest Φ.
//!
//! OCWF-ACC adds the *early-exit* technique: candidates are explored in
//! ascending order of the cheap lower bound Φ⁻ (eqs. 6–7); once the next
//! candidate's Φ⁻ exceeds the best full-WF Φ found so far, no remaining
//! candidate can win and the round stops. One deliberate deviation from
//! Algorithm 3's `Φ⁻ ≥ Φ_l` test: we break only on the *strict* `>`, so
//! equal-Φ ties resolve identically in OCWF and OCWF-ACC (by earliest
//! arrival) and the two schedulers produce bit-identical schedules — the
//! equivalence the paper's Table I reports. The weaker test gives up a
//! negligible amount of pruning.

use crate::assign::bounds::phi_lower;
use crate::assign::wf::Wf;
use crate::assign::{Assignment, Instance};
use crate::job::{Job, Slots, TaskCount, TaskGroup};

/// An outstanding job at a reorder point: the original job plus the
/// per-group counts of not-yet-processed tasks.
#[derive(Clone, Debug)]
pub struct Outstanding<'a> {
    pub job: &'a Job,
    /// Remaining tasks per group (aligned with `job.groups`).
    pub remaining: Vec<TaskCount>,
}

impl<'a> Outstanding<'a> {
    pub fn total_remaining(&self) -> TaskCount {
        self.remaining.iter().sum()
    }

    /// Materialize the remaining work as task groups (sizes = remaining).
    fn remaining_groups(&self) -> Vec<TaskGroup> {
        self.job
            .groups
            .iter()
            .zip(&self.remaining)
            .map(|(g, &r)| TaskGroup {
                size: r,
                servers: g.servers.clone(),
            })
            .collect()
    }
}

/// The outcome of one reorder: for each position in the new order, the
/// index into the `outstanding` slice and the WF assignment of that job's
/// remaining tasks (computed against the busy times of its predecessors).
#[derive(Clone, Debug)]
pub struct ReorderOutcome {
    pub order: Vec<usize>,
    pub assignments: Vec<Assignment>,
    /// Number of full WF evaluations performed (telemetry: the early-exit
    /// savings OCWF-ACC claims are measured as this counter's reduction).
    pub wf_evals: u64,
}

/// Run one OCWF(-ACC) reordering round over the outstanding jobs.
///
/// `num_servers` is M; each outstanding job carries its own μ vector.
pub fn reorder(
    outstanding: &[Outstanding],
    num_servers: usize,
    acc: bool,
    wf: &mut Wf,
) -> ReorderOutcome {
    let n = outstanding.len();
    let mut busy: Vec<Slots> = vec![0; num_servers];
    let mut placed = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut assignments = Vec::with_capacity(n);
    let mut wf_evals = 0u64;

    // Pre-materialize remaining groups once per job (server sets don't
    // change during the round; sizes are fixed at the reorder point).
    let groups: Vec<Vec<TaskGroup>> = outstanding.iter().map(|o| o.remaining_groups()).collect();

    // OCWF-ACC: lazily maintained lower bounds. Busy times only grow as
    // jobs are placed, so a bound computed against an older busy vector
    // remains a valid (stale) lower bound — the Minoux lazy-greedy trick.
    // Bounds are refreshed only when a stale value survives the early-
    // exit test, which cuts both the Φ⁻ recomputations and the full WF
    // evaluations.
    let mut stale_bounds: Vec<Slots> = if acc {
        (0..n)
            .map(|i| {
                let inst = Instance {
                    groups: &groups[i],
                    mu: &outstanding[i].job.mu,
                    busy: &busy,
                };
                phi_lower(&inst)
            })
            .collect()
    } else {
        vec![0; n]
    };

    for _ in 0..n {
        // Candidate exploration order: arrival order for OCWF; ascending
        // stale Φ⁻ for OCWF-ACC (enables the early exit).
        let mut cands: Vec<usize> = (0..n).filter(|&i| !placed[i]).collect();
        if acc {
            cands.sort_by_key(|&i| (stale_bounds[i], i));
        }

        let mut best: Option<(Slots, usize, Assignment, Vec<Slots>)> = None;
        for &i in &cands {
            if acc {
                if let Some((best_phi, _, _, _)) = &best {
                    // Early exit: Φ⁻ is a valid lower bound on Φ, so once
                    // the (ascending) stale bounds exceed the incumbent no
                    // later candidate can strictly improve. Strict `>`
                    // keeps tie handling identical to OCWF (module docs).
                    if stale_bounds[i] > *best_phi {
                        break;
                    }
                    // Refresh the bound against the current busy vector;
                    // skip the full WF evaluation if it now disqualifies.
                    let inst = Instance {
                        groups: &groups[i],
                        mu: &outstanding[i].job.mu,
                        busy: &busy,
                    };
                    let fresh = phi_lower(&inst);
                    stale_bounds[i] = fresh;
                    if fresh > *best_phi {
                        continue;
                    }
                }
            }
            let inst = Instance {
                groups: &groups[i],
                mu: &outstanding[i].job.mu,
                busy: &busy,
            };
            let (a, final_busy) = wf.assign_with_busy(&inst);
            wf_evals += 1;
            // WF's estimate is itself a valid (tighter) lower bound for
            // later rounds.
            if acc {
                stale_bounds[i] = a.phi;
            }
            let accept = match &best {
                None => true,
                // Strict improvement, ties to the earliest arrival (the
                // iteration order of OCWF guarantees this; for ACC the
                // explicit index tie-break restores it).
                Some((bphi, bi, _, _)) => a.phi < *bphi || (a.phi == *bphi && i < *bi),
            };
            if accept {
                best = Some((a.phi, i, a, final_busy));
            }
        }

        let (_, i, assignment, final_busy) =
            best.expect("reorder round must place one job");
        placed[i] = true;
        order.push(i);
        assignments.push(assignment);
        busy = final_busy;
    }

    ReorderOutcome {
        order,
        assignments,
        wf_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;

    fn mk_job(id: usize, sizes: &[u64], servers: &[&[usize]], m: usize) -> Job {
        Job {
            id,
            arrival: id as u64,
            groups: sizes
                .iter()
                .zip(servers)
                .map(|(&s, &sv)| TaskGroup::new(s, sv.to_vec()))
                .collect(),
            mu: vec![1; m],
        }
    }

    fn outstanding(jobs: &[Job]) -> Vec<Outstanding<'_>> {
        jobs.iter()
            .map(|j| Outstanding {
                job: j,
                remaining: j.groups.iter().map(|g| g.size).collect(),
            })
            .collect()
    }

    #[test]
    fn shortest_job_first() {
        // Big job arrived first, small job second; reorder should put the
        // small one first (shorter estimated completion).
        let m = 2;
        let jobs = vec![
            mk_job(0, &[10], &[&[0, 1]], m),
            mk_job(1, &[2], &[&[0, 1]], m),
        ];
        let out = outstanding(&jobs);
        let r = reorder(&out, m, false, &mut Wf::new());
        assert_eq!(r.order, vec![1, 0]);
    }

    #[test]
    fn acc_and_plain_agree_exactly() {
        use crate::util::rng::Rng;
        let m = 6;
        let mut rng = Rng::seed_from(300);
        for _ in 0..30 {
            let njobs = 1 + rng.gen_range(6) as usize;
            let jobs: Vec<Job> = (0..njobs)
                .map(|id| {
                    let k = 1 + rng.gen_range(3) as usize;
                    let groups: Vec<TaskGroup> = (0..k)
                        .map(|_| {
                            let ns = 1 + rng.gen_range(m as u64) as usize;
                            let mut sv: Vec<usize> = (0..m).collect();
                            rng.shuffle(&mut sv);
                            sv.truncate(ns);
                            TaskGroup::new(rng.gen_range_incl(1, 20), sv)
                        })
                        .collect();
                    Job {
                        id,
                        arrival: id as u64,
                        groups,
                        mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
                    }
                })
                .collect();
            let out = outstanding(&jobs);
            let plain = reorder(&out, m, false, &mut Wf::new());
            let accd = reorder(&out, m, true, &mut Wf::new());
            assert_eq!(plain.order, accd.order, "order must match");
            assert_eq!(
                plain.assignments, accd.assignments,
                "assignments must match"
            );
            assert!(
                accd.wf_evals <= plain.wf_evals,
                "ACC must not evaluate more: {} vs {}",
                accd.wf_evals,
                plain.wf_evals
            );
        }
    }

    #[test]
    fn acc_skips_evaluations() {
        // Many jobs with very different sizes: the early exit must prune.
        let m = 4;
        let jobs: Vec<Job> = (0..8)
            .map(|id| mk_job(id, &[(id as u64 + 1) * 10], &[&[0, 1, 2, 3]], m))
            .collect();
        let out = outstanding(&jobs);
        let plain = reorder(&out, m, false, &mut Wf::new());
        let accd = reorder(&out, m, true, &mut Wf::new());
        assert_eq!(plain.order, accd.order);
        assert!(
            accd.wf_evals < plain.wf_evals,
            "expected pruning: {} vs {}",
            accd.wf_evals,
            plain.wf_evals
        );
    }

    #[test]
    fn assignments_cover_remaining_tasks() {
        let m = 3;
        let jobs = vec![
            mk_job(0, &[6, 3], &[&[0, 1], &[2]], m),
            mk_job(1, &[4], &[&[1, 2]], m),
        ];
        let mut out = outstanding(&jobs);
        out[0].remaining = vec![4, 1]; // partially processed
        let r = reorder(&out, m, true, &mut Wf::new());
        for (pos, &i) in r.order.iter().enumerate() {
            let total: u64 = r.assignments[pos].total_assigned();
            assert_eq!(total, out[i].total_remaining());
        }
    }

    #[test]
    fn empty_outstanding_set() {
        let r = reorder(&[], 4, true, &mut Wf::new());
        assert!(r.order.is_empty());
    }
}
