//! Scheduling policies (paper §II's two scenarios): FIFO queues, and
//! prioritized reordering of outstanding jobs (§IV).

pub mod ocwf;

use crate::assign::AssignPolicy;

/// The queueing/scheduling discipline for a simulation run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// FIFO queues; each arriving job is assigned once by the given
    /// algorithm (paper §III).
    Fifo(AssignPolicy),
    /// Order-conscious water-filling (§IV): on every arrival, reorder all
    /// outstanding jobs shortest-estimated-time-first and reassign their
    /// remaining tasks with WF. `acc` enables the early-exit technique
    /// (OCWF-ACC, Algorithm 3).
    Ocwf { acc: bool },
}

impl SchedPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo(p) => p.name(),
            SchedPolicy::Ocwf { acc: false } => "ocwf",
            SchedPolicy::Ocwf { acc: true } => "ocwf-acc",
        }
    }

    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "ocwf" => Some(SchedPolicy::Ocwf { acc: false }),
            "ocwf-acc" | "ocwfacc" | "ocwf_acc" => Some(SchedPolicy::Ocwf { acc: true }),
            other => AssignPolicy::parse(other).map(SchedPolicy::Fifo),
        }
    }

    /// All six algorithms evaluated in the paper (§V-A).
    pub const ALL: [SchedPolicy; 6] = [
        SchedPolicy::Fifo(AssignPolicy::Nlip),
        SchedPolicy::Fifo(AssignPolicy::Obta),
        SchedPolicy::Fifo(AssignPolicy::Wf),
        SchedPolicy::Fifo(AssignPolicy::Rd),
        SchedPolicy::Ocwf { acc: false },
        SchedPolicy::Ocwf { acc: true },
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("nope"), None);
    }
}
