//! Scheduling policies (paper §II's two scenarios): FIFO queues, and
//! prioritized reordering of outstanding jobs (§IV).
//!
//! A policy is the composition of two independent axes: [`Ordering`] —
//! *when* a job's tasks are (re)assigned — and
//! [`crate::assign::AssignPolicy`] — *how* one job's tasks are placed.
//! The [`REGISTRY`] is the single extensible catalog of named
//! compositions: adding a policy means one `AssignPolicy` variant plus
//! one registry row; parsing, the sweep panels ([`PolicySet`]) and the
//! CLI listings all derive from it.

pub mod ocwf;

use crate::assign::AssignPolicy;

/// When tasks are (re)assigned. FIFO assigns each job once on arrival
/// (paper §III); reordering (OCWF, §IV) reorders all outstanding jobs
/// shortest-estimated-time-first on every arrival and reassigns their
/// remaining tasks. `acc` enables the early-exit technique (OCWF-ACC,
/// Algorithm 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    Fifo,
    Reorder { acc: bool },
}

/// The queueing/scheduling discipline for a simulation run: an
/// [`Ordering`] composed with an assignment algorithm. FIFO composes
/// with every assigner; reordering canonically pairs with WF (§IV
/// evaluates candidate orders by water-filling), so [`SchedPolicy::ocwf`]
/// pins `assign` to WF and equality stays structural.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedPolicy {
    pub ordering: Ordering,
    pub assign: AssignPolicy,
}

impl SchedPolicy {
    /// FIFO ordering with the given assignment algorithm.
    pub const fn fifo(assign: AssignPolicy) -> SchedPolicy {
        SchedPolicy {
            ordering: Ordering::Fifo,
            assign,
        }
    }

    /// Order-conscious water-filling (§IV), canonical WF assignment.
    pub const fn ocwf(acc: bool) -> SchedPolicy {
        SchedPolicy {
            ordering: Ordering::Reorder { acc },
            assign: AssignPolicy::Wf,
        }
    }

    pub fn is_fifo(&self) -> bool {
        matches!(self.ordering, Ordering::Fifo)
    }

    /// The assignment algorithm when this is a FIFO policy. Reordering
    /// returns `None`: OCWF drives WF through its own reorder workspace,
    /// not through a boxed [`crate::assign::Assigner`].
    pub fn fifo_assign(&self) -> Option<AssignPolicy> {
        match self.ordering {
            Ordering::Fifo => Some(self.assign),
            Ordering::Reorder { .. } => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self.ordering {
            Ordering::Fifo => self.assign.name(),
            Ordering::Reorder { acc: false } => "ocwf",
            Ordering::Reorder { acc: true } => "ocwf-acc",
        }
    }

    pub fn parse(s: &str) -> Option<SchedPolicy> {
        let lower = s.to_ascii_lowercase();
        REGISTRY
            .iter()
            .find(|d| d.policy.name() == lower || d.aliases.contains(&lower.as_str()))
            .map(|d| d.policy)
    }

    /// All six algorithms evaluated in the paper (§V-A).
    pub const ALL: [SchedPolicy; 6] = [
        SchedPolicy::fifo(AssignPolicy::Nlip),
        SchedPolicy::fifo(AssignPolicy::Obta),
        SchedPolicy::fifo(AssignPolicy::Wf),
        SchedPolicy::fifo(AssignPolicy::Rd),
        SchedPolicy::ocwf(false),
        SchedPolicy::ocwf(true),
    ];

    /// The classic baseline panel beyond the paper: delay scheduling,
    /// JSQ with and without replica affinity, and MaxWeight.
    pub const BASELINES: [SchedPolicy; 4] = [
        SchedPolicy::fifo(AssignPolicy::Jsq),
        SchedPolicy::fifo(AssignPolicy::JsqAffinity),
        SchedPolicy::fifo(AssignPolicy::Delay),
        SchedPolicy::fifo(AssignPolicy::MaxWeight),
    ];

    /// Paper panel + baseline panel (the `repro --fig baselines` default).
    pub const EXTENDED: [SchedPolicy; 10] = [
        SchedPolicy::ALL[0],
        SchedPolicy::ALL[1],
        SchedPolicy::ALL[2],
        SchedPolicy::ALL[3],
        SchedPolicy::ALL[4],
        SchedPolicy::ALL[5],
        SchedPolicy::BASELINES[0],
        SchedPolicy::BASELINES[1],
        SchedPolicy::BASELINES[2],
        SchedPolicy::BASELINES[3],
    ];
}

/// One registry row: a named policy with its accepted spellings, a
/// one-line semantic summary, and its literature anchor. The row order
/// is the canonical panel order ([`SchedPolicy::EXTENDED`]).
pub struct PolicyDesc {
    pub policy: SchedPolicy,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub citation: &'static str,
}

/// The policy catalog. Every parseable policy name lives here; adding a
/// policy is one [`AssignPolicy`] variant plus one row.
pub const REGISTRY: &[PolicyDesc] = &[
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Nlip),
        aliases: &[],
        summary: "exact program-P optimum, unnarrowed ILP search",
        citation: "paper §III (NLIP)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Obta),
        aliases: &[],
        summary: "exact optimum with the narrowed [phi-, phi+] search",
        citation: "paper §III-A (OBTA)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Wf),
        aliases: &[],
        summary: "water-filling approximation, K_c-tight",
        citation: "paper §III-B (Alg 2)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Rd),
        aliases: &[],
        summary: "replica-deletion heuristic, random tie-breaks",
        citation: "paper §III-C",
    },
    PolicyDesc {
        policy: SchedPolicy::ocwf(false),
        aliases: &[],
        summary: "reorder outstanding jobs SETF, reassign with WF",
        citation: "paper §IV (Alg 1)",
    },
    PolicyDesc {
        policy: SchedPolicy::ocwf(true),
        aliases: &["ocwfacc", "ocwf_acc"],
        summary: "OCWF with accelerated early-exit reordering",
        citation: "paper §IV (Alg 3)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Jsq),
        aliases: &[],
        summary: "join shortest estimated queue, locality-oblivious",
        citation: "Winston 1977 (JSQ)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::JsqAffinity),
        aliases: &["jsq_affinity", "jsqaffinity", "jsqa"],
        summary: "JSQ over replica holders, overflow spills remote",
        citation: "arXiv 1705.03125 (affinity scheduling)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::Delay),
        aliases: &["delay-sched", "delay_sched"],
        summary: "hold for a replica holder unless local wait > D",
        citation: "Zaharia et al., EuroSys 2010 (delay scheduling)",
    },
    PolicyDesc {
        policy: SchedPolicy::fifo(AssignPolicy::MaxWeight),
        aliases: &["max-weight", "max_weight"],
        summary: "queue-length x locality-weight priority routing",
        citation: "arXiv 1705.03125 (JSQ-MaxWeight)",
    },
];

/// An ordered, deduplicated set of policies — the panel a sweep or
/// comparison actually runs. Defaults to the paper's six so every
/// historical figure and golden export stays byte-identical.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicySet(Vec<SchedPolicy>);

impl PolicySet {
    /// The paper's six-policy panel ([`SchedPolicy::ALL`]).
    pub fn paper() -> PolicySet {
        PolicySet(SchedPolicy::ALL.to_vec())
    }

    /// Paper panel plus the four classic baselines.
    pub fn extended() -> PolicySet {
        PolicySet(SchedPolicy::EXTENDED.to_vec())
    }

    /// Parse a comma-separated policy list (`"obta,wf,jsq"`). Duplicate
    /// names collapse onto their first occurrence; unknown names error
    /// with the full known-name list.
    pub fn parse(s: &str) -> Result<PolicySet, String> {
        let mut out: Vec<SchedPolicy> = Vec::new();
        for raw in s.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            let p = SchedPolicy::parse(tok).ok_or_else(|| {
                format!(
                    "unknown policy `{tok}` (known: {})",
                    REGISTRY
                        .iter()
                        .map(|d| d.policy.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            if !out.contains(&p) {
                out.push(p);
            }
        }
        if out.is_empty() {
            return Err("empty policy list".into());
        }
        Ok(PolicySet(out))
    }

    pub fn as_slice(&self) -> &[SchedPolicy] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn contains(&self, p: SchedPolicy) -> bool {
        self.0.contains(&p)
    }

    /// Comma-joined canonical names (config round-trip / display form).
    pub fn names(&self) -> String {
        self.0
            .iter()
            .map(|p| p.name())
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl Default for PolicySet {
    fn default() -> Self {
        PolicySet::paper()
    }
}

impl<'a> IntoIterator for &'a PolicySet {
    type Item = SchedPolicy;
    type IntoIter = std::iter::Copied<std::slice::Iter<'a, SchedPolicy>>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for p in SchedPolicy::EXTENDED {
            assert_eq!(SchedPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("nope"), None);
        // Aliases resolve to their canonical policy.
        assert_eq!(SchedPolicy::parse("ocwf_acc"), Some(SchedPolicy::ocwf(true)));
        assert_eq!(
            SchedPolicy::parse("jsqa"),
            Some(SchedPolicy::fifo(AssignPolicy::JsqAffinity))
        );
        assert_eq!(
            SchedPolicy::parse("max_weight"),
            Some(SchedPolicy::fifo(AssignPolicy::MaxWeight))
        );
    }

    #[test]
    fn registry_is_the_extended_panel_in_order() {
        assert_eq!(REGISTRY.len(), SchedPolicy::EXTENDED.len());
        for (d, p) in REGISTRY.iter().zip(SchedPolicy::EXTENDED) {
            assert_eq!(d.policy, p);
            assert!(!d.summary.is_empty() && !d.citation.is_empty());
        }
        let mut names: Vec<&str> = REGISTRY.iter().map(|d| d.policy.name()).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "registry names must be unique");
    }

    #[test]
    fn ordering_splits_from_assignment() {
        assert!(SchedPolicy::fifo(AssignPolicy::Jsq).is_fifo());
        assert_eq!(
            SchedPolicy::fifo(AssignPolicy::Rd).fifo_assign(),
            Some(AssignPolicy::Rd)
        );
        assert_eq!(SchedPolicy::ocwf(true).fifo_assign(), None);
        assert_eq!(
            SchedPolicy::ocwf(false).ordering,
            Ordering::Reorder { acc: false }
        );
        // OCWF's canonical assign axis is WF, keeping equality structural.
        assert_eq!(SchedPolicy::ocwf(true).assign, AssignPolicy::Wf);
    }

    #[test]
    fn policy_set_parses_dedups_and_defaults() {
        assert_eq!(PolicySet::default(), PolicySet::paper());
        assert_eq!(PolicySet::paper().len(), 6);
        assert_eq!(PolicySet::extended().len(), 10);
        let ps = PolicySet::parse("obta, wf,obta,jsq").unwrap();
        assert_eq!(
            ps.as_slice(),
            &[
                SchedPolicy::fifo(AssignPolicy::Obta),
                SchedPolicy::fifo(AssignPolicy::Wf),
                SchedPolicy::fifo(AssignPolicy::Jsq),
            ]
        );
        assert_eq!(ps.names(), "obta,wf,jsq");
        let err = PolicySet::parse("obta,bogus").unwrap_err();
        assert!(err.contains("bogus") && err.contains("maxweight"), "{err}");
        assert!(PolicySet::parse(" , ").is_err());
    }
}
