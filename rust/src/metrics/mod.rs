//! Experiment metrics: job completion time statistics and per-arrival
//! computation overhead — the paper's two evaluation axes (§V-A
//! "Metrics": "average job completion time of all jobs to measure
//! performance and the computation overhead of each algorithm to measure
//! efficiency").

use crate::util::json::Json;
use crate::util::stats::{ecdf_series_sorted, Summary};

/// Pooled sort buffer for the per-cell statistics of a sweep: one
/// `f64` scratch vector reused across every
/// [`JctStats::from_jcts_pooled`] / [`jct_cdf_pooled`] call, so a
/// sweep's render loop performs no per-cell stats allocations once the
/// buffer has grown to the largest trial (`rust/tests/alloc_stability.rs`
/// asserts the capacity freeze).
#[derive(Debug, Default)]
pub struct StatsScratch {
    xs: Vec<f64>,
}

impl StatsScratch {
    pub fn new() -> StatsScratch {
        StatsScratch::default()
    }

    /// Reserved capacity of the scratch buffer (in elements).
    pub fn footprint(&self) -> usize {
        self.xs.capacity()
    }

    /// Clear, refill from `jcts` and sort — the shared front half of
    /// both pooled entry points.
    fn load_sorted(&mut self, jcts: &[u64]) -> &[f64] {
        self.xs.clear();
        self.xs.extend(jcts.iter().map(|&x| x as f64));
        self.xs.sort_by(f64::total_cmp);
        &self.xs
    }
}

/// Summary of per-job completion times (in slots).
#[derive(Clone, Debug)]
pub struct JctStats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl JctStats {
    pub fn from_jcts(jcts: &[u64]) -> JctStats {
        JctStats::from_jcts_pooled(jcts, &mut StatsScratch::new())
    }

    /// [`JctStats::from_jcts`] through a caller-owned scratch buffer:
    /// no allocation once the scratch has warmed up to `jcts.len()`.
    pub fn from_jcts_pooled(jcts: &[u64], scratch: &mut StatsScratch) -> JctStats {
        let s = Summary::from_sorted(scratch.load_sorted(jcts));
        JctStats {
            n: s.n,
            mean: s.mean,
            p50: s.p50,
            p90: s.p90,
            p99: s.p99,
            max: s.max,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Build the empirical CDF series of completion times (the CDF subplots
/// of Figs 10–14), sampled at `points` x-positions.
pub fn jct_cdf(jcts: &[u64], points: usize) -> Vec<(f64, f64)> {
    jct_cdf_pooled(jcts, points, &mut StatsScratch::new())
}

/// [`jct_cdf`] through a caller-owned scratch buffer: only the returned
/// series allocates.
pub fn jct_cdf_pooled(
    jcts: &[u64],
    points: usize,
    scratch: &mut StatsScratch,
) -> Vec<(f64, f64)> {
    ecdf_series_sorted(scratch.load_sorted(jcts), points)
}

/// One result row of a figure/table: algorithm → (mean JCT, overhead).
#[derive(Clone, Debug)]
pub struct ResultRow {
    pub algorithm: String,
    pub mean_jct: f64,
    pub overhead_us: f64,
}

impl ResultRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.clone())),
            ("mean_jct", Json::num(self.mean_jct)),
            ("overhead_us", Json::num(self.overhead_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_jcts() {
        let s = JctStats::from_jcts(&[10, 20, 30, 40]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 25.0).abs() < 1e-12);
        assert!((s.max - 40.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_series_spans_range() {
        let series = jct_cdf(&[1, 2, 3, 4, 5], 11);
        assert_eq!(series.len(), 11);
        assert!((series[0].0 - 1.0).abs() < 1e-12);
        assert!((series.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pooled_stats_match_allocating_path_and_freeze() {
        let mut scratch = StatsScratch::new();
        let jcts: Vec<u64> = (1..=200).collect();
        let a = JctStats::from_jcts(&jcts);
        let b = JctStats::from_jcts_pooled(&jcts, &mut scratch);
        assert_eq!(a.n, b.n);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p50, b.p50);
        assert_eq!(a.p99, b.p99);
        assert_eq!(jct_cdf(&jcts, 16), jct_cdf_pooled(&jcts, 16, &mut scratch));
        let frozen = scratch.footprint();
        assert!(frozen >= jcts.len());
        for _ in 0..4 {
            let _ = JctStats::from_jcts_pooled(&jcts, &mut scratch);
            let _ = jct_cdf_pooled(&jcts, 16, &mut scratch);
        }
        assert_eq!(scratch.footprint(), frozen, "scratch capacity frozen");
    }

    #[test]
    fn row_serializes() {
        let r = ResultRow {
            algorithm: "wf".into(),
            mean_jct: 6042.0,
            overhead_us: 12.5,
        };
        let j = r.to_json().to_string();
        assert!(j.contains("\"algorithm\":\"wf\""));
        assert!(j.contains("6042"));
    }
}
