//! Experiment metrics: job completion time statistics and per-arrival
//! computation overhead — the paper's two evaluation axes (§V-A
//! "Metrics": "average job completion time of all jobs to measure
//! performance and the computation overhead of each algorithm to measure
//! efficiency").

use crate::util::json::Json;
use crate::util::stats::{Ecdf, Summary};

/// Summary of per-job completion times (in slots).
#[derive(Clone, Debug)]
pub struct JctStats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl JctStats {
    pub fn from_jcts(jcts: &[u64]) -> JctStats {
        let xs: Vec<f64> = jcts.iter().map(|&x| x as f64).collect();
        let s = Summary::from(&xs);
        JctStats {
            n: s.n,
            mean: s.mean,
            p50: s.p50,
            p90: s.p90,
            p99: s.p99,
            max: s.max,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Build the empirical CDF series of completion times (the CDF subplots
/// of Figs 10–14), sampled at `points` x-positions.
pub fn jct_cdf(jcts: &[u64], points: usize) -> Vec<(f64, f64)> {
    let xs: Vec<f64> = jcts.iter().map(|&x| x as f64).collect();
    Ecdf::from(&xs).series(points)
}

/// One result row of a figure/table: algorithm → (mean JCT, overhead).
#[derive(Clone, Debug)]
pub struct ResultRow {
    pub algorithm: String,
    pub mean_jct: f64,
    pub overhead_us: f64,
}

impl ResultRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.clone())),
            ("mean_jct", Json::num(self.mean_jct)),
            ("overhead_us", Json::num(self.overhead_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_jcts() {
        let s = JctStats::from_jcts(&[10, 20, 30, 40]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 25.0).abs() < 1e-12);
        assert!((s.max - 40.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_series_spans_range() {
        let series = jct_cdf(&[1, 2, 3, 4, 5], 11);
        assert_eq!(series.len(), 11);
        assert!((series[0].0 - 1.0).abs() < 1e-12);
        assert!((series.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn row_serializes() {
        let r = ResultRow {
            algorithm: "wf".into(),
            mean_jct: 6042.0,
            overhead_us: 12.5,
        };
        let j = r.to_json().to_string();
        assert!(j.contains("\"algorithm\":\"wf\""));
        assert!(j.contains("6042"));
    }
}
