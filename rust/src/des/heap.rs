//! The pooled binary-heap event core of the DES engine.
//!
//! A hand-rolled min-heap on a plain `Vec` so the backing storage is
//! reusable across runs: [`EventHeap::clear`] keeps the capacity, and the
//! steady-state push/pop cycle of a warmed engine touches the allocator
//! zero times (the heap's high-water mark is part of
//! [`crate::des::DesRun::pool_footprint`], frozen by
//! `rust/tests/alloc_stability.rs`).
//!
//! ## Total event order
//!
//! Events are ordered by the key `(time, class, lane, seq)`:
//!
//! - `time` — the slot the event fires at;
//! - `class` — completions (`0`) strictly before arrivals (`1`) at the
//!   same slot. This mirrors the analytic engines: a queue entry whose
//!   finish coincides with an arrival is fully drained *before* the
//!   arrival is scheduled against the cluster state (the reordered
//!   engine's `ServerQueues::drain(from, to)` retires entries finishing
//!   exactly at `to`);
//! - `lane` — the server of a completion or the job index of an arrival;
//! - `seq` — a monotone push counter.
//!
//! The key is a *total* order over every event ever pushed, so a run's
//! event sequence — and with it every downstream decision and RNG draw —
//! is bit-reproducible regardless of heap internals.

use crate::job::{ServerId, Slots};

/// What happens when an event fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// The entry in service at `server` finishes. `token` must equal the
    /// server's current token; a stale token means the entry was
    /// preempted (reorder) or cancelled (lost a replica race) and the
    /// event is ignored.
    Complete { server: ServerId, token: u64 },
    /// Job `job` (index into the run's job slice) arrives.
    Arrival { job: usize },
}

/// One scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: Slots,
    pub kind: EventKind,
    /// Queue-assigned push counter — crate-visible so every
    /// [`crate::des::calendar::EventQueue`] implementation can stamp the
    /// same tie-break.
    pub(crate) seq: u64,
}

impl Event {
    #[inline]
    pub(crate) fn key(&self) -> (Slots, u8, u64, u64) {
        let (class, lane) = match self.kind {
            EventKind::Complete { server, .. } => (0u8, server as u64),
            EventKind::Arrival { job } => (1u8, job as u64),
        };
        (self.time, class, lane, self.seq)
    }
}

/// A pooled min-heap of [`Event`]s.
#[derive(Clone, Debug, Default)]
pub struct EventHeap {
    items: Vec<Event>,
    seq: u64,
}

impl EventHeap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Drop every pending event, keeping the backing allocation.
    pub fn clear(&mut self) {
        self.items.clear();
    }

    /// Schedule an event. Push order is the stability tie-break: two
    /// events with equal `(time, class, lane)` fire in push order.
    pub fn push(&mut self, time: Slots, kind: EventKind) {
        let ev = Event {
            time,
            kind,
            seq: self.seq,
        };
        self.seq += 1;
        self.items.push(ev);
        self.sift_up(self.items.len() - 1);
    }

    /// The next event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.items.first()
    }

    /// Remove and return the next event in `(time, class, lane, seq)`
    /// order.
    pub fn pop(&mut self) -> Option<Event> {
        if self.items.is_empty() {
            return None;
        }
        let last = self.items.len() - 1;
        self.items.swap(0, last);
        let ev = self.items.pop();
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        ev
    }

    /// Reserved capacity of the backing storage (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.items.capacity()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.items[i].key() < self.items[parent].key() {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.items[l].key() < self.items[smallest].key() {
                smallest = l;
            }
            if r < n && self.items[r].key() < self.items[smallest].key() {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.items.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        for &t in &[9u64, 3, 7, 1, 8, 2] {
            h.push(t, EventKind::Arrival { job: t as usize });
        }
        let mut times = Vec::new();
        while let Some(e) = h.pop() {
            times.push(e.time);
        }
        assert_eq!(times, vec![1, 2, 3, 7, 8, 9]);
    }

    #[test]
    fn completions_fire_before_arrivals_at_the_same_slot() {
        let mut h = EventHeap::new();
        h.push(5, EventKind::Arrival { job: 0 });
        h.push(
            5,
            EventKind::Complete {
                server: 3,
                token: 0,
            },
        );
        let first = h.pop().unwrap();
        assert!(matches!(first.kind, EventKind::Complete { server: 3, .. }));
        let second = h.pop().unwrap();
        assert!(matches!(second.kind, EventKind::Arrival { job: 0 }));
    }

    #[test]
    fn same_key_events_are_stable_by_push_order() {
        // Arrivals for distinct jobs at the same slot order by lane (job
        // index), and re-pushes of the same lane order by seq.
        let mut h = EventHeap::new();
        h.push(2, EventKind::Arrival { job: 4 });
        h.push(2, EventKind::Arrival { job: 1 });
        h.push(2, EventKind::Arrival { job: 4 });
        let picked: Vec<usize> = (0..3)
            .map(|_| match h.pop().unwrap().kind {
                EventKind::Arrival { job } => job,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(picked, vec![1, 4, 4]);

        // Completions on the same server at the same slot: push order.
        let mut h = EventHeap::new();
        for token in [7u64, 8, 9] {
            h.push(1, EventKind::Complete { server: 0, token });
        }
        let tokens: Vec<u64> = (0..3)
            .map(|_| match h.pop().unwrap().kind {
                EventKind::Complete { token, .. } => token,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tokens, vec![7, 8, 9]);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut h = EventHeap::new();
        for t in 0..64u64 {
            h.push(t, EventKind::Arrival { job: t as usize });
        }
        let cap = h.footprint();
        assert!(cap >= 64);
        h.clear();
        assert!(h.is_empty());
        assert_eq!(h.footprint(), cap);
        // Refilling to the same depth must not move the capacity.
        for t in 0..64u64 {
            h.push(t, EventKind::Arrival { job: t as usize });
        }
        assert_eq!(h.footprint(), cap);
    }

    #[test]
    fn randomized_heap_matches_sorted_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0xDE5);
        let mut h = EventHeap::new();
        let mut reference: Vec<(u64, u8, u64, u64)> = Vec::new();
        for seq in 0..500u64 {
            let t = rng.gen_range(50);
            if rng.gen_range(2) == 0 {
                let server = rng.gen_range(8) as usize;
                h.push(
                    t,
                    EventKind::Complete {
                        server,
                        token: seq,
                    },
                );
                reference.push((t, 0, server as u64, seq));
            } else {
                let job = rng.gen_range(20) as usize;
                h.push(t, EventKind::Arrival { job });
                reference.push((t, 1, job as u64, seq));
            }
        }
        reference.sort();
        for want in reference {
            let got = h.pop().unwrap();
            let (t, class, lane) = match got.kind {
                EventKind::Complete { server, .. } => (got.time, 0u8, server as u64),
                EventKind::Arrival { job } => (got.time, 1u8, job as u64),
            };
            assert_eq!((t, class, lane), (want.0, want.1, want.2));
        }
        assert!(h.pop().is_none());
    }
}
