//! The discrete-event fidelity engine.
//!
//! Every analytic engine in [`crate::sim`] evaluates the paper's
//! deterministic busy-time recursion (eq. 2) at arrival instants, which
//! restricts the scenario catalog to workloads where task durations are
//! exact and nothing happens between arrivals. This engine replays the
//! same traces through a genuine event loop — a pooled binary-heap event
//! core ([`heap::EventHeap`]), per-server run queues layered on
//! [`crate::cluster::state`], and the same `materialize_jobs` /
//! [`crate::assign::Assigner`] / OCWF pipeline as the analytic engines —
//! which unlocks three mechanism axes the analytic model cannot express:
//!
//! - **Stochastic service** ([`service::ServiceModel`]): entry durations
//!   are `max(1, round(base × X))` where `base` is the analytic
//!   `ceil(n/μ)` figure and `X` a sampled slowdown factor (exponential
//!   noise or a capped Pareto straggler tail).
//! - **Budgeted k-replica redundancy** (`SimConfig::replicas` +
//!   `SimConfig::replication_budget`, with `SimConfig::speculate` as the
//!   K = 2 alias): in the spirit of Wang–Joshi–Wornell's task-replication
//!   analysis, an entry whose start passes the replication budget forks
//!   onto up to K − 1 eligible servers, least-loaded first (the replicas
//!   RD would have deleted actually race); the first completion applies
//!   the progress and eagerly cancels *every* loser — a running loser
//!   frees its server at the winner's slot, a queued loser is dropped at
//!   its queue head in O(1) via the entry's back-index into the replica
//!   set (no queue scan). The slots losers burned are surfaced as
//!   `SimOutcome::wasted_work`, the cost axis of the replication
//!   frontier.
//! - **Hierarchical multi-level locality** (`SimConfig::locality_penalty`
//!   graded by `SimConfig::topology`, see [`crate::topology`]): per
//!   Yekkehkhany's near-data model, every server can run every task, but
//!   a task executed outside its group's data-local server set runs at
//!   `μ / tier_penalty`, where the tier (same rack → same zone → beyond)
//!   comes from the configured rack/zone hierarchy and the top tier
//!   charges the full penalty. The engine hands the assigners *expanded*
//!   server sets (they place freely; they are penalty-oblivious, exactly
//!   the tension near-data scheduling studies), charges the tier rate at
//!   execution time, and counts the tasks completed per tier
//!   (`SimOutcome::tier_tasks`, the locality hit-rate telemetry).
//!
//! ## The deterministic mode is a hard invariant
//!
//! With [`service::ServiceModel::Deterministic`] and both engine-only
//! mechanisms off, this engine reproduces the analytic engines' JCT
//! vectors **bit for bit** — FIFO and reordered policies alike, on every
//! scenario preset (`rust/tests/des_equivalence.rs`). That makes the DES
//! engine an independent differential oracle for the analytic engines:
//! the two implementations share the assignment/reorder layers but arrive
//! at completion times through entirely different machinery (event
//! cascade vs. closed-form drain).
//!
//! Determinism in the stochastic modes: the event order is a total order
//! (`(time, class, lane, seq)`, see [`heap`]), service-noise draws happen
//! in event order from a dedicated RNG stream, and the reorder fan-out is
//! bit-identical at any thread count — so one seed yields byte-identical
//! JCT vectors across runs and thread counts.
//!
//! ## Allocation discipline
//!
//! All steady-state state is pooled: the event heap keeps its backing
//! storage, run-queue entries recycle their parts buffers through a spare
//! pool (the [`EntrySink`] side of the shared [`QueueRebuild`] grouping
//! path), replica sets live in a slab with a free list (their member
//! lists recycle through a spare pool of their own), and the reorder
//! workspace/outcome/outstanding-set pools are the same ones the analytic
//! engine uses. After warmup, event processing performs **zero heap
//! allocations** ([`DesRun::pool_footprint`] freeze asserted by
//! `rust/tests/alloc_stability.rs`).

pub mod calendar;
pub mod heap;
pub mod service;
pub mod stream;

use crate::assign::{validate_assignment, Assigner};
use crate::cluster::state::{ClusterState, EntrySink, JobProgress, QueueRebuild};
use crate::config::SimConfig;
use crate::job::{Job, ServerId, Slots, TaskCount, TaskGroup};
use crate::obs::ObsSink;
use crate::sched::ocwf::{reorder_into, OutstandingSet, ReorderOutcome, ReorderWorkspace};
use crate::sched::SchedPolicy;
use crate::sim::SimOutcome;
use crate::topology::{Locality, Topology};
use crate::util::ceil_div;
use crate::util::rng::Rng;
use crate::util::timer::OverheadMeter;
use calendar::AnyEventQueue;
use heap::EventKind;
use std::collections::VecDeque;
use stream::{JobFeed, StreamFeed};

/// One run-queue entry: the tasks of one job assigned to one server,
/// split by task group — the DES twin of
/// [`crate::cluster::state::QueueEntry`], extended with the deterministic
/// duration estimate and replica-racing metadata.
#[derive(Clone, Debug)]
struct DesEntry {
    job: usize,
    parts: Vec<(usize, TaskCount)>,
    /// Deterministic duration estimate in slots (`ceil(n/μ)`, with the
    /// locality penalty folded in for remote parts).
    base: Slots,
    /// Back-index into the replica-set slab, if this entry races: the
    /// O(1) handle a queued loser is dropped through (the set's `done`
    /// flag is checked when the entry surfaces at its queue head).
    set: Option<u32>,
    /// True for a speculative copy (replicas never re-replicate and
    /// contribute no partial progress at a reorder preemption).
    replica: bool,
}

/// The entry a server is currently processing.
#[derive(Clone, Debug)]
struct Running {
    entry: DesEntry,
    start: Slots,
    /// Sampled duration (slots); equals `entry.base` in deterministic
    /// mode.
    dur: Slots,
}

/// One server's run queue + in-service state.
#[derive(Clone, Debug, Default)]
struct Lane {
    queue: VecDeque<DesEntry>,
    running: Option<Running>,
    /// Staleness guard for pending completion events: bumped on every
    /// preemption/cancellation, checked when a completion fires.
    token: u64,
}

/// A k-member replica race: the primary copy of one entry plus up to
/// K − 1 speculative copies, one per member lane. The winner resolves
/// the set (`done`); running losers retire at that very slot, queued
/// losers linger as tombstones until their queue head pops them, so the
/// slab slot recycles only when `live` reaches zero — an entry holding a
/// set id therefore always references a live slot.
#[derive(Clone, Debug, Default)]
struct ReplicaSet {
    /// Resolved: a member completed; every other member is a loser.
    done: bool,
    /// Members not yet retired (completed, cancelled, or dropped).
    live: u32,
    /// Member lanes in fork order: `members[0]` is the primary.
    members: Vec<ServerId>,
}

/// Deterministic duration estimate of a parts batch on `server`:
/// `ceil(total/μ)`, or — when multi-level locality is active
/// (`locality` carries the per-(job, group, server) tier table) —
/// `ceil(work/μ)` where each task counts `tier_penalty ×` its size.
///
/// A batch whose every part runs at exactly the local rate takes the
/// same integer `ceil_div` path as the no-locality estimate, so a
/// penalty of 1.0 (or an all-local placement) is bit-identical to the
/// no-locality engine at **any** task count — the f64 path rounds
/// `2^53 + 1` tasks down, the integer path does not.
fn entry_base(
    job_payload: &Job,
    locality: Option<&Locality>,
    job: usize,
    parts: &[(usize, TaskCount)],
    server: ServerId,
) -> Slots {
    let mu = job_payload.mu[server];
    let total: TaskCount = parts.iter().map(|&(_, n)| n).sum();
    let Some(loc) = locality else {
        return ceil_div(total, mu);
    };
    let mut work = 0.0f64;
    let mut weighted = false;
    for &(k, n) in parts {
        let w = loc.rate_weight(job, k, server);
        weighted |= w != 1.0;
        work += n as f64 * w;
    }
    if !weighted {
        return ceil_div(total, mu);
    }
    // The epsilon absorbs float dust from an inexact penalty
    // (10 × 1.1 / 11 computes as 1.0000000000000002 and must
    // not ceil to 2); penalties are user knobs with far coarser
    // precision than 1e-9.
    ((work / mu as f64 - 1e-9).ceil() as Slots).max(1)
}

/// The [`EntrySink`] the shared [`QueueRebuild`] grouping path writes
/// into: freshly grouped entries land at the tail of the target lane with
/// their deterministic duration estimate computed and the server's
/// queue-empty estimate advanced.
struct LaneSink<'s, 'a> {
    lanes: &'s mut [Lane],
    spare: &'s mut Vec<Vec<(usize, TaskCount)>>,
    feed: &'s JobFeed<'a>,
    locality: Option<&'a Locality>,
    free_est: &'s mut [Slots],
    now: Slots,
}

impl EntrySink for LaneSink<'_, '_> {
    fn take_parts(&mut self) -> Vec<(usize, TaskCount)> {
        self.spare.pop().unwrap_or_default()
    }

    fn push_entry(&mut self, server: ServerId, job: usize, parts: Vec<(usize, TaskCount)>) {
        let base = entry_base(self.feed.job(job), self.locality, job, &parts, server);
        self.free_est[server] = self.free_est[server].max(self.now) + base;
        self.lanes[server].queue.push_back(DesEntry {
            job,
            parts,
            base,
            set: None,
            replica: false,
        });
    }
}

/// The discrete-event engine, driving one trace through one policy.
///
/// Use [`run_des`] (or [`crate::sim::run_policy`] with `SimConfig.engine
/// = des`) for a one-shot run; the struct itself is public so tests can
/// pump events one at a time and probe [`DesRun::pool_footprint`].
pub struct DesRun<'a> {
    /// The assignment view of the jobs: the caller's slice (or the
    /// expanded-server-set clone when multi-level locality is active),
    /// or a bounded streaming window ([`stream::JobFeed`]).
    feed: JobFeed<'a>,
    /// Precomputed per-(job, group, server) locality tiers (`Some` iff
    /// the locality penalty is active; `jobs` then carries the expanded
    /// sets while the tier table was built from the original data-local
    /// sets).
    locality: Option<&'a Locality>,
    num_servers: usize,
    policy: SchedPolicy,
    cfg: &'a SimConfig,
    queue: AnyEventQueue,
    servers: Vec<Lane>,
    /// Recycled entry parts buffers (the engine-side spare pool).
    spare: Vec<Vec<(usize, TaskCount)>>,
    /// Recycled per-group progress rows (streaming mode: a retired job's
    /// row is reclaimed for the next pulled job).
    spare_rows: Vec<Vec<TaskCount>>,
    /// The replica-set slab (+ free list); member lists recycle through
    /// `member_spare` so reorder preemptions stay allocation-free.
    sets: Vec<ReplicaSet>,
    set_free: Vec<u32>,
    member_spare: Vec<Vec<ServerId>>,
    /// Scratch: lanes woken by replica forks during a start, drained by
    /// `kick_lane` (one fork can wake up to K − 1 idle lanes).
    woken: Vec<ServerId>,
    /// Scratch: lanes freed by cancelling running losers, kicked after
    /// the winner's lane.
    freed: Vec<ServerId>,
    /// Scratch: replica target lanes (fork order, primary first) and the
    /// matching deterministic estimates while a fork is being built.
    fork_members: Vec<ServerId>,
    fork_bases: Vec<Slots>,
    progress: JobProgress,
    rebuild: QueueRebuild,
    oset: OutstandingSet<'a>,
    ws: ReorderWorkspace,
    outcome: ReorderOutcome,
    state: ClusterState,
    /// Per-server queue-empty estimate (deterministic durations): the
    /// FIFO assigners' busy-time view and the replica-target ranking.
    free_est: Vec<Slots>,
    assigner: Option<Box<dyn Assigner>>,
    service_rng: Rng,
    overhead: OverheadMeter,
    wf_evals: u64,
    /// Tasks completed per locality tier (empty without locality): the
    /// hit-rate telemetry surfaced through `SimOutcome::tier_tasks`.
    tier_tasks: Vec<u64>,
    /// Slots burned by replica-race losers (running losers' elapsed time
    /// at cancellation or reorder preemption): the cost axis of the
    /// replication frontier, surfaced through `SimOutcome::wasted_work`.
    wasted_work: u64,
    /// Total slots any server spent in service (useful + wasted): the
    /// denominator of the wasted-work fraction.
    busy_work: u64,
    /// Events popped (live + stale) — the throughput telemetry numerator
    /// surfaced through `SimOutcome::events`.
    events: u64,
    /// High-water mark of the event-queue population.
    peak_events: usize,
    arrival_idx: usize,
    now: Slots,
    /// The observability sink (default: off — one branch per emission
    /// site). Attach via [`DesRun::attach_obs`]; scheduling decisions
    /// never read it, so outcomes are bit-identical tracing on or off.
    obs: ObsSink,
    /// Construction instant, for the `--progress` heartbeat's
    /// events-per-second figure (stderr only; never in artifacts).
    t0: std::time::Instant,
}

impl<'a> DesRun<'a> {
    pub fn new(
        jobs: &'a [Job],
        num_servers: usize,
        policy: SchedPolicy,
        cfg: &'a SimConfig,
        seed: u64,
    ) -> Self {
        Self::with_locality(jobs, None, num_servers, policy, cfg, seed)
    }

    fn with_locality(
        jobs: &'a [Job],
        locality: Option<&'a Locality>,
        num_servers: usize,
        policy: SchedPolicy,
        cfg: &'a SimConfig,
        seed: u64,
    ) -> Self {
        debug_assert!(
            jobs.iter().enumerate().all(|(i, j)| j.id == i),
            "DesRun requires job ids to equal their slice positions"
        );
        // Same precondition as ReorderedRun (and what materialize_jobs
        // produces): chronological job order. The arrival-staleness check
        // in `pump` classifies events below `arrival_idx` as absorbed by
        // an earlier batch, which is only sound for sorted arrivals.
        debug_assert!(
            jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "DesRun requires jobs sorted by arrival slot"
        );
        let mut run = Self::build(
            JobFeed::Slice(jobs),
            JobProgress::new(jobs),
            locality,
            num_servers,
            policy,
            cfg,
            seed,
        );
        for (i, job) in jobs.iter().enumerate() {
            debug_assert!(job.mu.len() == num_servers);
            run.queue.push(job.arrival, EventKind::Arrival { job: i });
        }
        run
    }

    /// A streaming run: jobs are pulled from `source` one admission
    /// ahead, payloads are evicted on completion ([`stream::JobFeed`]),
    /// and the outcome's JCT vector is still exact (per-job scalars stay
    /// resident). FIFO policies with unit locality only — OCWF and the
    /// locality model need the materialized slice.
    pub fn new_streaming(
        source: Box<dyn crate::sim::stream::JobSource + 'a>,
        num_servers: usize,
        policy: SchedPolicy,
        cfg: &'a SimConfig,
        seed: u64,
    ) -> crate::Result<Self> {
        if !policy.is_fifo() {
            return Err(crate::Error::Config(
                "streaming DES runs support FIFO policies only: OCWF reorders \
                 every outstanding job and needs the materialized path"
                    .into(),
            ));
        }
        if cfg.locality_penalty > 1.0 {
            return Err(crate::Error::Config(
                "streaming DES runs require locality_penalty = 1: the locality \
                 model precomputes per-job tier tables over the full job list"
                    .into(),
            ));
        }
        let mut run = Self::build(
            JobFeed::Stream(StreamFeed::new(source)),
            JobProgress::empty(),
            None,
            num_servers,
            policy,
            cfg,
            seed,
        );
        run.pull_next_arrival()?;
        Ok(run)
    }

    fn build(
        feed: JobFeed<'a>,
        progress: JobProgress,
        locality: Option<&'a Locality>,
        num_servers: usize,
        policy: SchedPolicy,
        cfg: &'a SimConfig,
        seed: u64,
    ) -> Self {
        let assigner = policy
            .fifo_assign()
            .map(|p| p.build_with(seed, &cfg.assign_params()));
        let mut ws = ReorderWorkspace::default();
        ws.set_spec_chunk(cfg.acc_spec_chunk);
        DesRun {
            feed,
            locality,
            num_servers,
            policy,
            cfg,
            queue: AnyEventQueue::new(cfg.event_queue),
            servers: vec![Lane::default(); num_servers],
            spare: Vec::new(),
            spare_rows: Vec::new(),
            sets: Vec::new(),
            set_free: Vec::new(),
            member_spare: Vec::new(),
            woken: Vec::new(),
            freed: Vec::new(),
            fork_members: Vec::new(),
            fork_bases: Vec::new(),
            progress,
            rebuild: QueueRebuild::new(num_servers),
            oset: OutstandingSet::new(),
            ws,
            outcome: ReorderOutcome::default(),
            state: ClusterState::new(num_servers),
            free_est: vec![0; num_servers],
            assigner,
            service_rng: Rng::seed_from(seed).fork(0xDE5),
            overhead: OverheadMeter::new(),
            wf_evals: 0,
            tier_tasks: vec![0; locality.map_or(0, |l| l.num_tiers())],
            wasted_work: 0,
            busy_work: 0,
            events: 0,
            peak_events: 0,
            arrival_idx: 0,
            now: 0,
            obs: ObsSink::off(),
            t0: std::time::Instant::now(),
        }
    }

    /// Attach an observability sink (default: off). The DES engine emits
    /// the full event vocabulary: arrivals, per-server assignment rows,
    /// task start/finish spans, replica fork/win/lose, reorder rounds,
    /// preemptions and job completions.
    pub fn attach_obs(&mut self, obs: ObsSink) {
        self.obs = obs;
    }

    /// Streaming mode: pull the next job from the source and schedule its
    /// arrival event (no-op for materialized slices, whose arrivals are
    /// all pre-pushed). Called once at construction and once per
    /// admission, so the event queue always holds the next unadmitted
    /// arrival — see [`stream`] for why this lazy push is bit-identical
    /// to pushing everything up front.
    fn pull_next_arrival(&mut self) -> crate::Result<()> {
        let DesRun {
            feed,
            progress,
            queue,
            spare_rows,
            num_servers,
            ..
        } = self;
        if let JobFeed::Stream(sf) = feed {
            if let Some(job) = sf.pull()? {
                debug_assert!(job.mu.len() == *num_servers);
                progress.push_job(job, spare_rows);
                queue.push(job.arrival, EventKind::Arrival { job: job.id });
            }
        }
        Ok(())
    }

    /// Current simulation time (last processed event).
    pub fn now(&self) -> Slots {
        self.now
    }

    /// Process one event. Returns `Ok(false)` once the heap is drained,
    /// [`crate::Error::Sim`] when a *live* event lies beyond
    /// `cfg.max_slots`.
    pub fn pump(&mut self) -> crate::Result<bool> {
        self.peak_events = self.peak_events.max(self.queue.len());
        let Some(ev) = self.queue.pop() else {
            return Ok(false);
        };
        self.events += 1;
        if self.cfg.progress_every > 0 && self.events % self.cfg.progress_every == 0 {
            let seen = self.feed.seen();
            let done = seen - self.progress.unfinished();
            let secs = self.t0.elapsed().as_secs_f64();
            let rate = if secs > 0.0 {
                self.events as f64 / secs
            } else {
                0.0
            };
            eprintln!(
                "[taos des] events={} jobs={}/{} rate={:.0} ev/s peak_window={}",
                self.events,
                done,
                seen,
                rate,
                self.feed.peak_window()
            );
        }
        // Staleness before the horizon check: a preempted or cancelled
        // entry's completion event may lie far past `max_slots` even
        // though the live schedule finishes well within it (the analytic
        // engines only error when real work crosses the horizon).
        let live = match ev.kind {
            EventKind::Complete { server, token } => token == self.servers[server].token,
            EventKind::Arrival { job } => job >= self.arrival_idx,
        };
        if !live {
            return Ok(!self.queue.is_empty());
        }
        if ev.time > self.cfg.max_slots {
            return Err(crate::Error::Sim(format!(
                "des/{} run exceeded max_slots = {}: event at slot {} \
                 ({} jobs, {} servers, service {}, speculate {}, \
                 locality_penalty {}, topology {}); utilization config too hot",
                self.policy.name(),
                self.cfg.max_slots,
                ev.time,
                self.feed.seen(),
                self.num_servers,
                self.cfg.service.describe(),
                self.cfg.speculate,
                self.cfg.locality_penalty,
                self.cfg.topology.name()
            )));
        }
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        match ev.kind {
            EventKind::Complete { server, token } => self.on_complete(server, token),
            EventKind::Arrival { job } => match self.policy.ordering {
                crate::sched::Ordering::Fifo => self.admit_fifo(job)?,
                crate::sched::Ordering::Reorder { acc } => self.admit_reorder_batch(job, acc),
            },
        }
        Ok(!self.queue.is_empty())
    }

    /// Drain every event and produce the outcome.
    pub fn finish(self) -> crate::Result<SimOutcome> {
        self.finish_inner().map(|(out, _)| out)
    }

    /// [`DesRun::finish`] returning the attached [`ObsSink`] as well, so
    /// callers can export the trace / metrics it collected.
    pub fn finish_with_obs(self) -> crate::Result<(SimOutcome, ObsSink)> {
        self.finish_inner()
    }

    fn finish_inner(mut self) -> crate::Result<(SimOutcome, ObsSink)> {
        while self.pump()? {}
        if !self.progress.all_complete() {
            return Err(crate::Error::Sim(format!(
                "des/{} run drained its event heap with {} of {} jobs \
                 unfinished ({} servers)",
                self.policy.name(),
                self.progress.unfinished(),
                self.feed.seen(),
                self.num_servers
            )));
        }
        let peak_pool = self.pool_footprint();
        let (jcts, makespan) = match &self.feed {
            JobFeed::Slice(jobs) => self.progress.jcts_and_makespan(jobs),
            JobFeed::Stream(sf) => self.progress.jcts_and_makespan_from(sf.arrivals()),
        };
        let waits = match &self.feed {
            JobFeed::Slice(jobs) => self.progress.waits(jobs),
            JobFeed::Stream(sf) => self.progress.waits_from(sf.arrivals()),
        };
        Ok((
            SimOutcome {
                jcts,
                waits,
                overhead: self.overhead,
                makespan,
                wf_evals: self.wf_evals,
                oracle_stats: self.assigner.as_ref().and_then(|a| a.oracle_stats()),
                tier_tasks: self.tier_tasks,
                wasted_work: self.wasted_work,
                busy_work: self.busy_work,
                telemetry: crate::sim::RunTelemetry {
                    events: self.events,
                    peak_events: self.peak_events,
                    peak_pool,
                    peak_window: self.feed.peak_window(),
                },
            },
            self.obs,
        ))
    }

    /// Reserved capacity across every pooled buffer of the event path:
    /// the heap, lane queues (live entries + spare parts pool), the
    /// replica-set slab, the rebuild rows, and the reorder pools shared
    /// with the analytic engine (allocation-stability tests).
    pub fn pool_footprint(&self) -> usize {
        let lanes: usize = self
            .servers
            .iter()
            .map(|l| {
                l.queue.capacity()
                    + l.queue.iter().map(|e| e.parts.capacity()).sum::<usize>()
                    + l.running.as_ref().map_or(0, |r| r.entry.parts.capacity())
            })
            .sum();
        self.queue.footprint()
            + self.servers.capacity()
            + lanes
            + self.spare.capacity()
            + self.spare.iter().map(|v| v.capacity()).sum::<usize>()
            + self.feed.footprint()
            + self.spare_rows.capacity()
            + self.spare_rows.iter().map(|v| v.capacity()).sum::<usize>()
            + self.sets.capacity()
            + self.sets.iter().map(|s| s.members.capacity()).sum::<usize>()
            + self.set_free.capacity()
            + self.member_spare.capacity()
            + self.member_spare.iter().map(|v| v.capacity()).sum::<usize>()
            + self.woken.capacity()
            + self.freed.capacity()
            + self.fork_members.capacity()
            + self.fork_bases.capacity()
            + self.rebuild.footprint()
            + self.oset.footprint()
            + self.ws.footprint()
            + self.outcome.footprint()
            + self.state.footprint()
            + self.free_est.capacity()
            + self.obs.footprint()
    }

    /// FIFO admission: assign the arriving job once against the current
    /// queue-empty estimates (the exact cluster view the analytic
    /// `run_fifo` computes) and append its per-server entries. Streaming
    /// feeds pull the *next* job first, so its arrival event is in the
    /// queue before this admission completes.
    fn admit_fifo(&mut self, i: usize) -> crate::Result<()> {
        self.pull_next_arrival()?;
        let t = self.now;
        {
            let DesRun {
                feed,
                locality,
                state,
                free_est,
                assigner,
                overhead,
                servers,
                spare,
                rebuild,
                obs,
                ..
            } = self;
            let feed: &JobFeed<'a> = feed;
            let job = feed.job(i);
            debug_assert_eq!(job.arrival, t);
            obs.trace
                .job_arrive(t, i, job.groups.len() as u64, job.total_tasks());
            state.observe_free(free_est.as_slice(), t);
            if obs.metrics {
                for &f in free_est.iter() {
                    obs.queue_depth.observe(f.saturating_sub(t));
                }
            }
            let inst = state.instance(&job.groups, &job.mu);
            let assigner = assigner.as_mut().expect("FIFO policy has an assigner");
            let a = overhead.measure(|| assigner.assign(&inst));
            debug_assert_eq!(validate_assignment(&inst, &a), Ok(()));
            if obs.trace.on() {
                for (m, n) in a.per_server() {
                    obs.trace.assign(t, i, m, n, 0);
                }
            }
            let mut sink = LaneSink {
                lanes: servers,
                spare,
                feed,
                locality: *locality,
                free_est,
                now: t,
            };
            rebuild.push_grouped(&mut sink, i, &a.per_group);
        }
        self.arrival_idx = i + 1;
        self.kick_idle(t);
        Ok(())
    }

    /// Reordered admission: preempt every in-service entry (crediting the
    /// whole slots it already ran, exactly like the analytic drain's
    /// partial-entry rule), reorder all outstanding jobs once per
    /// distinct arrival slot, and rebuild every queue in the new order.
    fn admit_reorder_batch(&mut self, first: usize, acc: bool) {
        let t = self.now;
        debug_assert_eq!(self.feed.job(first).arrival, t);
        let mut newest = first;
        {
            let jobs = self.feed.slice();
            while newest + 1 < jobs.len() && jobs[newest + 1].arrival == t {
                newest += 1;
            }
        }
        if self.obs.trace.on() {
            let jobs = self.feed.slice();
            for i in first..=newest {
                self.obs
                    .trace
                    .job_arrive(t, i, jobs[i].groups.len() as u64, jobs[i].total_tasks());
            }
        }
        self.preempt_all(t);

        let DesRun {
            feed,
            locality,
            num_servers,
            cfg,
            servers,
            spare,
            free_est,
            rebuild,
            progress,
            oset,
            ws,
            outcome,
            overhead,
            wf_evals,
            obs,
            ..
        } = self;
        let jobs: &'a [Job] = feed.slice();
        oset.clear();
        for j in 0..=newest {
            if progress.total_remaining[j] > 0 {
                oset.push(&jobs[j], &progress.remaining[j]);
            }
        }
        let outstanding = oset.as_slice();
        obs.trace
            .reorder_round(t, (newest + 1 - first) as u64, outstanding.len() as u64);
        overhead.measure(|| {
            reorder_into(
                outstanding,
                *num_servers,
                acc,
                cfg.reorder_threads,
                &mut *ws,
                &mut *outcome,
            )
        });
        *wf_evals += outcome.wf_evals;

        for f in free_est.iter_mut() {
            *f = t;
        }
        let mut sink = LaneSink {
            lanes: servers,
            spare,
            feed: &*feed,
            locality: *locality,
            free_est,
            now: t,
        };
        for (pos, &oi) in outcome.order.iter().enumerate() {
            let job_idx = outstanding[oi].job.id;
            debug_assert_eq!(
                outcome.assignments[pos].total_assigned(),
                progress.total_remaining[job_idx]
            );
            rebuild.push_grouped(&mut sink, job_idx, &outcome.assignments[pos].per_group);
        }
        self.arrival_idx = newest + 1;
        self.kick_idle(t);
    }

    /// Preempt every server for a reorder: credit the in-service primary
    /// entries' partial progress, drop every queued entry (all remaining
    /// tasks are about to be reassigned), dissolve every replica set.
    fn preempt_all(&mut self, t: Slots) {
        for m in 0..self.num_servers {
            self.servers[m].token += 1;
            if let Some(run) = self.servers[m].running.take() {
                let elapsed = t - run.start;
                self.busy_work += elapsed;
                // Replicas never contribute progress at a preemption: the
                // primary copy of the same tasks is credited instead (a
                // won race would have retired every member already) —
                // their elapsed slots are burned, not banked.
                if !run.entry.replica {
                    debug_assert!(elapsed < run.dur, "completion events fire before arrivals");
                    if elapsed > 0 {
                        // Latency decomposition: the entry made real
                        // progress from `run.start` — the same rule as
                        // the analytic drain, which only notes starts
                        // for entries that processed at least one slot.
                        self.progress.note_start(run.entry.job, run.start);
                        self.apply_partial(&run.entry, m, elapsed, run.dur);
                    }
                } else {
                    self.wasted_work += elapsed;
                }
                self.obs.trace.preempt(t, run.entry.job, m, elapsed);
                self.recycle(run.entry);
            }
            while let Some(e) = self.servers[m].queue.pop_front() {
                self.recycle(e);
            }
        }
        // Every member entry was just dropped, so the whole slab
        // dissolves; the member lists go back to the spare pool.
        for mut s in self.sets.drain(..) {
            s.members.clear();
            self.member_spare.push(s.members);
        }
        self.set_free.clear();
    }

    /// Credit the whole slots an in-service entry ran before a
    /// preemption. When the entry runs at its deterministic estimate
    /// (`dur == base`, always true in deterministic mode) this is the
    /// analytic drain's partial rule — `elapsed × μ` tasks, parts in
    /// order — bit-compatible with `ServerQueues::drain`. A slowed entry
    /// progresses proportionally (`floor(total × elapsed / dur)`, capped
    /// below `total` so the entry stays alive).
    fn apply_partial(&mut self, entry: &DesEntry, server: ServerId, elapsed: Slots, dur: Slots) {
        let total: TaskCount = entry.parts.iter().map(|&(_, n)| n).sum();
        // The analytic drain's exact rule applies when the entry ran at
        // its deterministic estimate AND every part ran at the local
        // rate (a tier-weighted batch drains fewer than μ tasks/slot).
        let exact = dur == entry.base
            && self
                .locality
                .map_or(true, |l| l.unit_rate(entry.job, &entry.parts, server));
        let mut budget = if exact {
            elapsed * self.feed.job(entry.job).mu[server]
        } else {
            // Proportional credit in u128: the f64 product loses integer
            // precision above 2^53 (the entry_base bug class), crediting
            // a 2^53 + 1 task batch one task short.
            let prop = (total as u128 * elapsed as u128 / dur as u128) as TaskCount;
            prop.min(total.saturating_sub(1))
        };
        debug_assert!(!exact || budget < total);
        for &(k, n) in &entry.parts {
            if budget == 0 {
                break;
            }
            let take = n.min(budget);
            self.progress.remaining[entry.job][k] -= take;
            self.progress.total_remaining[entry.job] -= take;
            if let Some(loc) = self.locality {
                // Preempted progress is completed work: count it toward
                // the tier it actually ran on, so every task is credited
                // exactly once across partial + full applications.
                self.tier_tasks[loc.tier(entry.job, k, server)] += take;
            }
            budget -= take;
        }
    }

    /// A completion event fired. Stale tokens (preempted or cancelled
    /// entries) are ignored; a replica-race winner eagerly cancels every
    /// loser — running losers free their servers at this very slot,
    /// queued losers tombstone in place and are dropped in O(1) when
    /// they surface at their queue head (no queue scan).
    fn on_complete(&mut self, server: ServerId, token: u64) {
        if token != self.servers[server].token {
            return;
        }
        let Some(run) = self.servers[server].running.take() else {
            debug_assert!(false, "valid completion token without a running entry");
            return;
        };
        let t = self.now;
        debug_assert_eq!(run.start + run.dur, t);
        self.busy_work += run.dur;
        let entry = run.entry;
        // Latency decomposition: the completed batch made progress from
        // `run.start` (a winning replica counts — it is the copy whose
        // work the job banks).
        self.progress.note_start(entry.job, run.start);
        if self.obs.trace.on() {
            let tasks: TaskCount = entry.parts.iter().map(|&(_, n)| n).sum();
            self.obs.trace.task_finish(t, entry.job, server, tasks, run.dur);
        }
        debug_assert!(self.freed.is_empty());
        if let Some(p) = entry.set {
            debug_assert!(!self.sets[p as usize].done, "losers are cancelled eagerly");
            self.sets[p as usize].done = true;
            self.obs.trace.replica_win(t, entry.job, server, p as u64);
            // Cancel running losers in fork order (primary first); the
            // slots they burned are the race's wasted work.
            for i in 0..self.sets[p as usize].members.len() {
                let s = self.sets[p as usize].members[i];
                if s == server {
                    continue;
                }
                let running_loser = self.servers[s]
                    .running
                    .as_ref()
                    .map_or(false, |r| r.entry.set == Some(p));
                if running_loser {
                    self.servers[s].token += 1;
                    let r = self.servers[s].running.take().unwrap();
                    let elapsed = t - r.start;
                    self.busy_work += elapsed;
                    self.wasted_work += elapsed;
                    self.obs.trace.replica_lose(t, r.entry.job, s, elapsed, p as u64);
                    self.retire_member(p);
                    self.recycle(r.entry);
                    self.freed.push(s);
                }
            }
            self.retire_member(p);
        }
        self.apply_full(&entry, server, t);
        self.recycle(entry);
        // Targeted kicks: completions are the hot event, and only the
        // completing lane (and the freed race losers' lanes) can have
        // become startable — no full lane rescan.
        self.kick_lane(server, t);
        let mut i = 0;
        while i < self.freed.len() {
            let s = self.freed[i];
            i += 1;
            self.kick_lane(s, t);
        }
        self.freed.clear();
    }

    /// Retire one member of a replica set (completed, cancelled while
    /// running, or dropped at its queue head). The slab slot recycles
    /// only when every member is gone — queued tombstones outlive the
    /// resolution, so their back-indices always reference a live slot.
    fn retire_member(&mut self, p: u32) {
        let set = &mut self.sets[p as usize];
        debug_assert!(set.live > 0);
        set.live -= 1;
        if set.live == 0 {
            let mut members = std::mem::take(&mut set.members);
            members.clear();
            self.member_spare.push(members);
            self.set_free.push(p);
        }
    }

    /// Credit a completed entry's full task batch, mirroring the analytic
    /// drain's whole-entry retirement. `server` is where the batch ran —
    /// the tier the locality telemetry attributes its tasks to.
    fn apply_full(&mut self, entry: &DesEntry, server: ServerId, t: Slots) {
        for &(k, n) in &entry.parts {
            self.progress.remaining[entry.job][k] -= n;
            self.progress.total_remaining[entry.job] -= n;
            if let Some(loc) = self.locality {
                self.tier_tasks[loc.tier(entry.job, k, server)] += n;
            }
        }
        let lf = self.progress.last_finish[entry.job].max(t);
        self.progress.last_finish[entry.job] = lf;
        if self.progress.total_remaining[entry.job] == 0
            && self.progress.completion[entry.job].is_none()
        {
            self.progress.completion[entry.job] = Some(lf);
            if self.obs.trace.on() {
                let arrival = self.feed.job(entry.job).arrival;
                self.obs.trace.job_complete(lf, entry.job, lf - arrival);
            }
            // Streaming eviction: a completed job has no live entries
            // anywhere (every entry holds unapplied tasks), so its
            // payload and per-group progress row can go now.
            if let JobFeed::Stream(sf) = &mut self.feed {
                self.progress.reclaim(entry.job, &mut self.spare_rows);
                sf.retire(entry.job);
            }
        }
    }

    fn recycle(&mut self, mut entry: DesEntry) {
        entry.parts.clear();
        self.spare.push(entry.parts);
    }

    /// Start the head entry on every idle server with queued work — the
    /// admission-path kick, where any lane may have received entries
    /// (admissions are O(num_servers) in the analytic engines too).
    /// Looped because starting a straggler may enqueue replicas on idle
    /// lanes the scan already passed; replicas never re-replicate, so
    /// only the first two passes may start anything and the third must
    /// come up empty. That invariant is load-bearing (it bounds the
    /// admission kick), so it is debug-asserted rather than trusted.
    fn kick_idle(&mut self, t: Slots) {
        let mut passes = 0u32;
        loop {
            passes += 1;
            debug_assert!(
                passes <= 3,
                "kick_idle failed to settle in two starting passes: \
                 a replica re-replicated"
            );
            let mut started = false;
            for m in 0..self.num_servers {
                if self.servers[m].running.is_none() && !self.servers[m].queue.is_empty() {
                    self.start_next(m, t);
                    started |= self.servers[m].running.is_some();
                }
            }
            // Forks' woken lanes are re-found by the next full scan.
            self.woken.clear();
            if !started {
                return;
            }
        }
    }

    /// Start lane `m` if it is idle with queued work, then chase every
    /// lane a start wakes in turn (idle replica targets — one fork can
    /// wake up to K − 1 of them). The completion-path kick: O(woken)
    /// lanes instead of a full rescan.
    fn kick_lane(&mut self, m: ServerId, t: Slots) {
        debug_assert!(self.woken.is_empty());
        if self.servers[m].running.is_none() && !self.servers[m].queue.is_empty() {
            self.start_next(m, t);
        }
        let mut i = 0;
        while i < self.woken.len() {
            let l = self.woken[i];
            i += 1;
            if self.servers[l].running.is_none() && !self.servers[l].queue.is_empty() {
                self.start_next(l, t);
            }
        }
        self.woken.clear();
    }

    /// Pop the head entry of lane `m` (dropping cancelled-race
    /// tombstones in O(1) each), sample its duration, schedule its
    /// completion, and — when the replication budget passes — fork up to
    /// K − 1 racing replicas. Forks that land on *idle* lanes are pushed
    /// onto the `woken` scratch (the caller must kick them).
    fn start_next(&mut self, m: ServerId, t: Slots) {
        loop {
            let Some(mut entry) = self.servers[m].queue.pop_front() else {
                return;
            };
            // A queued race loser: its set resolved while it waited. Drop
            // it here — the entry's back-index makes this O(1), no queue
            // scan at cancellation time — and consume no service draw.
            if let Some(p) = entry.set {
                if self.sets[p as usize].done {
                    self.obs.trace.replica_lose(t, entry.job, m, 0, p as u64);
                    self.retire_member(p);
                    self.recycle(entry);
                    continue;
                }
            }
            let base = entry.base;
            let dur = if self.cfg.service.is_deterministic() {
                base
            } else {
                let f = self.cfg.service.sample_factor(&mut self.service_rng);
                ((base as f64 * f).round() as Slots).max(1)
            };
            let k = self.cfg.effective_replicas();
            if k >= 2 && !entry.replica && entry.set.is_none() && self.budget_passes(dur, base) {
                self.fork_replicas(&mut entry, m, t, k);
            }
            let token = self.servers[m].token;
            self.queue.push(t + dur, EventKind::Complete { server: m, token });
            if self.obs.trace.on() {
                let tasks: TaskCount = entry.parts.iter().map(|&(_, n)| n).sum();
                self.obs.trace.task_start(t, entry.job, m, tasks, dur);
            }
            self.servers[m].running = Some(Running {
                entry,
                start: t,
                dur,
            });
            return;
        }
    }

    /// The replication budget: does this primary's draw earn replicas?
    /// `tail` (the legacy `speculate` gate) forks only when the sampled
    /// duration crosses `speculate ×` the deterministic estimate; `idle`
    /// adds the constraint that targets must be idle (checked per target
    /// in [`Self::replica_target`]); `always` forks unconditionally.
    fn budget_passes(&self, dur: Slots, base: Slots) -> bool {
        match self.cfg.replication_budget {
            crate::des::service::ReplicationBudget::Always => true,
            crate::des::service::ReplicationBudget::Tail
            | crate::des::service::ReplicationBudget::Idle => {
                self.cfg.speculate > 0.0
                    && dur > base
                    && dur as f64 >= self.cfg.speculate * base as f64
            }
        }
    }

    /// Fork up to `k − 1` replicas of `entry` (about to start on lane
    /// `m`), least-loaded eligible lane first; each chosen target's
    /// queue-empty estimate is bumped before the next pick so the
    /// replicas spread. Allocates one replica-set slot iff at least one
    /// target exists; K = 2 reproduces the old one-sibling pair engine
    /// bit for bit (same single target, same estimate bump, same queue
    /// push, same wake signal).
    fn fork_replicas(&mut self, entry: &mut DesEntry, m: ServerId, t: Slots, k: usize) {
        let idle_only =
            self.cfg.replication_budget == crate::des::service::ReplicationBudget::Idle;
        debug_assert!(self.fork_members.is_empty() && self.fork_bases.is_empty());
        self.fork_members.push(m);
        for _ in 1..k {
            let Some(r) = self.replica_target(entry.job, &entry.parts, idle_only) else {
                break;
            };
            let rbase = entry_base(self.feed.job(entry.job), self.locality, entry.job, &entry.parts, r);
            self.free_est[r] = self.free_est[r].max(t) + rbase;
            self.fork_members.push(r);
            self.fork_bases.push(rbase);
        }
        if self.fork_members.len() > 1 {
            let p = self.alloc_set();
            entry.set = Some(p);
            let tasks: TaskCount = if self.obs.trace.on() {
                entry.parts.iter().map(|&(_, n)| n).sum()
            } else {
                0
            };
            for i in 0..self.fork_bases.len() {
                let r = self.fork_members[i + 1];
                let rbase = self.fork_bases[i];
                let mut parts = self.spare.pop().unwrap_or_default();
                parts.extend_from_slice(&entry.parts);
                self.servers[r].queue.push_back(DesEntry {
                    job: entry.job,
                    parts,
                    base: rbase,
                    set: Some(p),
                    replica: true,
                });
                self.obs.trace.replica_fork(t, entry.job, r, tasks, p as u64);
                if self.servers[r].running.is_none() {
                    self.woken.push(r);
                }
            }
        }
        self.fork_members.clear();
        self.fork_bases.clear();
    }

    /// Where the next replica of this entry may race: the least-loaded
    /// server (by queue-empty estimate, ties to the lowest id) that every
    /// part's group allows, excluding the primary and the targets already
    /// chosen (all in `fork_members`). Under the `idle` budget only
    /// strictly idle lanes (nothing running, nothing queued) qualify.
    fn replica_target(
        &self,
        job: usize,
        parts: &[(usize, TaskCount)],
        idle_only: bool,
    ) -> Option<ServerId> {
        let groups = &self.feed.job(job).groups;
        let (k0, _) = parts[0];
        let mut best: Option<(Slots, ServerId)> = None;
        'srv: for &s in &groups[k0].servers {
            if self.fork_members.contains(&s) {
                continue;
            }
            if idle_only && (self.servers[s].running.is_some() || !self.servers[s].queue.is_empty())
            {
                continue;
            }
            for &(k, _) in &parts[1..] {
                if groups[k].servers.binary_search(&s).is_err() {
                    continue 'srv;
                }
            }
            let key = (self.free_est[s], s);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
        best.map(|(_, s)| s)
    }

    /// Allocate a replica-set slot for the lanes in `fork_members` (fork
    /// order, primary first); member lists recycle through the spare
    /// pool so steady-state forks stay allocation-free.
    fn alloc_set(&mut self) -> u32 {
        let mut members = self.member_spare.pop().unwrap_or_default();
        members.clear();
        members.extend_from_slice(&self.fork_members);
        let set = ReplicaSet {
            done: false,
            live: members.len() as u32,
            members,
        };
        if let Some(p) = self.set_free.pop() {
            self.sets[p as usize] = set;
            p
        } else {
            self.sets.push(set);
            (self.sets.len() - 1) as u32
        }
    }
}

/// Expand every group's available-server set to its topology-eligible
/// set at the top tier — the assignment view of the multi-level locality
/// model. The top tier of every preset covers the whole cluster (any
/// server can run any task; non-local execution pays the tier's rate
/// penalty at execution time), but the expansion goes through
/// [`Topology::eligible_within`] so the assigners' view and the charged
/// tiers come from the same table.
fn expand_jobs(jobs: &[Job], topo: &Topology) -> Vec<Job> {
    let top = topo.top_tier();
    jobs.iter()
        .map(|j| Job {
            id: j.id,
            arrival: j.arrival,
            groups: j
                .groups
                .iter()
                .map(|g| {
                    // The pre-expansion available set is the replica-holder
                    // set: affinity-aware assigners (delay, jsq-affinity,
                    // maxweight) read it via `TaskGroup::holders`.
                    TaskGroup::with_local(
                        g.size,
                        topo.eligible_within(&g.servers, top),
                        g.local.clone().unwrap_or_else(|| g.servers.clone()),
                    )
                })
                .collect(),
            mu: j.mu.clone(),
        })
        .collect()
}

/// One-shot DES run of a policy over a job list — the engine behind
/// [`crate::sim::run_policy`] when `SimConfig.engine = des`. `seed`
/// drives RD's tie-breaking (as in the analytic engines) and the service
/// noise stream. Jobs must be sorted by arrival with `id == position`
/// (what [`crate::sim::materialize_jobs`] produces — the same contract
/// as [`crate::sim::ReorderedRun`]).
pub fn run_des(
    jobs: &[Job],
    num_servers: usize,
    policy: SchedPolicy,
    cfg: &SimConfig,
    seed: u64,
) -> crate::Result<SimOutcome> {
    let mut obs = ObsSink::off();
    run_des_obs(jobs, num_servers, policy, cfg, seed, &mut obs)
}

/// [`run_des`] with an observability sink. The sink is taken over for
/// the duration of the run (the consuming [`DesRun`] owns it while it
/// executes) and handed back — populated — through `obs` on success.
pub fn run_des_obs(
    jobs: &[Job],
    num_servers: usize,
    policy: SchedPolicy,
    cfg: &SimConfig,
    seed: u64,
    obs: &mut ObsSink,
) -> crate::Result<SimOutcome> {
    let sink = std::mem::replace(obs, ObsSink::off());
    let result = if cfg.locality_penalty > 1.0 {
        let topo = Topology::build(cfg.topology, num_servers);
        let locality = Locality::new(jobs, &topo, cfg.locality_penalty);
        let expanded = expand_jobs(jobs, &topo);
        let mut run =
            DesRun::with_locality(&expanded, Some(&locality), num_servers, policy, cfg, seed);
        run.attach_obs(sink);
        run.finish_with_obs()
    } else {
        let mut run = DesRun::new(jobs, num_servers, policy, cfg, seed);
        run.attach_obs(sink);
        run.finish_with_obs()
    };
    let (out, sink) = result?;
    *obs = sink;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::service::ServiceModel;
    use super::*;
    use crate::assign::AssignPolicy;
    use crate::sim::{run_fifo, run_reordered};

    fn job(id: usize, arrival: Slots, sizes: &[u64], servers: &[&[usize]], mu: Vec<u64>) -> Job {
        Job {
            id,
            arrival,
            groups: sizes
                .iter()
                .zip(servers)
                .map(|(&s, &sv)| TaskGroup::new(s, sv.to_vec()))
                .collect(),
            mu,
        }
    }

    fn random_jobs(rng: &mut Rng, m: usize, njobs: usize) -> Vec<Job> {
        let mut arrival = 0u64;
        (0..njobs)
            .map(|id| {
                arrival += rng.gen_range(6);
                let k = 1 + rng.gen_range(3) as usize;
                let groups: Vec<TaskGroup> = (0..k)
                    .map(|_| {
                        let ns = 1 + rng.gen_range(m as u64) as usize;
                        let mut sv: Vec<usize> = (0..m).collect();
                        rng.shuffle(&mut sv);
                        sv.truncate(ns);
                        TaskGroup::new(rng.gen_range_incl(1, 25), sv)
                    })
                    .collect();
                Job {
                    id,
                    arrival,
                    groups,
                    mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
                }
            })
            .collect()
    }

    #[test]
    fn deterministic_fifo_matches_analytic_engine() {
        let m = 5;
        let cfg = SimConfig::default();
        let mut rng = Rng::seed_from(0xDE51);
        for case in 0..8 {
            let jobs = random_jobs(&mut rng, m, 2 + case % 7);
            for policy in AssignPolicy::ALL {
                let analytic = run_fifo(&jobs, m, policy, &cfg, 3).unwrap();
                let des =
                    run_des(&jobs, m, SchedPolicy::fifo(policy), &cfg, 3).unwrap();
                assert_eq!(analytic.jcts, des.jcts, "case {case}, {}", policy.name());
                assert_eq!(analytic.makespan, des.makespan, "case {case}, {}", policy.name());
                assert_eq!(
                    analytic.waits,
                    des.waits,
                    "case {case}, {}: FIFO latency decomposition must agree",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn deterministic_reordered_matches_analytic_engine() {
        let m = 5;
        let cfg = SimConfig::default();
        let mut rng = Rng::seed_from(0xDE52);
        for case in 0..8 {
            let jobs = random_jobs(&mut rng, m, 2 + case % 9);
            for acc in [false, true] {
                let analytic = run_reordered(&jobs, m, acc, &cfg).unwrap();
                let des =
                    run_des(&jobs, m, SchedPolicy::ocwf(acc), &cfg, 3).unwrap();
                assert_eq!(analytic.jcts, des.jcts, "case {case}, acc={acc}");
                assert_eq!(analytic.makespan, des.makespan, "case {case}, acc={acc}");
                assert_eq!(analytic.wf_evals, des.wf_evals, "case {case}, acc={acc}");
            }
        }
    }

    #[test]
    fn single_straggler_entry_completes_late() {
        // One job, one server, Pareto service: the JCT must be >= the
        // deterministic figure and bounded by the cap.
        let jobs = vec![job(0, 0, &[10], &[&[0]], vec![2])];
        let mut cfg = SimConfig::default();
        cfg.service = ServiceModel::ParetoTail {
            alpha: 0.8,
            cap: 10.0,
        };
        let out = run_des(&jobs, 1, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 1).unwrap();
        assert_eq!(out.jcts.len(), 1);
        assert!(out.jcts[0] >= 5, "Pareto is a pure slowdown: {:?}", out.jcts);
        assert!(out.jcts[0] <= 50, "cap bounds the tail: {:?}", out.jcts);
    }

    #[test]
    fn replica_race_first_completion_wins() {
        // Two servers, both available to the group. Speculation threshold
        // 1.0 fires on any slowdown; the replica on the idle server races
        // the straggler and the job finishes no later than the straggler
        // alone would.
        let jobs = vec![job(0, 0, &[8], &[&[0, 1]], vec![2, 2])];
        let mut cfg = SimConfig::default();
        cfg.service = ServiceModel::ParetoTail {
            alpha: 0.5,
            cap: 50.0,
        };
        let slow = run_des(&jobs, 2, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 7).unwrap();
        cfg.speculate = 1.5;
        let raced = run_des(&jobs, 2, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 7).unwrap();
        assert_eq!(raced.jcts.len(), 1);
        // Both runs are valid executions; the raced one must still
        // process every task exactly once (completion recorded).
        assert!(raced.makespan >= 1 && slow.makespan >= 1);
    }

    #[test]
    fn unit_penalty_locality_path_matches_no_locality_bitwise() {
        // Satellite regression for the old two-branch entry_base: with
        // every tier at penalty 1.0 the locality path must take the same
        // integer duration path as the no-locality engine — bit-identical
        // outcomes on the *unexpanded* jobs, for every topology preset
        // and both FIFO and reordered policies, across scenario presets.
        use crate::config::ExperimentConfig;
        use crate::sim::materialize_jobs;
        use crate::topology::TopologyKind;
        use crate::trace::scenarios::Scenario;

        let mut cfg = ExperimentConfig::default();
        cfg.seed = 0x10CA;
        cfg.cluster.servers = 12;
        cfg.cluster.avail_lo = 3;
        cfg.cluster.avail_hi = 5;
        cfg.trace.jobs = 12;
        cfg.trace.total_tasks = 500;
        for scenario in Scenario::ALL {
            if scenario.has_engine_twist() {
                continue;
            }
            scenario.apply(&mut cfg);
            let jobs = materialize_jobs(&cfg).unwrap();
            let sim = SimConfig::default();
            for kind in TopologyKind::ALL {
                let topo = Topology::build(kind, cfg.cluster.servers);
                let loc = Locality::new(&jobs, &topo, 1.0);
                for policy in [
                    SchedPolicy::fifo(AssignPolicy::Wf),
                    SchedPolicy::fifo(AssignPolicy::Obta),
                    SchedPolicy::ocwf(true),
                ] {
                    let m = cfg.cluster.servers;
                    let plain = DesRun::new(&jobs, m, policy, &sim, 3).finish().unwrap();
                    let unit = DesRun::with_locality(&jobs, Some(&loc), m, policy, &sim, 3)
                        .finish()
                        .unwrap();
                    assert_eq!(
                        plain.jcts,
                        unit.jcts,
                        "{}/{}/{}: unit-penalty locality must be bit-identical",
                        scenario.name(),
                        kind.name(),
                        policy.name()
                    );
                    assert_eq!(plain.makespan, unit.makespan);
                    assert_eq!(plain.wf_evals, unit.wf_evals);
                    // Telemetry active but everything runs data-local or
                    // same-assignment: the per-tier counts must cover
                    // every task exactly once.
                    let total: u64 = jobs.iter().map(|j| j.total_tasks()).sum();
                    assert_eq!(unit.tier_tasks.iter().sum::<u64>(), total);
                    assert_eq!(unit.tier_tasks.len(), kind.num_tiers());
                    assert!(plain.tier_tasks.is_empty(), "no locality, no telemetry");
                }
            }
        }
    }

    #[test]
    fn entry_base_is_integer_exact_at_unit_penalty() {
        // The f64 path loses integer precision above 2^53: a batch of
        // 2^53 + 1 unit-μ tasks must take 2^53 + 1 slots, not 2^53.
        let n: u64 = (1 << 53) + 1;
        let jobs = vec![job(0, 0, &[n], &[&[0]], vec![1, 1])];
        let topo = Topology::build(crate::topology::TopologyKind::Flat, 2);
        let loc = Locality::new(&jobs, &topo, 1.0);
        let parts = [(0usize, n)];
        let plain = entry_base(&jobs[0], None, 0, &parts, 0);
        assert_eq!(plain, n);
        assert_eq!(entry_base(&jobs[0], Some(&loc), 0, &parts, 0), plain);
        // With a real penalty the weighted f64 path still applies (and
        // only to remote batches): server 1 is remote at penalty 2.
        let loc2 = Locality::new(&jobs, &topo, 2.0);
        assert_eq!(entry_base(&jobs[0], Some(&loc2), 0, &[(0, 10)], 0), 10);
        assert_eq!(entry_base(&jobs[0], Some(&loc2), 0, &[(0, 10)], 1), 20);
    }

    #[test]
    fn multi_rack_tiers_are_charged_and_counted() {
        // 8 servers = 2 racks; data local to server 0 only. Remote
        // same-rack servers run cheaper than cross-rack ones, and the
        // telemetry attributes every task to exactly one tier.
        use crate::topology::TopologyKind;
        let jobs = vec![job(0, 0, &[24], &[&[0]], vec![2; 8])];
        let mut cfg = SimConfig::default();
        cfg.locality_penalty = 3.0;
        cfg.topology = TopologyKind::MultiRack;
        let out = run_des(&jobs, 8, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 1).unwrap();
        assert_eq!(out.jcts.len(), 1);
        assert_eq!(out.tier_tasks.len(), 3);
        assert_eq!(out.tier_tasks.iter().sum::<u64>(), 24);
        // Fully local would take ceil(24/2) = 12 slots; the expanded
        // placement must not be slower than that.
        assert!(out.jcts[0] <= 12, "{:?}", out.jcts);
    }

    #[test]
    fn locality_penalty_slows_remote_execution() {
        // One group local to server 0 only, but the cluster has a second,
        // idle server. With the penalty active the assigners may spread
        // to server 1; tasks there run at mu/penalty, so the optimal
        // split is still correct and every task completes.
        let jobs = vec![job(0, 0, &[12], &[&[0]], vec![3, 3])];
        let mut cfg = SimConfig::default();
        cfg.locality_penalty = 2.0;
        let out = run_des(&jobs, 2, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 1).unwrap();
        assert_eq!(out.jcts.len(), 1);
        // Fully local would take ceil(12/3) = 4 slots; remote-only would
        // take ceil(12*2/3) = 8. Any valid split lands in between.
        assert!(out.jcts[0] >= 2 && out.jcts[0] <= 8, "{:?}", out.jcts);
    }

    #[test]
    fn stochastic_runs_are_seed_reproducible() {
        let m = 4;
        let mut rng = Rng::seed_from(0xDE53);
        let jobs = random_jobs(&mut rng, m, 10);
        let mut cfg = SimConfig::default();
        cfg.service = ServiceModel::ParetoTail {
            alpha: 1.5,
            cap: 20.0,
        };
        cfg.speculate = 2.0;
        for policy in [
            SchedPolicy::fifo(AssignPolicy::Wf),
            SchedPolicy::ocwf(true),
        ] {
            let a = run_des(&jobs, m, policy, &cfg, 11).unwrap();
            let b = run_des(&jobs, m, policy, &cfg, 11).unwrap();
            assert_eq!(a.jcts, b.jcts, "{}", policy.name());
            let c = run_des(&jobs, m, policy, &cfg, 12).unwrap();
            assert!(
                a.jcts != c.jcts || a.makespan == c.makespan,
                "different seeds should usually differ (sanity)"
            );
        }
    }

    #[test]
    fn obs_sink_traces_lifecycle_without_changing_outcomes() {
        let m = 4;
        let mut rng = Rng::seed_from(0xDE54);
        let jobs = random_jobs(&mut rng, m, 6);
        let cfg = SimConfig::default();
        for policy in [SchedPolicy::fifo(AssignPolicy::Wf), SchedPolicy::ocwf(true)] {
            let plain = run_des(&jobs, m, policy, &cfg, 5).unwrap();
            let mut obs = ObsSink::new(4096, true);
            let traced = run_des_obs(&jobs, m, policy, &cfg, 5, &mut obs).unwrap();
            assert_eq!(plain.jcts, traced.jcts, "{}", policy.name());
            assert_eq!(plain.waits, traced.waits, "{}", policy.name());
            assert!(obs.trace.total() > 0, "{}: trace recorded", policy.name());
            use crate::obs::TraceKind;
            let kinds: Vec<TraceKind> =
                obs.trace.iter_in_order().map(|e| e.kind).collect();
            assert!(kinds.contains(&TraceKind::JobArrive));
            assert!(kinds.contains(&TraceKind::TaskStart));
            assert!(kinds.contains(&TraceKind::TaskFinish));
            assert_eq!(
                kinds
                    .iter()
                    .filter(|k| **k == TraceKind::JobComplete)
                    .count(),
                jobs.len(),
                "{}: one completion per job",
                policy.name()
            );
            if policy.is_fifo() {
                assert_eq!(
                    obs.queue_depth.count(),
                    (jobs.len() * m) as u64,
                    "one depth sample per server per arrival"
                );
            } else {
                assert!(kinds.contains(&TraceKind::ReorderRound));
            }
        }
    }

    #[test]
    fn hot_config_returns_sim_error() {
        let jobs = vec![job(0, 0, &[10], &[&[0]], vec![1])];
        let cfg = SimConfig {
            max_slots: 1,
            ..SimConfig::default()
        };
        let err = run_des(&jobs, 1, SchedPolicy::fifo(AssignPolicy::Wf), &cfg, 0).unwrap_err();
        match err {
            crate::Error::Sim(msg) => {
                assert!(msg.contains("des/wf"), "{msg}");
                assert!(msg.contains("max_slots = 1"), "{msg}");
            }
            other => panic!("expected Error::Sim, got {other:?}"),
        }
    }
}
