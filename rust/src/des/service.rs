//! Service-time models and the engine selector.
//!
//! The analytic engines evaluate the paper's deterministic busy-time
//! recursion (eq. 2): a batch of `n` tasks at a `μ`-per-slot server takes
//! exactly `ceil(n/μ)` slots. The DES engine keeps that figure as the
//! *base* duration and lets a [`ServiceModel`] perturb it multiplicatively
//! — the knob that opens the stochastic-service / straggler-tail scenario
//! axis (Wang–Joshi–Wornell's replication analysis lives entirely in this
//! regime).
//!
//! A sampled entry duration is `max(1, round(base × X))` where `X` is the
//! model's slowdown factor:
//!
//! - [`ServiceModel::Deterministic`] — `X = 1` exactly, **no RNG draw**.
//!   This is the invariant mode: with it (and no engine-only mechanisms)
//!   the DES engine reproduces the analytic engines' completion times bit
//!   for bit (`rust/tests/des_equivalence.rs`).
//! - [`ServiceModel::Exp`] — `X ~ Exponential(mean)`: memoryless service
//!   noise, both speedups and slowdowns.
//! - [`ServiceModel::ParetoTail`] — `X ~ min(Pareto(α), cap)`: `X ≥ 1`
//!   always (pure slowdown) with a heavy straggler tail; `cap` bounds the
//!   worst case so runs terminate promptly.

use crate::util::rng::Rng;

/// How entry durations are drawn in the DES engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceModel {
    /// Exact `ceil(n/μ)` durations — the analytic engines' model.
    Deterministic,
    /// Multiplicative exponential noise with the given mean factor.
    Exp { mean: f64 },
    /// Multiplicative Pareto(α) slowdown capped at `cap` (straggler tail).
    ParetoTail { alpha: f64, cap: f64 },
}

impl Default for ServiceModel {
    fn default() -> Self {
        ServiceModel::Deterministic
    }
}

impl ServiceModel {
    pub fn is_deterministic(&self) -> bool {
        matches!(self, ServiceModel::Deterministic)
    }

    /// Parse `det` | `exp:MEAN` | `pareto:ALPHA:CAP` (the config-file and
    /// `--service` syntax).
    pub fn parse(s: &str) -> Option<ServiceModel> {
        let s = s.trim().to_ascii_lowercase();
        if matches!(s.as_str(), "det" | "deterministic") {
            return Some(ServiceModel::Deterministic);
        }
        let mut it = s.split(':');
        match it.next()? {
            "exp" => {
                let mean: f64 = it.next()?.parse().ok()?;
                if it.next().is_some() {
                    return None;
                }
                Some(ServiceModel::Exp { mean })
            }
            "pareto" => {
                let alpha: f64 = it.next()?.parse().ok()?;
                let cap: f64 = it.next()?.parse().ok()?;
                if it.next().is_some() {
                    return None;
                }
                Some(ServiceModel::ParetoTail { alpha, cap })
            }
            _ => None,
        }
    }

    /// Render back into the `parse` syntax (logs, help text).
    pub fn describe(&self) -> String {
        match self {
            ServiceModel::Deterministic => "det".into(),
            ServiceModel::Exp { mean } => format!("exp:{mean}"),
            ServiceModel::ParetoTail { alpha, cap } => format!("pareto:{alpha}:{cap}"),
        }
    }

    /// Parameter sanity; called from `ExperimentConfig::validate`.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ServiceModel::Deterministic => Ok(()),
            ServiceModel::Exp { mean } => {
                if mean.is_finite() && mean > 0.0 {
                    Ok(())
                } else {
                    Err(format!("exp service mean must be finite and > 0, got {mean}"))
                }
            }
            ServiceModel::ParetoTail { alpha, cap } => {
                if !(alpha.is_finite() && alpha > 0.0) {
                    Err(format!("pareto service alpha must be finite and > 0, got {alpha}"))
                } else if !(cap.is_finite() && cap >= 1.0) {
                    Err(format!("pareto service cap must be finite and >= 1, got {cap}"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Draw one slowdown factor. [`ServiceModel::Deterministic`] returns
    /// `1.0` without touching the RNG, so deterministic runs consume zero
    /// service randomness (part of the bit-equivalence contract).
    pub fn sample_factor(&self, rng: &mut Rng) -> f64 {
        match *self {
            ServiceModel::Deterministic => 1.0,
            ServiceModel::Exp { mean } => rng.gen_exp(1.0 / mean),
            ServiceModel::ParetoTail { alpha, cap } => rng.gen_pareto(alpha).min(cap),
        }
    }
}

/// Which execution engine replays a trace: the analytic busy-time
/// recursion ([`crate::sim::run_fifo`] / [`crate::sim::run_reordered`])
/// or the discrete-event engine ([`crate::des`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    #[default]
    Analytic,
    Des,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Analytic => "analytic",
            EngineKind::Des => "des",
        }
    }

    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "analytic" | "analytical" => Some(EngineKind::Analytic),
            "des" | "discrete-event" | "event" => Some(EngineKind::Des),
            _ => None,
        }
    }
}

/// What earns a primary entry its racing replicas (DES engine,
/// `SimConfig::replicas >= 2` or the `speculate` K = 2 alias). The
/// budget makes wasted work a policy choice instead of an accident:
///
/// - [`ReplicationBudget::Tail`] (default, the legacy `speculate`
///   behavior): fork only when the sampled duration crosses
///   `speculate ×` the deterministic estimate — replicate the straggler
///   tail, wherever the targets' queues stand.
/// - [`ReplicationBudget::Idle`]: the tail threshold *and* only strictly
///   idle targets (nothing running, nothing queued) — replicate the tail
///   only when spare capacity exists.
/// - [`ReplicationBudget::Always`]: fork every primary entry regardless
///   of its draw — the full-replication end of the
///   Wang–Joshi–Wornell frontier.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplicationBudget {
    #[default]
    Tail,
    Idle,
    Always,
}

impl ReplicationBudget {
    pub const ALL: [ReplicationBudget; 3] = [
        ReplicationBudget::Tail,
        ReplicationBudget::Idle,
        ReplicationBudget::Always,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ReplicationBudget::Tail => "tail",
            ReplicationBudget::Idle => "idle",
            ReplicationBudget::Always => "always",
        }
    }

    pub fn parse(s: &str) -> Option<ReplicationBudget> {
        match s.trim().to_ascii_lowercase().as_str() {
            "tail" => Some(ReplicationBudget::Tail),
            "idle" => Some(ReplicationBudget::Idle),
            "always" | "all" => Some(ReplicationBudget::Always),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [
            ServiceModel::Deterministic,
            ServiceModel::Exp { mean: 1.5 },
            ServiceModel::ParetoTail {
                alpha: 1.5,
                cap: 20.0,
            },
        ] {
            assert_eq!(ServiceModel::parse(&m.describe()), Some(m));
            m.validate().unwrap();
        }
        assert_eq!(ServiceModel::parse("det"), Some(ServiceModel::Deterministic));
        assert!(ServiceModel::parse("exp").is_none());
        assert!(ServiceModel::parse("exp:1:2").is_none());
        assert!(ServiceModel::parse("pareto:1.5").is_none());
        assert!(ServiceModel::parse("weibull:1").is_none());
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(ServiceModel::Exp { mean: 0.0 }.validate().is_err());
        assert!(ServiceModel::Exp { mean: f64::NAN }.validate().is_err());
        assert!(ServiceModel::ParetoTail {
            alpha: 0.0,
            cap: 10.0
        }
        .validate()
        .is_err());
        assert!(ServiceModel::ParetoTail {
            alpha: 1.5,
            cap: 0.5
        }
        .validate()
        .is_err());
    }

    #[test]
    fn deterministic_consumes_no_randomness() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(1);
        assert_eq!(ServiceModel::Deterministic.sample_factor(&mut a), 1.0);
        assert_eq!(a.next_u64(), b.next_u64(), "no draw may have happened");
    }

    #[test]
    fn pareto_factor_is_a_capped_slowdown() {
        let model = ServiceModel::ParetoTail {
            alpha: 1.2,
            cap: 8.0,
        };
        let mut rng = Rng::seed_from(2);
        let mut above_one = 0;
        for _ in 0..2_000 {
            let f = model.sample_factor(&mut rng);
            assert!((1.0..=8.0).contains(&f), "factor {f}");
            if f > 1.5 {
                above_one += 1;
            }
        }
        assert!(above_one > 100, "the tail must actually bite: {above_one}");
    }

    #[test]
    fn exp_factor_mean_matches() {
        let model = ServiceModel::Exp { mean: 2.0 };
        let mut rng = Rng::seed_from(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| model.sample_factor(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("des"), Some(EngineKind::Des));
        assert_eq!(EngineKind::parse("Analytic"), Some(EngineKind::Analytic));
        assert_eq!(EngineKind::parse("x"), None);
        assert_eq!(EngineKind::default(), EngineKind::Analytic);
        for k in [EngineKind::Analytic, EngineKind::Des] {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn replication_budget_parse() {
        assert_eq!(ReplicationBudget::default(), ReplicationBudget::Tail);
        for b in ReplicationBudget::ALL {
            assert_eq!(ReplicationBudget::parse(b.name()), Some(b));
        }
        assert_eq!(
            ReplicationBudget::parse("ALL"),
            Some(ReplicationBudget::Always)
        );
        assert_eq!(ReplicationBudget::parse("sometimes"), None);
    }
}
