//! Streaming job ingestion for the DES engine.
//!
//! [`JobFeed`] is the engine's view of its workload. The classic mode is
//! a materialized slice (every arrival event pre-pushed, zero overhead
//! over the pre-streaming engine — and the differential oracle for the
//! streaming mode). The streaming mode wraps a
//! [`crate::sim::stream::JobSource`] and keeps only a *window* of job
//! payloads resident: job `i+1` is pulled (and its arrival event pushed)
//! when job `i` is admitted, and a completed job's payload is evicted as
//! the retired prefix of the window advances.
//!
//! ## Why incremental arrival pushes cannot change the schedule
//!
//! The event order is the total key `(time, class, lane, seq)`
//! ([`crate::des::heap`]). `seq` only breaks ties between events with
//! equal `(time, class, lane)` — i.e. completions of the *same server* at
//! the same slot, of which at most one is live (token staleness) — so
//! pushing arrival events lazily instead of up front permutes only `seq`
//! assignments, never the relative order of live events. Arrival `i+1` is
//! always pushed before it can fire: admitting arrival `i` pulls it, and
//! same-slot arrivals order by `lane` (job index), not push order.
//! Streaming runs are therefore bit-identical to materialized runs — the
//! equality `rust/tests/streaming_scale.rs` asserts.
//!
//! Residency: payloads (`groups`, `mu`, per-group progress rows) are
//! O(window); per-job *scalars* (arrival slot, completion, last finish —
//! needed to emit the exact JCT vector) remain O(jobs), a few words each.

use crate::job::{Job, Slots};
use crate::sim::stream::JobSource;
use std::collections::VecDeque;

/// Where [`super::DesRun`] gets its jobs: a materialized slice, or a
/// bounded window over a streaming source.
pub(crate) enum JobFeed<'a> {
    Slice(&'a [Job]),
    Stream(StreamFeed<'a>),
}

/// The streaming window: jobs pulled from the source but not yet retired.
pub(crate) struct StreamFeed<'a> {
    source: Box<dyn JobSource + 'a>,
    /// Resident payloads; `window[0]` is job `base`.
    window: VecDeque<Job>,
    /// Parallel to `window`: completed jobs awaiting prefix eviction.
    retired: VecDeque<bool>,
    base: usize,
    /// Arrival slot of every job pulled so far (O(1) per job; the exact
    /// JCT vector needs it after the payload is gone).
    arrivals: Vec<Slots>,
    done: bool,
    peak_window: usize,
}

impl<'a> StreamFeed<'a> {
    pub(crate) fn new(source: Box<dyn JobSource + 'a>) -> Self {
        StreamFeed {
            source,
            window: VecDeque::new(),
            retired: VecDeque::new(),
            base: 0,
            arrivals: Vec::new(),
            done: false,
            peak_window: 0,
        }
    }

    /// Pull the next job from the source into the window. `None` once the
    /// source is exhausted.
    pub(crate) fn pull(&mut self) -> crate::Result<Option<&Job>> {
        if self.done {
            return Ok(None);
        }
        match self.source.next_job()? {
            None => {
                self.done = true;
                Ok(None)
            }
            Some(job) => {
                debug_assert_eq!(job.id, self.arrivals.len(), "ids are emission order");
                debug_assert!(
                    self.arrivals.last().map_or(true, |&a| job.arrival >= a),
                    "JobSource must yield non-decreasing arrivals"
                );
                self.arrivals.push(job.arrival);
                self.window.push_back(job);
                self.retired.push_back(false);
                self.peak_window = self.peak_window.max(self.window.len());
                Ok(self.window.back())
            }
        }
    }

    /// Mark job `i` complete and evict the retired window prefix.
    pub(crate) fn retire(&mut self, i: usize) {
        self.retired[i - self.base] = true;
        while self.retired.front() == Some(&true) {
            self.retired.pop_front();
            self.window.pop_front();
            self.base += 1;
        }
    }

    pub(crate) fn arrivals(&self) -> &[Slots] {
        &self.arrivals
    }

    /// High-water mark of resident payloads, combined with the source's
    /// own window (the CSV reader's row window).
    pub(crate) fn peak_window(&self) -> usize {
        self.peak_window.max(self.source.peak_window())
    }
}

impl<'a> JobFeed<'a> {
    /// Payload of job `i`. Panics if `i` was evicted — structurally
    /// impossible for the engine, which only touches live jobs.
    #[inline]
    pub(crate) fn job(&self, i: usize) -> &Job {
        match self {
            JobFeed::Slice(jobs) => &jobs[i],
            JobFeed::Stream(sf) => &sf.window[i - sf.base],
        }
    }

    /// The full materialized slice. Streaming feeds have none — the
    /// reordering policies that need one are rejected at construction.
    pub(crate) fn slice(&self) -> &'a [Job] {
        match self {
            JobFeed::Slice(jobs) => jobs,
            JobFeed::Stream(_) => {
                unreachable!("streaming DES runs are FIFO-only (no full job slice exists)")
            }
        }
    }

    /// Jobs known so far (total for slices).
    pub(crate) fn seen(&self) -> usize {
        match self {
            JobFeed::Slice(jobs) => jobs.len(),
            JobFeed::Stream(sf) => sf.arrivals.len(),
        }
    }

    pub(crate) fn peak_window(&self) -> usize {
        match self {
            JobFeed::Slice(_) => 0,
            JobFeed::Stream(sf) => sf.peak_window(),
        }
    }

    /// Reserved capacity of the feed's own buffers (0 for slices, so the
    /// materialized engine's footprint freeze is untouched).
    pub(crate) fn footprint(&self) -> usize {
        match self {
            JobFeed::Slice(_) => 0,
            JobFeed::Stream(sf) => {
                sf.window.capacity() + sf.retired.capacity() + sf.arrivals.capacity()
            }
        }
    }
}
