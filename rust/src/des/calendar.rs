//! The calendar-queue event core: O(1) amortized insert/pop at
//! million-event scale, bit-identical in pop order to [`EventHeap`].
//!
//! A binary heap pays O(log n) per operation with n live events; a
//! calendar queue (Brown 1988) buckets events by time so the steady-state
//! cost per event is O(1) amortized. This implementation is a
//! single-level wheel with an overflow day and a sorted drain run:
//!
//! - **`cur`** — the drain run: every pending event with `time <
//!   cur_end`, kept sorted *descending* by the total event key so `pop`
//!   is a `Vec::pop` from the back. Pushes into the current window (an
//!   arrival admitted at the slot being processed, a 1-slot completion)
//!   binary-insert into place.
//! - **`wheel`** — `NB` buckets of `width` slots each covering
//!   `[wheel_start, wheel_start + NB·width)`. A push beyond the drain
//!   window lands in its bucket unsorted in O(1). When the drain run
//!   empties, the next non-empty bucket is swapped in (capacity-
//!   preserving) and sorted once — each event is sorted exactly once per
//!   residence, and bucket loads are O(1) on DES workloads whose events
//!   cluster near the simulation clock.
//! - **`overflow`** — everything beyond the wheel's horizon. When the
//!   wheel is exhausted the queue *rebases*: the wheel is re-anchored at
//!   the overflow's minimum time with a width sized so the whole
//!   overflow fits one rotation, and the overflow is redistributed. An
//!   event is redistributed at most once per rebase and rebases advance
//!   the horizon past every redistributed event, so the amortized cost
//!   stays O(1) per event for forward-marching (DES) push patterns.
//!
//! ## The order contract
//!
//! Pop order is the **exact total order of [`EventHeap`]** — `(time,
//! class, lane, seq)` with completions before arrivals at a slot and a
//! per-queue monotone push counter breaking the remaining ties. The two
//! cores are interchangeable behind [`EventQueue`]; every JCT vector is
//! bit-identical under either (`rust/tests/streaming_scale.rs` asserts
//! the differential on random streams and whole runs).

use super::heap::{Event, EventHeap, EventKind};
use crate::job::Slots;

/// The common interface of the DES event cores. `pop` must yield the
/// exact `(time, class, lane, seq)` total order documented in
/// [`super::heap`]; `clear` must keep backing capacity; `footprint` is
/// the reserved capacity (allocation-stability tests).
pub trait EventQueue {
    fn push(&mut self, time: Slots, kind: EventKind);
    fn pop(&mut self) -> Option<Event>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);
    fn footprint(&self) -> usize;
}

impl EventQueue for EventHeap {
    fn push(&mut self, time: Slots, kind: EventKind) {
        EventHeap::push(self, time, kind);
    }
    fn pop(&mut self) -> Option<Event> {
        EventHeap::pop(self)
    }
    fn len(&self) -> usize {
        EventHeap::len(self)
    }
    fn clear(&mut self) {
        EventHeap::clear(self);
    }
    fn footprint(&self) -> usize {
        EventHeap::footprint(self)
    }
}

/// Which event core drives a DES run: the binary heap (default, O(log n)
/// per event) or the calendar queue (O(1) amortized, the streaming-scale
/// core). A pure wall-clock knob — pop order is identical.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EventQueueKind {
    #[default]
    Heap,
    Calendar,
}

impl EventQueueKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventQueueKind::Heap => "heap",
            EventQueueKind::Calendar => "calendar",
        }
    }

    pub fn parse(s: &str) -> Option<EventQueueKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "heap" | "binary-heap" => Some(EventQueueKind::Heap),
            "calendar" | "calendar-queue" | "wheel" => Some(EventQueueKind::Calendar),
            _ => None,
        }
    }
}

/// Number of wheel buckets. Power of two, sized so the idle footprint
/// (one `Vec` header per bucket) stays a few KB while typical DES event
/// populations (≤ a few events per server) spread to O(1) per bucket.
const NB: usize = 256;

/// The calendar-queue event core. See the module docs for the layout and
/// [`EventQueue`] for the contract.
#[derive(Clone, Debug)]
pub struct CalendarQueue {
    /// Drain run: events with `time < cur_end`, sorted descending by key.
    cur: Vec<Event>,
    /// Exclusive upper bound of the drain window. Invariant:
    /// `cur_end == wheel_start + day * width`.
    cur_end: Slots,
    /// `wheel[b]` holds events in `[wheel_start + b·width, +width)`,
    /// unsorted. Buckets below `day` are empty (already drained).
    wheel: Vec<Vec<Event>>,
    /// Next bucket to swap into the drain run.
    day: usize,
    wheel_start: Slots,
    width: Slots,
    /// Events at or beyond the wheel horizon, unsorted.
    overflow: Vec<Event>,
    /// Rebase redistribution buffer (capacity is retained).
    scratch: Vec<Event>,
    len: usize,
    seq: u64,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue {
            cur: Vec::new(),
            cur_end: 0,
            wheel: (0..NB).map(|_| Vec::new()).collect(),
            day: 0,
            wheel_start: 0,
            width: 1,
            overflow: Vec::new(),
            scratch: Vec::new(),
            len: 0,
            seq: 0,
        }
    }
}

impl CalendarQueue {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn wheel_end(&self) -> Slots {
        self.wheel_start + NB as Slots * self.width
    }

    /// File an event into its bucket or the overflow. Callers have
    /// already ruled out the drain run (`ev.time >= cur_end`).
    #[inline]
    fn place(&mut self, ev: Event) {
        debug_assert!(ev.time >= self.cur_end);
        if ev.time < self.wheel_end() {
            let b = ((ev.time - self.wheel_start) / self.width) as usize;
            debug_assert!(b >= self.day);
            self.wheel[b].push(ev);
        } else {
            self.overflow.push(ev);
        }
    }

    /// Re-anchor the wheel at the overflow's minimum time with a width
    /// that fits the whole overflow into one rotation, then
    /// redistribute. Only called with the wheel fully drained.
    fn rebase(&mut self) {
        debug_assert!(self.day == NB && !self.overflow.is_empty());
        let mut lo = Slots::MAX;
        let mut hi = 0;
        for e in &self.overflow {
            lo = lo.min(e.time);
            hi = hi.max(e.time);
        }
        debug_assert!(lo >= self.cur_end);
        self.wheel_start = lo;
        self.width = (hi - lo) / NB as Slots + 1;
        self.cur_end = lo;
        self.day = 0;
        // Redistribute via the scratch buffer; the two allocations swap
        // roles so the summed footprint stays frozen.
        std::mem::swap(&mut self.overflow, &mut self.scratch);
        let mut tmp = std::mem::take(&mut self.scratch);
        for ev in tmp.drain(..) {
            self.place(ev);
        }
        self.scratch = tmp;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop every pending event, keeping every backing allocation, and
    /// re-anchor the timeline at slot 0 for the next run.
    pub fn clear(&mut self) {
        self.cur.clear();
        for b in &mut self.wheel {
            b.clear();
        }
        self.overflow.clear();
        self.cur_end = 0;
        self.day = 0;
        self.wheel_start = 0;
        self.width = 1;
        self.len = 0;
    }

    /// Schedule an event. Same stability contract as
    /// [`EventHeap::push`]: equal `(time, class, lane)` fire in push
    /// order.
    pub fn push(&mut self, time: Slots, kind: EventKind) {
        let ev = Event {
            time,
            kind,
            seq: self.seq,
        };
        self.seq += 1;
        self.len += 1;
        if time < self.cur_end {
            // Into the drain run, sorted descending: find the insertion
            // point from the back (new events land near the clock).
            let key = ev.key();
            let pos = self
                .cur
                .partition_point(|e| e.key() > key);
            self.cur.insert(pos, ev);
        } else {
            self.place(ev);
        }
    }

    /// Remove and return the next event in `(time, class, lane, seq)`
    /// order.
    pub fn pop(&mut self) -> Option<Event> {
        loop {
            if let Some(ev) = self.cur.pop() {
                self.len -= 1;
                return Some(ev);
            }
            if self.len == 0 {
                return None;
            }
            if self.day == NB {
                self.rebase();
                continue;
            }
            let b = self.day;
            self.day += 1;
            self.cur_end += self.width;
            if !self.wheel[b].is_empty() {
                std::mem::swap(&mut self.cur, &mut self.wheel[b]);
                // Keys are unique (seq is a total tie-break), so an
                // unstable sort is deterministic.
                self.cur.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
            }
        }
    }

    /// Reserved capacity across the drain run, every wheel bucket, the
    /// overflow and the rebase scratch (allocation-stability tests).
    pub fn footprint(&self) -> usize {
        self.cur.capacity()
            + self.wheel.capacity()
            + self.wheel.iter().map(|b| b.capacity()).sum::<usize>()
            + self.overflow.capacity()
            + self.scratch.capacity()
    }
}

impl EventQueue for CalendarQueue {
    fn push(&mut self, time: Slots, kind: EventKind) {
        CalendarQueue::push(self, time, kind);
    }
    fn pop(&mut self) -> Option<Event> {
        CalendarQueue::pop(self)
    }
    fn len(&self) -> usize {
        CalendarQueue::len(self)
    }
    fn clear(&mut self) {
        CalendarQueue::clear(self);
    }
    fn footprint(&self) -> usize {
        CalendarQueue::footprint(self)
    }
}

/// Runtime-selected event core — the non-generic dispatch [`super::DesRun`]
/// holds, so the engine's type does not go viral over the queue choice.
#[derive(Clone, Debug)]
pub enum AnyEventQueue {
    Heap(EventHeap),
    Calendar(Box<CalendarQueue>),
}

impl AnyEventQueue {
    pub fn new(kind: EventQueueKind) -> Self {
        match kind {
            EventQueueKind::Heap => AnyEventQueue::Heap(EventHeap::new()),
            EventQueueKind::Calendar => AnyEventQueue::Calendar(Box::default()),
        }
    }

    #[inline]
    pub fn push(&mut self, time: Slots, kind: EventKind) {
        match self {
            AnyEventQueue::Heap(q) => q.push(time, kind),
            AnyEventQueue::Calendar(q) => q.push(time, kind),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        match self {
            AnyEventQueue::Heap(q) => q.pop(),
            AnyEventQueue::Calendar(q) => q.pop(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            AnyEventQueue::Heap(q) => q.len(),
            AnyEventQueue::Calendar(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        match self {
            AnyEventQueue::Heap(q) => q.clear(),
            AnyEventQueue::Calendar(q) => q.clear(),
        }
    }

    pub fn footprint(&self) -> usize {
        match self {
            AnyEventQueue::Heap(q) => q.footprint(),
            AnyEventQueue::Calendar(q) => q.footprint(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn lane_of(ev: &Event) -> (u64, u8, u64) {
        match ev.kind {
            EventKind::Complete { server, .. } => (ev.time, 0, server as u64),
            EventKind::Arrival { job } => (ev.time, 1, job as u64),
        }
    }

    #[test]
    fn drains_in_heap_order_on_random_batches() {
        let mut rng = Rng::seed_from(0xCA1);
        for case in 0..20 {
            let mut cal = CalendarQueue::new();
            let mut heap = EventHeap::new();
            let n = 1 + (case * 97) % 700;
            for _ in 0..n {
                // Wide, clustered and tie-heavy times in one mix.
                let t = match rng.gen_range(3) {
                    0 => rng.gen_range(10),
                    1 => rng.gen_range(1_000),
                    _ => 100_000 + rng.gen_range(1_000_000),
                };
                let kind = if rng.gen_range(2) == 0 {
                    EventKind::Complete {
                        server: rng.gen_range(6) as usize,
                        token: rng.gen_range(3),
                    }
                } else {
                    EventKind::Arrival {
                        job: rng.gen_range(9) as usize,
                    }
                };
                cal.push(t, kind);
                heap.push(t, kind);
            }
            assert_eq!(cal.len(), heap.len());
            while let Some(want) = heap.pop() {
                let got = cal.pop().expect("calendar drained early");
                assert_eq!(lane_of(&got), lane_of(&want), "case {case}");
                assert_eq!(got.kind, want.kind, "case {case}");
            }
            assert!(cal.pop().is_none());
        }
    }

    #[test]
    fn interleaved_push_pop_matches_heap() {
        // The DES access pattern: pops interleaved with pushes near (and
        // sometimes exactly at) the current clock, including same-slot
        // class/lane/seq ties and far-future completions that force
        // overflow rebases.
        let mut rng = Rng::seed_from(0xCA2);
        let mut cal = CalendarQueue::new();
        let mut heap = EventHeap::new();
        let mut now = 0u64;
        for step in 0..5_000u64 {
            let burst = 1 + rng.gen_range(3);
            for _ in 0..burst {
                let dt = match rng.gen_range(4) {
                    0 => 0,
                    1 => 1 + rng.gen_range(4),
                    2 => 1 + rng.gen_range(200),
                    _ => 10_000 + rng.gen_range(50_000),
                };
                let kind = if rng.gen_range(2) == 0 {
                    EventKind::Complete {
                        server: rng.gen_range(4) as usize,
                        token: step,
                    }
                } else {
                    EventKind::Arrival {
                        job: rng.gen_range(5) as usize,
                    }
                };
                cal.push(now + dt, kind);
                heap.push(now + dt, kind);
            }
            for _ in 0..rng.gen_range(3) {
                match (cal.pop(), heap.pop()) {
                    (Some(a), Some(b)) => {
                        assert_eq!(lane_of(&a), lane_of(&b), "step {step}");
                        assert_eq!(a.kind, b.kind, "step {step}");
                        assert!(a.time >= now);
                        now = a.time;
                    }
                    (None, None) => {}
                    other => panic!("length divergence at step {step}: {other:?}"),
                }
            }
        }
        while let Some(want) = heap.pop() {
            let got = cal.pop().unwrap();
            assert_eq!(lane_of(&got), lane_of(&want));
        }
        assert!(cal.is_empty());
    }

    #[test]
    fn same_slot_ties_fire_in_class_lane_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(2, EventKind::Arrival { job: 4 });
        q.push(2, EventKind::Arrival { job: 1 });
        q.push(2, EventKind::Complete { server: 9, token: 0 });
        q.push(2, EventKind::Arrival { job: 4 });
        q.push(2, EventKind::Complete { server: 3, token: 7 });
        let order: Vec<(u64, u8, u64)> = (0..5).map(|_| lane_of(&q.pop().unwrap())).collect();
        assert_eq!(
            order,
            vec![(2, 0, 3), (2, 0, 9), (2, 1, 1), (2, 1, 4), (2, 1, 4)]
        );
        // Same (time, class, lane): push order (seq).
        let mut q = CalendarQueue::new();
        for token in [7u64, 8, 9] {
            q.push(1, EventKind::Complete { server: 0, token });
        }
        let tokens: Vec<u64> = (0..3)
            .map(|_| match q.pop().unwrap().kind {
                EventKind::Complete { token, .. } => token,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tokens, vec![7, 8, 9]);
    }

    #[test]
    fn pushes_into_the_open_drain_window_are_ordered() {
        // Pop an event at slot 10, then push same-slot work (what a
        // 0-width completion cascade does): the new event must come out
        // in key order, not at the end.
        let mut q = CalendarQueue::new();
        q.push(10, EventKind::Arrival { job: 2 });
        q.push(50, EventKind::Arrival { job: 3 });
        let first = q.pop().unwrap();
        assert_eq!(first.time, 10);
        q.push(10, EventKind::Complete { server: 0, token: 1 });
        q.push(20, EventKind::Arrival { job: 7 });
        let order: Vec<u64> = (0..3).map(|_| q.pop().unwrap().time).collect();
        assert_eq!(order, vec![10, 20, 50]);
    }

    #[test]
    fn clear_keeps_capacity_and_restarts_the_timeline() {
        let mut q = CalendarQueue::new();
        for t in 0..512u64 {
            q.push(t * 731, EventKind::Arrival { job: t as usize });
        }
        while q.pop().is_some() {}
        let fp = q.footprint();
        assert!(fp > 0);
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.footprint(), fp);
        // A fresh run starting at slot 0 must drain correctly and not
        // grow the pools when refilled to the same depth.
        for t in 0..512u64 {
            q.push(t * 731, EventKind::Arrival { job: t as usize });
        }
        assert_eq!(q.footprint(), fp);
        let mut last = 0;
        while let Some(e) = q.pop() {
            assert!(e.time >= last);
            last = e.time;
        }
    }

    #[test]
    fn steady_state_cycles_freeze_the_footprint() {
        // alloc_stability-style: after a warmup cycle, repeated
        // push/drain waves at the same depth must not allocate.
        let mut q = CalendarQueue::new();
        let mut rng = Rng::seed_from(0xCA3);
        let mut base = 0u64;
        let wave = |q: &mut CalendarQueue, rng: &mut Rng, base: u64| {
            for i in 0..300u64 {
                q.push(
                    base + rng.gen_range(5_000),
                    EventKind::Complete {
                        server: (i % 7) as usize,
                        token: i,
                    },
                );
            }
            while q.pop().is_some() {}
        };
        wave(&mut q, &mut rng, base);
        let fp = q.footprint();
        for _ in 0..20 {
            base += 100_000;
            wave(&mut q, &mut rng, base);
            assert_eq!(q.footprint(), fp, "steady-state wave must not allocate");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [EventQueueKind::Heap, EventQueueKind::Calendar] {
            assert_eq!(EventQueueKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventQueueKind::parse("wheel"), Some(EventQueueKind::Calendar));
        assert_eq!(EventQueueKind::parse("fibonacci"), None);
        assert_eq!(EventQueueKind::default(), EventQueueKind::Heap);
    }

    #[test]
    fn any_event_queue_dispatches_both_cores() {
        for kind in [EventQueueKind::Heap, EventQueueKind::Calendar] {
            let mut q = AnyEventQueue::new(kind);
            assert!(q.is_empty());
            q.push(5, EventKind::Arrival { job: 1 });
            q.push(3, EventKind::Arrival { job: 0 });
            assert_eq!(q.len(), 2);
            assert_eq!(q.pop().unwrap().time, 3, "{}", kind.name());
            q.clear();
            assert!(q.is_empty());
            assert!(q.footprint() > 0 || matches!(kind, EventQueueKind::Heap));
        }
    }
}
