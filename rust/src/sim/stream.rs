//! Streaming job ingestion: million-job runs without materializing the
//! job list.
//!
//! [`materialize_jobs`](crate::sim::materialize_jobs) builds every
//! [`Job`] up front — O(jobs) resident payloads (server sets, μ vectors),
//! which caps runs around 10⁴–10⁵ jobs. This module pulls jobs through a
//! [`JobSource`] one at a time instead:
//!
//! - [`JobStream`] — the production source. Synthetic traces keep the
//!   *compact* trace (arrival + group sizes, ~20× smaller than payloads)
//!   resident and materialize payloads on demand; CSV traces stream
//!   through the windowed reader
//!   ([`crate::trace::csv::CsvWindowReader`]), so nothing is O(jobs) but
//!   a few scalars per job. Both drive the per-job RNG draws in the exact
//!   order of `materialize_jobs` (shared
//!   [`crate::trace::materialize_one`]), so [`JobStream::collect_all`]
//!   reproduces it bit for bit — the differential-oracle contract
//!   `rust/tests/streaming_scale.rs` asserts.
//! - [`run_fifo_stream`] — the analytic FIFO engine as a per-job fold:
//!   O(servers) state, one job resident at a time.
//! - Streaming DES runs go through [`crate::des::DesRun::new_streaming`],
//!   which windows payload residency over the event cascade.
//! - [`StreamStats`] — fixed-footprint summary (Welford + P² quantile
//!   sketches) for `--stream-stats` output, replacing the sort-based
//!   percentile path.
//!
//! Scope: streaming runs are FIFO-policy, unit-locality only. OCWF
//! reorders *every* outstanding job on each arrival and the locality
//! model precomputes per-job tier tables — both need the materialized
//! path, which remains available via [`JobStream::collect_all`].

use crate::assign::{validate_assignment, AssignPolicy};
use crate::cluster::placement::Placement;
use crate::cluster::Cluster;
use crate::config::{ExperimentConfig, SimConfig};
use crate::des::service::EngineKind;
use crate::job::Job;
use crate::sched::SchedPolicy;
use crate::sim::{RunTelemetry, SimOutcome};
use crate::trace::csv::{CsvWindowReader, DEFAULT_LOOKAHEAD};
use crate::trace::{arrival_span, materialize_one, raw_last, Trace, TraceJob};
use crate::util::ceil_div;
use crate::util::rng::Rng;
use crate::util::stats::{P2Quantile, Welford};
use crate::util::timer::OverheadMeter;

/// A source of materialized jobs in arrival order. Contract: emitted jobs
/// carry `id == emission index` and non-decreasing `arrival` slots.
pub trait JobSource {
    fn next_job(&mut self) -> crate::Result<Option<Job>>;
    /// Total job count when known up front (both built-in sources know
    /// it: synthetic from the trace config, CSV from pass 1).
    fn len_hint(&self) -> Option<usize>;
    /// High-water mark of the source's own lookahead window (CSV rows);
    /// 0 for sources with no window.
    fn peak_window(&self) -> usize {
        0
    }
}

enum Provider {
    /// Compact synthetic trace, payloads materialized on demand.
    Synth { trace: Trace, next: usize },
    /// Windowed CSV reader (two passes over the file, O(window) rows).
    Csv(CsvWindowReader),
}

/// The production [`JobSource`]: cluster + placement + RNG state plus a
/// trace provider, materializing one job per pull with the exact RNG
/// sequence of [`crate::sim::materialize_jobs`].
pub struct JobStream {
    provider: Provider,
    cluster: Cluster,
    placement: Placement,
    span: f64,
    raw_last: f64,
    rng: Rng,
    next_id: usize,
    len: usize,
}

impl JobStream {
    /// Open a stream for a config, with the default CSV lookahead window.
    pub fn open(cfg: &ExperimentConfig) -> crate::Result<JobStream> {
        Self::open_with_lookahead(cfg, DEFAULT_LOOKAHEAD)
    }

    /// [`JobStream::open`] with an explicit CSV lookahead bound (raw
    /// trace-time units; ignored for synthetic traces, which arrive
    /// sorted by construction).
    ///
    /// The construction sequence — seed fork, cluster generation, trace
    /// build, placement — mirrors `materialize_jobs` statement for
    /// statement, so the per-job draws that follow line up bit for bit.
    pub fn open_with_lookahead(cfg: &ExperimentConfig, lookahead: f64) -> crate::Result<JobStream> {
        cfg.validate()?;
        let root = Rng::seed_from(cfg.seed);
        let mut rng = root.fork(1);
        let cluster = Cluster::generate(&cfg.cluster, &mut rng);
        // Trace::build consumes RNG only on the synthetic path; the CSV
        // path replaces the batch parse with the windowed reader and
        // leaves the RNG untouched, exactly like `Trace::from_csv_file`.
        let (provider, total_tasks, last_raw, len) = match &cfg.trace.csv_path {
            Some(path) => {
                let (reader, stats) = CsvWindowReader::open(path, lookahead)?;
                (
                    Provider::Csv(reader),
                    stats.total_tasks,
                    Some(stats.raw_last),
                    stats.jobs,
                )
            }
            None => {
                let trace = cfg.trace.scenario.synth(&cfg.trace, &mut rng);
                let total = trace.total_tasks();
                let last = trace.jobs.last().map(|j| j.arrival_raw);
                let len = trace.jobs.len();
                (Provider::Synth { trace, next: 0 }, total, last, len)
            }
        };
        let placement = Placement::with_mode(
            cfg.cluster.servers,
            cfg.cluster.zipf_alpha,
            cfg.cluster.placement_mode,
            &mut rng,
        );
        let span = arrival_span(total_tasks, cfg.trace.utilization, &cluster)?;
        Ok(JobStream {
            provider,
            cluster,
            placement,
            span,
            raw_last: raw_last(last_raw),
            rng,
            next_id: 0,
            len,
        })
    }

    pub fn num_servers(&self) -> usize {
        self.cluster.num_servers()
    }

    /// Drain the stream into a `Vec<Job>` — the collect-all adapter for
    /// small runs and tests; bit-identical to
    /// [`crate::sim::materialize_jobs`] on the same config.
    pub fn collect_all(mut self) -> crate::Result<Vec<Job>> {
        let mut jobs = Vec::with_capacity(self.len);
        while let Some(job) = self.next_job()? {
            jobs.push(job);
        }
        Ok(jobs)
    }
}

impl JobSource for JobStream {
    fn next_job(&mut self) -> crate::Result<Option<Job>> {
        let owned;
        let tj: &TraceJob = match &mut self.provider {
            Provider::Synth { trace, next } => {
                if *next >= trace.jobs.len() {
                    return Ok(None);
                }
                let tj = &trace.jobs[*next];
                *next += 1;
                tj
            }
            Provider::Csv(reader) => match reader.next_trace_job()? {
                Some(tj) => {
                    owned = tj;
                    &owned
                }
                None => return Ok(None),
            },
        };
        let job = materialize_one(
            self.next_id,
            tj,
            &self.cluster,
            &self.placement,
            self.span,
            self.raw_last,
            &mut self.rng,
        );
        self.next_id += 1;
        Ok(Some(job))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.len)
    }

    fn peak_window(&self) -> usize {
        match &self.provider {
            Provider::Synth { .. } => 0,
            Provider::Csv(reader) => reader.peak_window(),
        }
    }
}

/// The analytic FIFO engine ([`crate::sim::run_fifo`]) as a streaming
/// fold: identical per-job arithmetic, one job resident at a time.
pub fn run_fifo_stream(
    source: &mut dyn JobSource,
    num_servers: usize,
    policy: AssignPolicy,
    cfg: &SimConfig,
    seed: u64,
) -> crate::Result<SimOutcome> {
    let mut assigner = policy.build_with(seed, &cfg.assign_params());
    let mut free: Vec<crate::job::Slots> = vec![0; num_servers];
    let mut state = crate::cluster::state::ClusterState::new(num_servers);
    let mut jcts = Vec::with_capacity(source.len_hint().unwrap_or(0));
    let mut waits = Vec::with_capacity(source.len_hint().unwrap_or(0));
    let mut overhead = OverheadMeter::new();
    let mut makespan = 0;
    let mut seen = 0usize;
    let t0 = std::time::Instant::now();

    while let Some(job) = source.next_job()? {
        debug_assert!(job.mu.len() == num_servers);
        seen += 1;
        if cfg.progress_every > 0 && seen as u64 % cfg.progress_every == 0 {
            let secs = t0.elapsed().as_secs_f64();
            let rate = if secs > 0.0 { seen as f64 / secs } else { 0.0 };
            eprintln!(
                "[taos stream] jobs={} rate={:.0} jobs/s peak_window={}",
                seen,
                rate,
                source.peak_window()
            );
        }
        state.observe_free(&free, job.arrival);
        let inst = state.instance(&job.groups, &job.mu);
        let a = overhead.measure(|| assigner.assign(&inst));
        debug_assert_eq!(validate_assignment(&inst, &a), Ok(()));
        let mut completion = job.arrival;
        let mut first_start = crate::job::Slots::MAX;
        for (m, n) in a.per_server() {
            let start = free[m].max(job.arrival);
            first_start = first_start.min(start);
            let fin = start + ceil_div(n, job.mu[m]);
            free[m] = fin;
            completion = completion.max(fin);
        }
        if completion > cfg.max_slots {
            return Err(crate::Error::Sim(format!(
                "fifo/{} run exceeded max_slots = {}: job {} (arrival {}) \
                 would complete at slot {} ({} jobs, {} servers); \
                 utilization config too hot",
                policy.name(),
                cfg.max_slots,
                job.id,
                job.arrival,
                completion,
                seen,
                num_servers
            )));
        }
        jcts.push(completion - job.arrival);
        waits.push(if first_start == crate::job::Slots::MAX {
            0
        } else {
            first_start - job.arrival
        });
        makespan = makespan.max(completion);
    }

    Ok(SimOutcome {
        jcts,
        waits,
        overhead,
        makespan,
        wf_evals: 0,
        oracle_stats: assigner.oracle_stats(),
        tier_tasks: Vec::new(),
        wasted_work: 0,
        busy_work: 0,
        telemetry: RunTelemetry {
            peak_window: source.peak_window().max(1),
            ..RunTelemetry::default()
        },
    })
}

/// One streaming run for a config: [`JobStream`] pulled through the
/// analytic FIFO fold or the streaming DES engine, per `cfg.sim.engine`.
/// Rejects non-FIFO policies and active locality penalties — those need
/// the materialized path.
pub fn run_stream_experiment(
    cfg: &ExperimentConfig,
    policy: SchedPolicy,
) -> crate::Result<SimOutcome> {
    let Some(alg) = policy.fifo_assign() else {
        return Err(crate::Error::Config(
            "streaming runs support FIFO policies only: OCWF reorders every \
             outstanding job and needs the materialized path"
                .into(),
        ));
    };
    let mut stream = JobStream::open(cfg)?;
    let servers = stream.num_servers();
    let seed = cfg.seed ^ 0xA55A;
    match cfg.sim.engine {
        EngineKind::Analytic => run_fifo_stream(&mut stream, servers, alg, &cfg.sim, seed),
        EngineKind::Des => {
            crate::des::DesRun::new_streaming(Box::new(stream), servers, policy, &cfg.sim, seed)?
                .finish()
        }
    }
}

/// Fixed-footprint streaming summary: mean/std via [`Welford`], p50/p90/
/// p99 via [`P2Quantile`] sketches, exact min/max. `Copy`-sized no matter
/// how many samples pass through — the `--stream-stats` output path.
#[derive(Clone, Copy, Debug)]
pub struct StreamStats {
    w: Welford,
    p50: P2Quantile,
    p90: P2Quantile,
    p99: P2Quantile,
    min: f64,
    max: f64,
}

impl Default for StreamStats {
    fn default() -> Self {
        StreamStats {
            w: Welford::default(),
            p50: P2Quantile::new(0.50),
            p90: P2Quantile::new(0.90),
            p99: P2Quantile::new(0.99),
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl StreamStats {
    pub fn push(&mut self, x: f64) {
        self.w.push(x);
        self.p50.push(x);
        self.p90.push(x);
        self.p99.push(x);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_jcts(jcts: &[crate::job::Slots]) -> StreamStats {
        let mut s = StreamStats::default();
        for &j in jcts {
            s.push(j as f64);
        }
        s
    }

    pub fn n(&self) -> u64 {
        self.w.n()
    }
    pub fn mean(&self) -> f64 {
        self.w.mean()
    }
    pub fn std(&self) -> f64 {
        self.w.std()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn p50(&self) -> f64 {
        self.p50.value()
    }
    pub fn p90(&self) -> f64 {
        self.p90.value()
    }
    pub fn p99(&self) -> f64 {
        self.p99.value()
    }
}
