//! A literal slot-by-slot reference engine.
//!
//! [`super::run_fifo`] computes queue-entry finish times analytically
//! (eq. 2 telescopes, so no slot stepping is needed). This module is the
//! *semantic ground truth*: it advances time one slot at a time, each
//! server processing at most `μ_m^h` tasks of its head-of-queue job per
//! slot, never sharing a partial slot between jobs — exactly the model of
//! paper §II. A property test asserts the two engines produce identical
//! completion times on random traces; the analytic engine is what the
//! benches run (it is O(assignments) instead of O(makespan · M)).

use crate::assign::{AssignPolicy, Assigner};
use crate::cluster::state::ClusterState;
use crate::config::SimConfig;
use crate::job::{Job, Slots, TaskCount};
use crate::util::ceil_div;
use crate::util::timer::OverheadMeter;

use super::SimOutcome;

/// One queue entry: `remaining` tasks of `job` at this server, plus the
/// per-slot progress state (tasks already processed within the current
/// "ceil block" — the paper's model charges whole slots per job, so a
/// slot that finishes a job's tasks cannot start the next job's).
#[derive(Clone, Debug)]
struct Entry {
    job: usize,
    remaining: TaskCount,
}

/// Slot-stepping FIFO simulation. Semantically identical to
/// [`super::run_fifo`]; use only for validation (cost O(makespan · M)).
pub fn run_fifo_stepping(
    jobs: &[Job],
    num_servers: usize,
    policy: AssignPolicy,
    cfg: &SimConfig,
    seed: u64,
) -> SimOutcome {
    let mut assigner = policy.build(seed);
    let mut queues: Vec<std::collections::VecDeque<Entry>> =
        vec![Default::default(); num_servers];
    let mut completion: Vec<Option<Slots>> = vec![None; jobs.len()];
    let mut started: Vec<Option<Slots>> = vec![None; jobs.len()];
    let mut remaining_total: Vec<TaskCount> = jobs.iter().map(|j| j.total_tasks()).collect();
    let mut last_finish: Vec<Slots> = jobs.iter().map(|j| j.arrival).collect();
    let mut overhead = OverheadMeter::new();
    let mut state = ClusterState::new(num_servers);

    let mut next_arrival = 0usize;
    let mut now: Slots = 0;
    loop {
        // 1. Admit arrivals at `now`.
        while next_arrival < jobs.len() && jobs[next_arrival].arrival == now {
            let job = &jobs[next_arrival];
            // Busy time per eq. 2: Σ_h ceil(o_m^h / μ_m^h) over queued
            // entries.
            let busy = state.busy_mut();
            for (m, q) in queues.iter().enumerate() {
                busy[m] = q
                    .iter()
                    .map(|e| ceil_div(e.remaining, jobs[e.job].mu[m]))
                    .sum();
            }
            let inst = state.instance(&job.groups, &job.mu);
            let a = overhead.measure(|| assigner.assign(&inst));
            for (m, n) in a.per_server() {
                queues[m].push_back(Entry {
                    job: job.id,
                    remaining: n,
                });
            }
            if job.total_tasks() == 0 {
                completion[job.id] = Some(now);
            }
            next_arrival += 1;
        }

        // 2. Termination.
        let queues_empty = queues.iter().all(|q| q.is_empty());
        if queues_empty && next_arrival >= jobs.len() {
            break;
        }
        assert!(now < cfg.max_slots, "stepping engine exceeded max_slots");

        // 3. Process one slot on every server: μ tasks of the head job;
        // the slot is charged to that job even if it finishes early
        // (integer slots per job, eq. 2).
        for (m, q) in queues.iter_mut().enumerate() {
            if let Some(head) = q.front_mut() {
                let mu = jobs[head.job].mu[m];
                if started[head.job].is_none() {
                    started[head.job] = Some(now);
                }
                let processed = head.remaining.min(mu);
                head.remaining -= processed;
                remaining_total[head.job] -= processed;
                if head.remaining == 0 {
                    let job = head.job;
                    q.pop_front();
                    last_finish[job] = last_finish[job].max(now + 1);
                    if remaining_total[job] == 0 && completion[job].is_none() {
                        completion[job] = Some(last_finish[job]);
                    }
                }
            }
        }
        now += 1;
    }

    let jcts: Vec<Slots> = jobs
        .iter()
        .zip(&completion)
        .map(|(j, c)| c.expect("job must complete") - j.arrival)
        .collect();
    let makespan = completion.iter().map(|c| c.unwrap()).max().unwrap_or(0);
    let waits: Vec<Slots> = jobs
        .iter()
        .zip(&started)
        .map(|(j, s)| s.map_or(0, |t| t.saturating_sub(j.arrival)))
        .collect();
    SimOutcome {
        jcts,
        waits,
        overhead,
        makespan,
        wf_evals: 0,
        oracle_stats: None,
        tier_tasks: Vec::new(),
        wasted_work: 0,
        busy_work: 0,
        telemetry: crate::sim::RunTelemetry::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;
    use crate::proptest::{forall, Config};
    use crate::sim::run_fifo;
    use crate::util::rng::Rng;

    fn random_jobs(rng: &mut Rng, m: usize) -> Vec<Job> {
        let njobs = 1 + rng.gen_range(10) as usize;
        let mut arrival = 0u64;
        (0..njobs)
            .map(|id| {
                arrival += rng.gen_range(8);
                let k = 1 + rng.gen_range(3) as usize;
                let groups: Vec<TaskGroup> = (0..k)
                    .map(|_| {
                        let ns = 1 + rng.gen_range(m as u64) as usize;
                        let mut sv: Vec<usize> = (0..m).collect();
                        rng.shuffle(&mut sv);
                        sv.truncate(ns);
                        TaskGroup::new(rng.gen_range_incl(1, 30), sv)
                    })
                    .collect();
                Job {
                    id,
                    arrival,
                    groups,
                    mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
                }
            })
            .collect()
    }

    #[test]
    fn stepping_single_server_basics() {
        let jobs = vec![Job {
            id: 0,
            arrival: 0,
            groups: vec![TaskGroup::new(10, vec![0])],
            mu: vec![3],
        }];
        let out = run_fifo_stepping(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0);
        assert_eq!(out.jcts, vec![4]);
        assert_eq!(out.makespan, 4);
    }

    #[test]
    fn stepping_charges_whole_slots_per_job() {
        // Job 0: 1 task (μ=3) takes a WHOLE slot; job 1 starts at slot 1.
        let jobs = vec![
            Job {
                id: 0,
                arrival: 0,
                groups: vec![TaskGroup::new(1, vec![0])],
                mu: vec![3],
            },
            Job {
                id: 1,
                arrival: 0,
                groups: vec![TaskGroup::new(3, vec![0])],
                mu: vec![3],
            },
        ];
        let out = run_fifo_stepping(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0);
        assert_eq!(out.jcts, vec![1, 2]);
    }

    #[test]
    fn property_analytic_engine_equals_stepping_engine() {
        // The core semantic claim of the fast simulator: identical
        // completion times on arbitrary traces, for every assigner.
        let m = 4;
        forall(
            Config::default().cases(25).seed(0x57E9),
            |rng| random_jobs(rng, m),
            |jobs| {
                [AssignPolicy::Wf, AssignPolicy::Rd, AssignPolicy::Obta]
                    .into_iter()
                    .all(|p| {
                        let fast = run_fifo(jobs, m, p, &SimConfig::default(), 3).unwrap();
                        let slow =
                            run_fifo_stepping(jobs, m, p, &SimConfig::default(), 3);
                        fast.jcts == slow.jcts && fast.makespan == slow.makespan
                    })
            },
        );
    }
}
