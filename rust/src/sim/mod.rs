//! The slotted discrete-event cluster simulator.
//!
//! Time is divided into identical slots (paper §II). Server `m` processes
//! the job at the head of its queue at `μ_m^h` tasks per slot, and a job's
//! tasks at a server occupy an integer number of slots (`ceil(o/μ)`,
//! eq. 2) — a partial slot is never shared between jobs.
//!
//! Two engines:
//! - [`run_fifo`]: queues are FIFO, so every queue entry's finish time is
//!   determined at assignment time; the engine is *analytic* (no slot
//!   stepping) and exactly equivalent to stepping slot-by-slot.
//! - [`run_reordered`]: OCWF(-ACC) rebuilds all queues on every arrival,
//!   so the engine drains queues between arrivals (also analytically, by
//!   walking entries), tracks per-group remaining tasks, and invokes the
//!   reordering driver of [`crate::sched::ocwf`]. It is a thin driver
//!   over [`ReorderedRun`], the arrival-stepping engine whose pooled
//!   state makes the whole per-arrival path — outstanding-set build,
//!   reorder, queue rebuild — **allocation-free after warmup**
//!   (`rust/tests/alloc_stability.rs` asserts the capacity freeze).
//!
//! A run that exceeds its `max_slots` horizon returns
//! [`crate::Error::Sim`] identifying the offending configuration instead
//! of aborting the process, so one too-hot sweep cell no longer kills the
//! entire sweep (`sweep::run_specs` adds the cell coordinates).
//!
//! Both engines here are *analytic*: they exploit the determinism of
//! eq. 2 to never step between arrivals. The discrete-event engine
//! ([`crate::des`]) replays the same traces through a genuine event loop
//! — [`run_policy`] dispatches to it when `SimConfig.engine = des` — and
//! reproduces these engines bit for bit in its deterministic mode while
//! opening the stochastic-service / straggler-replication /
//! multi-level-locality axes the analytic model cannot express.

pub mod stepping;
pub mod stream;

use crate::assign::{validate_assignment, AssignPolicy, Assigner};
use crate::cluster::state::{ClusterState, JobProgress, QueueRebuild, ServerQueues};
use crate::config::{ExperimentConfig, SimConfig};
use crate::job::{Job, Slots};
use crate::metrics::JctStats;
use crate::obs::ObsSink;
use crate::sched::ocwf::{reorder_into, OutstandingSet, ReorderOutcome, ReorderWorkspace};
use crate::sched::SchedPolicy;
use crate::util::ceil_div;
use crate::util::timer::OverheadMeter;

/// Per-run throughput telemetry (DES engine; zero for the analytic
/// engines, which process no events). The counters are deterministic —
/// events/sec is computed by the caller from wall-clock time and is the
/// only non-reproducible figure derived from them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunTelemetry {
    /// Events popped from the event queue (live + stale).
    pub events: u64,
    /// High-water mark of the event-queue population.
    pub peak_events: usize,
    /// Pooled-buffer footprint at the end of the run (pools only grow,
    /// so this is also the peak).
    pub peak_pool: usize,
    /// High-water mark of resident job payloads in a streaming run
    /// (0 for materialized runs, where residency is simply the job
    /// count) — the O(window) residency claim, observable.
    pub peak_window: usize,
}

/// Result of one simulation run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Per-job completion time in slots (completion − arrival), in job
    /// order.
    pub jcts: Vec<Slots>,
    /// Per-job queueing wait in slots, in job order: the first slot any
    /// of the job's tasks made progress minus the arrival slot. The
    /// remainder of the JCT is service time (`jct = wait + service` by
    /// construction — the latency decomposition; `obs_trace` asserts
    /// conservation).
    pub waits: Vec<Slots>,
    /// Per-arrival computation overhead of the scheduling algorithm.
    pub overhead: OverheadMeter,
    /// Slot at which the last task finished.
    pub makespan: Slots,
    /// Total WF evaluations (reordered runs only; early-exit telemetry).
    pub wf_evals: u64,
    /// Feasibility-oracle tier counters (exact assigners only).
    pub oracle_stats: Option<crate::assign::feasible::OracleStats>,
    /// Tasks completed per locality tier (DES runs with an active
    /// locality penalty only; empty otherwise). Index 0 is data-local,
    /// rising with network distance per [`crate::topology`]; the counts
    /// sum to the trace's total task count — the locality hit-rate
    /// telemetry.
    pub tier_tasks: Vec<u64>,
    /// Slots burned by replica-race losers (DES runs with replication
    /// active; 0 otherwise) — the cost axis of the k-replica frontier.
    pub wasted_work: u64,
    /// Total slots servers spent in service, useful + wasted (DES runs
    /// only; 0 for the analytic engines, which never track per-slot
    /// busy time) — the denominator of the wasted-work fraction.
    pub busy_work: u64,
    /// Event-loop throughput counters (zero for analytic engines).
    pub telemetry: RunTelemetry,
}

impl SimOutcome {
    pub fn jct_stats(&self) -> JctStats {
        JctStats::from_jcts(&self.jcts)
    }

    pub fn mean_jct(&self) -> f64 {
        self.jct_stats().mean
    }

    /// Summary of per-job queueing waits (the delay component of the
    /// latency decomposition).
    pub fn wait_stats(&self) -> JctStats {
        JctStats::from_jcts(&self.waits)
    }

    /// Mean queueing wait in slots (0 when the engine recorded no
    /// waits, e.g. a zero-job run).
    pub fn mean_wait(&self) -> f64 {
        if self.waits.is_empty() {
            0.0
        } else {
            self.waits.iter().sum::<u64>() as f64 / self.waits.len() as f64
        }
    }

    /// Mean service time in slots: `mean_jct − mean_wait` (conservation
    /// holds per job, so it holds for the means).
    pub fn mean_service(&self) -> f64 {
        if self.jcts.is_empty() {
            0.0
        } else {
            let jct = self.jcts.iter().sum::<u64>() as f64 / self.jcts.len() as f64;
            jct - self.mean_wait()
        }
    }

    /// Fraction of total service slots burned by replica-race losers
    /// (`wasted_work / busy_work`; 0 when no server ever ran or the
    /// engine does not track busy time).
    pub fn wasted_fraction(&self) -> f64 {
        if self.busy_work == 0 {
            0.0
        } else {
            self.wasted_work as f64 / self.busy_work as f64
        }
    }
}

/// FIFO simulation (paper §III): assign each arriving job once with the
/// given algorithm; queues drain in arrival order. Returns
/// [`crate::Error::Sim`] when a completion would exceed
/// `cfg.max_slots`.
pub fn run_fifo(
    jobs: &[Job],
    num_servers: usize,
    policy: AssignPolicy,
    cfg: &SimConfig,
    seed: u64,
) -> crate::Result<SimOutcome> {
    let mut obs = ObsSink::off();
    run_fifo_obs(jobs, num_servers, policy, cfg, seed, &mut obs)
}

/// [`run_fifo`] with an observability sink: when the sink's tracer /
/// metrics are enabled, the run emits arrival / assignment / task-span /
/// completion events and samples per-server queue depth at each
/// arrival. The schedule arithmetic is untouched — with
/// [`ObsSink::off`] this *is* `run_fifo`, and with it on the JCT vector
/// is bit-identical (every emission is observation-only).
pub fn run_fifo_obs(
    jobs: &[Job],
    num_servers: usize,
    policy: AssignPolicy,
    cfg: &SimConfig,
    seed: u64,
    obs: &mut ObsSink,
) -> crate::Result<SimOutcome> {
    let mut assigner = policy.build_with(seed, &cfg.assign_params());
    // Absolute slot at which each server's queue empties.
    let mut free: Vec<Slots> = vec![0; num_servers];
    // Busy time at arrival (eq. 2): remaining queue length in slots.
    let mut state = ClusterState::new(num_servers);
    let mut jcts = Vec::with_capacity(jobs.len());
    let mut waits = Vec::with_capacity(jobs.len());
    let mut overhead = OverheadMeter::new();
    let mut makespan = 0;

    for job in jobs {
        debug_assert!(job.mu.len() == num_servers);
        state.observe_free(&free, job.arrival);
        if obs.metrics {
            for &f in &free {
                obs.queue_depth.observe(f.saturating_sub(job.arrival));
            }
        }
        obs.trace.job_arrive(
            job.arrival,
            job.id,
            job.groups.len() as u64,
            job.total_tasks(),
        );
        let inst = state.instance(&job.groups, &job.mu);
        let a = overhead.measure(|| assigner.assign(&inst));
        debug_assert_eq!(validate_assignment(&inst, &a), Ok(()));
        let mut completion = job.arrival;
        let mut first_start = Slots::MAX;
        for (m, n) in a.per_server() {
            let start = free[m].max(job.arrival);
            let fin = start + ceil_div(n, job.mu[m]);
            free[m] = fin;
            completion = completion.max(fin);
            first_start = first_start.min(start);
            obs.trace.assign(job.arrival, job.id, m, n, 0);
            obs.trace.task_start(start, job.id, m, n, fin - start);
        }
        if completion > cfg.max_slots {
            return Err(crate::Error::Sim(format!(
                "fifo/{} run exceeded max_slots = {}: job {} (arrival {}) \
                 would complete at slot {} ({} jobs, {} servers); \
                 utilization config too hot",
                policy.name(),
                cfg.max_slots,
                job.id,
                job.arrival,
                completion,
                jobs.len(),
                num_servers
            )));
        }
        jcts.push(completion - job.arrival);
        waits.push(if first_start == Slots::MAX {
            0
        } else {
            first_start - job.arrival
        });
        obs.trace
            .job_complete(completion, job.id, completion - job.arrival);
        makespan = makespan.max(completion);
    }

    Ok(SimOutcome {
        jcts,
        waits,
        overhead,
        makespan,
        wf_evals: 0,
        oracle_stats: assigner.oracle_stats(),
        tier_tasks: Vec::new(),
        wasted_work: 0,
        busy_work: 0,
        telemetry: RunTelemetry::default(),
    })
}

/// The arrival-stepping OCWF(-ACC) engine (paper §IV): every call to
/// [`ReorderedRun::step`] drains queues up to the next arrival slot, then
/// rebuilds the order and all assignments for that arrival batch.
///
/// All per-arrival state is pooled inside the struct — the reorder
/// workspace/outcome, the [`OutstandingSet`], the [`ServerQueues`] with
/// their recycled entry buffers, and the [`QueueRebuild`] grouping rows —
/// so after a warmup cycle a step performs **zero heap allocations**
/// ([`ReorderedRun::pool_footprint`] exposes the reserved capacity;
/// `rust/tests/alloc_stability.rs` asserts it freezes). This is the
/// production arrival path the paper's computational-overhead results
/// (§V) are about: serving a reorder on every arrival must stay O(small).
pub struct ReorderedRun<'a> {
    jobs: &'a [Job],
    num_servers: usize,
    acc: bool,
    cfg: &'a SimConfig,
    ws: ReorderWorkspace,
    outcome: ReorderOutcome,
    /// Pooled outstanding set: the per-arrival remaining-count copies
    /// recycle their buffers instead of cloning fresh vectors.
    oset: OutstandingSet<'a>,
    queues: ServerQueues,
    rebuild: QueueRebuild,
    progress: JobProgress,
    overhead: OverheadMeter,
    wf_evals: u64,
    now: Slots,
    arrival_idx: usize,
    obs: ObsSink,
}

impl<'a> ReorderedRun<'a> {
    pub fn new(jobs: &'a [Job], num_servers: usize, acc: bool, cfg: &'a SimConfig) -> Self {
        debug_assert!(
            jobs.iter().enumerate().all(|(i, j)| j.id == i),
            "ReorderedRun requires job ids to equal their slice positions"
        );
        let mut ws = ReorderWorkspace::default();
        ws.set_spec_chunk(cfg.acc_spec_chunk);
        ReorderedRun {
            jobs,
            num_servers,
            acc,
            cfg,
            ws,
            outcome: ReorderOutcome::default(),
            oset: OutstandingSet::new(),
            queues: ServerQueues::new(num_servers),
            rebuild: QueueRebuild::new(num_servers),
            progress: JobProgress::new(jobs),
            overhead: OverheadMeter::new(),
            wf_evals: 0,
            now: 0,
            arrival_idx: 0,
            obs: ObsSink::off(),
        }
    }

    /// Attach an observability sink (default: off). The analytic
    /// reordered engine traces arrivals and reorder rounds; task-level
    /// spans need the DES engine, whose event loop sees every start.
    pub fn attach_obs(&mut self, obs: ObsSink) {
        self.obs = obs;
    }

    /// Process the next arrival batch (all jobs arriving at the same
    /// slot): drain queues to the arrival, reorder every outstanding job
    /// (Alg. 3), rebuild the per-server queues in the new order. Returns
    /// `false` once every arrival has been admitted.
    pub fn step(&mut self) -> bool {
        if self.arrival_idx >= self.jobs.len() {
            return false;
        }
        let ReorderedRun {
            jobs,
            num_servers,
            acc,
            cfg,
            ws,
            outcome,
            oset,
            queues,
            rebuild,
            progress,
            overhead,
            wf_evals,
            now,
            arrival_idx,
            obs,
        } = self;
        let jobs: &'a [Job] = *jobs;
        let job = &jobs[*arrival_idx];
        debug_assert!(job.mu.len() == *num_servers);
        // 1. Drain to the arrival slot (analytically, entry by entry).
        queues.drain(jobs, progress, *now, job.arrival);
        *now = job.arrival;

        // Collect every arrival at this exact slot before reordering
        // (reordering once per distinct arrival time is equivalent and
        // cheaper than once per job).
        let mut newest = *arrival_idx;
        while newest + 1 < jobs.len() && jobs[newest + 1].arrival == *now {
            newest += 1;
        }

        for i in *arrival_idx..=newest {
            obs.trace.job_arrive(
                *now,
                jobs[i].id,
                jobs[i].groups.len() as u64,
                jobs[i].total_tasks(),
            );
        }

        // 2. Reorder all outstanding jobs (Alg. 3; busy times start at 0).
        oset.clear();
        for i in 0..=newest {
            if progress.total_remaining[i] > 0 {
                oset.push(&jobs[i], &progress.remaining[i]);
            }
        }
        let outstanding = oset.as_slice();
        obs.trace.reorder_round(
            *now,
            (newest + 1 - *arrival_idx) as u64,
            outstanding.len() as u64,
        );
        // Explicit reborrows: the closure must borrow the pooled
        // workspace/outcome, not consume the destructured references.
        overhead.measure(|| {
            reorder_into(
                outstanding,
                *num_servers,
                *acc,
                cfg.reorder_threads,
                &mut *ws,
                &mut *outcome,
            )
        });
        *wf_evals += outcome.wf_evals;

        // 3. Rebuild queues in the new order, grouping each job's
        // assignment by server through the pooled rebuild rows.
        queues.clear();
        for (pos, &oi) in outcome.order.iter().enumerate() {
            let job_idx = outstanding[oi].job.id;
            let a = &outcome.assignments[pos];
            debug_assert_eq!(a.total_assigned(), progress.total_remaining[job_idx]);
            rebuild.push_grouped(queues, job_idx, &a.per_group);
        }

        *arrival_idx = newest + 1;
        *arrival_idx < jobs.len()
    }

    /// Admit any remaining arrivals, drain the tail of every queue and
    /// produce the outcome. Returns [`crate::Error::Sim`] when jobs are
    /// still unfinished at the `max_slots` horizon.
    pub fn finish(self) -> crate::Result<SimOutcome> {
        self.finish_inner().map(|(out, _)| out)
    }

    /// [`ReorderedRun::finish`] returning the attached [`ObsSink`] as
    /// well, so callers can export the trace / metrics it collected.
    pub fn finish_with_obs(self) -> crate::Result<(SimOutcome, ObsSink)> {
        self.finish_inner()
    }

    fn finish_inner(mut self) -> crate::Result<(SimOutcome, ObsSink)> {
        while self.step() {}
        // 4. Drain everything that remains.
        self.queues
            .drain(self.jobs, &mut self.progress, self.now, self.cfg.max_slots);
        if !self.progress.all_complete() {
            return Err(crate::Error::Sim(format!(
                "ocwf{} run exceeded max_slots = {}: {} of {} jobs unfinished \
                 at the horizon ({} servers, reorder_threads = {}); \
                 utilization config too hot",
                if self.acc { "-acc" } else { "" },
                self.cfg.max_slots,
                self.progress.unfinished(),
                self.jobs.len(),
                self.num_servers,
                self.cfg.reorder_threads
            )));
        }

        let (jcts, makespan) = self.progress.jcts_and_makespan(self.jobs);
        let waits = self.progress.waits(self.jobs);
        Ok((
            SimOutcome {
                jcts,
                waits,
                overhead: self.overhead,
                makespan,
                wf_evals: self.wf_evals,
                oracle_stats: None,
                tier_tasks: Vec::new(),
                wasted_work: 0,
                busy_work: 0,
                telemetry: RunTelemetry::default(),
            },
            self.obs,
        ))
    }

    /// Reserved capacity across every pooled buffer of the arrival path
    /// (allocation-stability tests): reorder workspace + outcome,
    /// outstanding set, server queues (entries + spare pool) and the
    /// queue-rebuild rows.
    pub fn pool_footprint(&self) -> usize {
        self.ws.footprint()
            + self.outcome.footprint()
            + self.oset.footprint()
            + self.queues.footprint()
            + self.rebuild.footprint()
            + self.obs.footprint()
    }
}

/// OCWF / OCWF-ACC simulation (paper §IV): on every arrival, drain queues
/// up to the arrival slot, then rebuild the order and all assignments.
/// The reordering rounds run on `cfg.reorder_threads` workers (1 = the
/// serial reference; the schedule is bit-identical at any thread count,
/// and the thread budget composes with a sweep's worker threads through
/// the executor's admission budget). Thin driver over [`ReorderedRun`].
pub fn run_reordered(
    jobs: &[Job],
    num_servers: usize,
    acc: bool,
    cfg: &SimConfig,
) -> crate::Result<SimOutcome> {
    ReorderedRun::new(jobs, num_servers, acc, cfg).finish()
}

/// Dispatch on a [`SchedPolicy`] and on `cfg.engine`: the analytic
/// engines above, or the discrete-event engine ([`crate::des`]) when the
/// config selects it (`engine = des` / `--engine des`). With
/// deterministic service and no engine-only mechanisms both engines are
/// bit-identical (`rust/tests/des_equivalence.rs`), so the choice is a
/// fidelity knob, not a semantics change.
pub fn run_policy(
    jobs: &[Job],
    num_servers: usize,
    policy: SchedPolicy,
    cfg: &SimConfig,
    seed: u64,
) -> crate::Result<SimOutcome> {
    let mut obs = ObsSink::off();
    run_policy_obs(jobs, num_servers, policy, cfg, seed, &mut obs)
}

/// [`run_policy`] with an observability sink threaded through to the
/// selected engine. The sink is taken over for the duration of the run
/// (the consuming DES / reordered drivers own it while they execute)
/// and handed back — populated — through `obs` on success. Scheduling
/// decisions never read the sink, so outcomes are bit-identical with
/// tracing on or off.
pub fn run_policy_obs(
    jobs: &[Job],
    num_servers: usize,
    policy: SchedPolicy,
    cfg: &SimConfig,
    seed: u64,
    obs: &mut ObsSink,
) -> crate::Result<SimOutcome> {
    if cfg.engine == crate::des::service::EngineKind::Des {
        return crate::des::run_des_obs(jobs, num_servers, policy, cfg, seed, obs);
    }
    match policy.ordering {
        crate::sched::Ordering::Fifo => {
            run_fifo_obs(jobs, num_servers, policy.assign, cfg, seed, obs)
        }
        crate::sched::Ordering::Reorder { acc } => {
            let mut run = ReorderedRun::new(jobs, num_servers, acc, cfg);
            run.attach_obs(std::mem::replace(obs, ObsSink::off()));
            let (out, sink) = run.finish_with_obs()?;
            *obs = sink;
            Ok(out)
        }
    }
}

/// Build cluster + trace + placement from a config and materialize the
/// job list — the deterministic front half of [`run_experiment`], exposed
/// so tests can replay the *same* jobs through several engines (e.g. the
/// analytic FIFO engine against the slot-stepping validator).
pub fn materialize_jobs(cfg: &ExperimentConfig) -> crate::Result<Vec<Job>> {
    use crate::cluster::placement::Placement;
    use crate::cluster::Cluster;
    use crate::trace::Trace;
    use crate::util::rng::Rng;

    cfg.validate()?;
    let root = Rng::seed_from(cfg.seed);
    let mut rng = root.fork(1);
    let cluster = Cluster::generate(&cfg.cluster, &mut rng);
    let trace = Trace::build(&cfg.trace, &mut rng)?;
    let placement = Placement::with_mode(
        cfg.cluster.servers,
        cfg.cluster.zipf_alpha,
        cfg.cluster.placement_mode,
        &mut rng,
    );
    trace.materialize(&cluster, &placement, cfg.trace.utilization, &mut rng)
}

/// Convenience: build cluster + trace from a config and run one policy.
pub fn run_experiment(cfg: &ExperimentConfig, policy: SchedPolicy) -> crate::Result<SimOutcome> {
    let jobs = materialize_jobs(cfg)?;
    run_policy(
        &jobs,
        cfg.cluster.servers,
        policy,
        &cfg.sim,
        cfg.seed ^ 0xA55A,
    )
}

/// [`run_experiment`] with an observability sink (see
/// [`run_policy_obs`]).
pub fn run_experiment_obs(
    cfg: &ExperimentConfig,
    policy: SchedPolicy,
    obs: &mut ObsSink,
) -> crate::Result<SimOutcome> {
    let jobs = materialize_jobs(cfg)?;
    run_policy_obs(
        &jobs,
        cfg.cluster.servers,
        policy,
        &cfg.sim,
        cfg.seed ^ 0xA55A,
        obs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;

    fn job(id: usize, arrival: Slots, sizes: &[u64], servers: &[&[usize]], mu: Vec<u64>) -> Job {
        Job {
            id,
            arrival,
            groups: sizes
                .iter()
                .zip(servers)
                .map(|(&s, &sv)| TaskGroup::new(s, sv.to_vec()))
                .collect(),
            mu,
        }
    }

    #[test]
    fn fifo_single_job_single_server() {
        let jobs = vec![job(0, 0, &[10], &[&[0]], vec![3])];
        let out = run_fifo(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        assert_eq!(out.jcts, vec![4]); // ceil(10/3)
        assert_eq!(out.makespan, 4);
    }

    #[test]
    fn fifo_queueing_delay_accumulates() {
        // Two identical jobs on one server, back to back.
        let jobs = vec![
            job(0, 0, &[4], &[&[0]], vec![1]),
            job(1, 1, &[4], &[&[0]], vec![1]),
        ];
        let out = run_fifo(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        // Job 0: 0→4 (JCT 4). Job 1 arrives at 1, waits 3, runs 4 → JCT 7.
        assert_eq!(out.jcts, vec![4, 7]);
        // Latency decomposition: job 0 starts immediately (wait 0), job 1
        // waits behind it until slot 4 (wait 3); service = jct − wait.
        assert_eq!(out.waits, vec![0, 3]);
        assert!((out.mean_wait() - 1.5).abs() < 1e-12);
        assert!((out.mean_service() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fifo_obs_does_not_change_outcomes_and_traces_lifecycle() {
        use crate::obs::{ObsSink, TraceKind};
        let jobs = vec![
            job(0, 0, &[4], &[&[0]], vec![1]),
            job(1, 1, &[4], &[&[0]], vec![1]),
        ];
        let plain = run_fifo(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        let mut obs = ObsSink::new(64, true);
        let traced =
            run_fifo_obs(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0, &mut obs).unwrap();
        assert_eq!(plain.jcts, traced.jcts, "tracing must not move the schedule");
        assert_eq!(plain.waits, traced.waits);
        // 2 jobs × (arrive + assign + start + complete) = 8 events.
        assert_eq!(obs.trace.total(), 8);
        let kinds: Vec<TraceKind> = obs.trace.iter_in_order().map(|e| e.kind).collect();
        assert_eq!(kinds[0], TraceKind::JobArrive);
        assert_eq!(*kinds.last().unwrap(), TraceKind::JobComplete);
        // Queue depth sampled once per server per arrival.
        assert_eq!(obs.queue_depth.count(), 2);
    }

    #[test]
    fn fifo_idle_gap_resets_busy() {
        let jobs = vec![
            job(0, 0, &[2], &[&[0]], vec![1]),
            job(1, 10, &[2], &[&[0]], vec![1]),
        ];
        let out = run_fifo(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        assert_eq!(out.jcts, vec![2, 2]);
        assert_eq!(out.makespan, 12);
    }

    #[test]
    fn fifo_all_assigners_agree_on_single_server() {
        let jobs = vec![
            job(0, 0, &[7], &[&[0]], vec![2]),
            job(1, 2, &[5], &[&[0]], vec![2]),
        ];
        for p in AssignPolicy::ALL {
            let out = run_fifo(&jobs, 1, p, &SimConfig::default(), 0).unwrap();
            assert_eq!(out.jcts, vec![4, 2 + 3 + 2 - 2 /* wait + run */], "{}", p.name());
        }
    }

    #[test]
    fn fifo_hot_config_returns_sim_error() {
        // A horizon of 1 slot cannot fit a 10-task job: the run must
        // surface an Error::Sim naming the config, not abort the process.
        let jobs = vec![job(0, 0, &[10], &[&[0]], vec![1])];
        let cfg = SimConfig {
            max_slots: 1,
            ..SimConfig::default()
        };
        let err = run_fifo(&jobs, 1, AssignPolicy::Wf, &cfg, 0).unwrap_err();
        match err {
            crate::Error::Sim(msg) => {
                assert!(msg.contains("max_slots = 1"), "{msg}");
                assert!(msg.contains("wf"), "{msg}");
            }
            other => panic!("expected Error::Sim, got {other:?}"),
        }
    }

    #[test]
    fn reordered_hot_config_returns_sim_error() {
        let jobs = vec![job(0, 0, &[10], &[&[0]], vec![1])];
        let cfg = SimConfig {
            max_slots: 1,
            ..SimConfig::default()
        };
        let err = run_reordered(&jobs, 1, true, &cfg).unwrap_err();
        match err {
            crate::Error::Sim(msg) => {
                assert!(msg.contains("ocwf-acc"), "{msg}");
                assert!(msg.contains("max_slots = 1"), "{msg}");
            }
            other => panic!("expected Error::Sim, got {other:?}"),
        }
    }

    #[test]
    fn reordered_prioritizes_short_job() {
        // Long job arrives at 0 on server 0; short job arrives at 1.
        // FIFO: short job waits behind the long one. OCWF: the short job
        // jumps the queue (its remaining time is smaller).
        let jobs = vec![
            job(0, 0, &[100], &[&[0]], vec![1]),
            job(1, 1, &[2], &[&[0]], vec![1]),
        ];
        let fifo = run_fifo(&jobs, 1, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        let re = run_reordered(&jobs, 1, false, &SimConfig::default()).unwrap();
        // FIFO: job 1 completes at 102 → JCT 101.
        assert_eq!(fifo.jcts, vec![100, 101]);
        // OCWF: at t=1 job 1 (2 tasks) goes first: completes at 3 (JCT 2);
        // job 0 (99 left) completes at 102 → JCT 102.
        assert_eq!(re.jcts, vec![102, 2]);
        // Mean JCT improves.
        assert!(re.mean_jct() < fifo.mean_jct());
    }

    #[test]
    fn reordered_acc_matches_plain() {
        use crate::util::rng::Rng;
        let m = 5;
        let mut rng = Rng::seed_from(400);
        for _ in 0..10 {
            let njobs = 2 + rng.gen_range(8) as usize;
            let mut arrival = 0u64;
            let jobs: Vec<Job> = (0..njobs)
                .map(|id| {
                    arrival += rng.gen_range(6);
                    let k = 1 + rng.gen_range(3) as usize;
                    let groups: Vec<TaskGroup> = (0..k)
                        .map(|_| {
                            let ns = 1 + rng.gen_range(m as u64) as usize;
                            let mut sv: Vec<usize> = (0..m).collect();
                            rng.shuffle(&mut sv);
                            sv.truncate(ns);
                            TaskGroup::new(rng.gen_range_incl(1, 25), sv)
                        })
                        .collect();
                    Job {
                        id,
                        arrival,
                        groups,
                        mu: (0..m).map(|_| rng.gen_range_incl(1, 4)).collect(),
                    }
                })
                .collect();
            let plain = run_reordered(&jobs, m, false, &SimConfig::default()).unwrap();
            let accd = run_reordered(&jobs, m, true, &SimConfig::default()).unwrap();
            assert_eq!(plain.jcts, accd.jcts, "OCWF and OCWF-ACC must coincide");
            assert!(accd.wf_evals <= plain.wf_evals);
        }
    }

    #[test]
    fn reordered_single_job_matches_fifo_wf() {
        let jobs = vec![job(0, 0, &[12], &[&[0, 1, 2]], vec![2, 2, 2])];
        let fifo = run_fifo(&jobs, 3, AssignPolicy::Wf, &SimConfig::default(), 0).unwrap();
        let re = run_reordered(&jobs, 3, true, &SimConfig::default()).unwrap();
        assert_eq!(fifo.jcts, re.jcts);
    }

    #[test]
    fn stepping_api_matches_one_shot_driver() {
        // Driving ReorderedRun arrival by arrival must equal the one-shot
        // run_reordered wrapper exactly.
        let jobs = vec![
            job(0, 0, &[9, 4], &[&[0, 1], &[1, 2]], vec![2, 1, 2]),
            job(1, 2, &[6], &[&[0, 2]], vec![2, 1, 2]),
            job(2, 2, &[3], &[&[1]], vec![2, 1, 2]),
            job(3, 9, &[5], &[&[0, 1, 2]], vec![2, 1, 2]),
        ];
        let cfg = SimConfig::default();
        let reference = run_reordered(&jobs, 3, true, &cfg).unwrap();
        let mut run = ReorderedRun::new(&jobs, 3, true, &cfg);
        let mut steps = 0;
        while run.step() {
            steps += 1;
        }
        // 3 distinct arrival slots (0, 2, 9): step returns true while more
        // arrivals remain, so the loop body runs per batch.
        assert_eq!(steps, 2);
        let out = run.finish().unwrap();
        assert_eq!(reference.jcts, out.jcts);
        assert_eq!(reference.makespan, out.makespan);
        assert_eq!(reference.wf_evals, out.wf_evals);
    }

    #[test]
    fn conservation_all_tasks_processed() {
        use crate::util::rng::Rng;
        let m = 4;
        let mut rng = Rng::seed_from(401);
        let jobs: Vec<Job> = (0..12)
            .map(|id| {
                let groups = vec![TaskGroup::new(
                    rng.gen_range_incl(1, 30),
                    (0..m).collect::<Vec<_>>(),
                )];
                Job {
                    id,
                    arrival: id as u64 * 2,
                    groups,
                    mu: (0..m).map(|_| rng.gen_range_incl(1, 3)).collect(),
                }
            })
            .collect();
        for policy in SchedPolicy::ALL {
            let out = run_policy(&jobs, m, policy, &SimConfig::default(), 1).unwrap();
            assert_eq!(out.jcts.len(), jobs.len(), "{}", policy.name());
            assert!(out.jcts.iter().all(|&j| j >= 1), "{}", policy.name());
        }
    }

    #[test]
    fn run_experiment_end_to_end_smoke() {
        let mut cfg = ExperimentConfig::default();
        cfg.trace.jobs = 15;
        cfg.trace.total_tasks = 600;
        cfg.cluster.servers = 20;
        cfg.cluster.avail_lo = 3;
        cfg.cluster.avail_hi = 6;
        let out = run_experiment(&cfg, SchedPolicy::fifo(AssignPolicy::Wf)).unwrap();
        assert_eq!(out.jcts.len(), 15);
        let out2 = run_experiment(&cfg, SchedPolicy::ocwf(true)).unwrap();
        assert_eq!(out2.jcts.len(), 15);
    }
}
