//! `taos` — the command-line launcher.
//!
//! Subcommands:
//! - `simulate`   run one scheduling policy over a (synthetic or CSV)
//!                trace and print JCT statistics + overhead.
//! - `repro`      regenerate a paper table/figure (10, 11, 12, 13, 14,
//!                `table1`, the `scenarios` catalog sweep, the `topology`
//!                locality-penalty sweep, the `replication` k-replica
//!                frontier, or the `baselines` load sweep over the
//!                extended policy panel); fans the (policy × setting ×
//!                trial) cells across `--threads` worker threads with
//!                bit-identical results. `--policies` narrows or extends
//!                the panel.
//! - `compare`    run the policy panel on one setting side by side.
//! - `gen-trace`  emit a synthetic Alibaba-like trace as batch_task.csv.
//! - `live`       run the live coordinator (leader/workers + PJRT
//!                payload kernel) on a small workload; needs artifacts
//!                and a binary built with `--features pjrt`.
//! - `verify-kernel`  cross-check the AOT water-filling kernel against
//!                the native rust WF on random instances; needs artifacts
//!                and a binary built with `--features pjrt`.

use taos::assign::AssignPolicy;
use taos::cli::{flag, flag_req, switch, Cli};
use taos::config::ExperimentConfig;
use taos::sched::SchedPolicy;
use taos::sim::run_experiment;
use taos::sweep;
use taos::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = build_cli();
    let parsed = match cli.parse(&args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if msg.starts_with("taos") { 0 } else { 2 });
        }
    };
    let result = match parsed.subcommand.as_str() {
        "simulate" => cmd_simulate(&parsed),
        "repro" => cmd_repro(&parsed),
        "compare" => cmd_compare(&parsed),
        "gen-trace" => cmd_gen_trace(&parsed),
        "live" => cmd_live(&parsed),
        "verify-kernel" => cmd_verify_kernel(&parsed),
        other => Err(format!("unhandled subcommand {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn build_cli() -> Cli {
    // No defaults here: unset flags fall through to the config file (or
    // the paper defaults in `ExperimentConfig::default()`).
    let common = || {
        vec![
            flag_req("servers", "number of servers M [default 100]"),
            flag_req("alpha", "Zipf skew for data placement [default 0]"),
            flag_req("util", "target system utilization [default 0.5]"),
            flag_req("jobs", "number of jobs [default 250]"),
            flag_req("tasks", "total tasks across jobs [default 113653]"),
            flag_req("avail", "available servers per group, lo:hi [default 8:12]"),
            flag_req("mu", "per-server capacity range, lo:hi [default 3:5]"),
            flag_req("seed", "master RNG seed [default 42]"),
            flag_req("csv", "path to a batch_task.csv trace (overrides synth)"),
            flag_req("config", "config file (key = value lines)"),
            flag_req(
                "scenario",
                "named workload: alibaba | bursty | heavy-tail | hetero-cap | hotspot | \
                 bursty-hetero | hotspot-heavy-tail | straggler | k-replica | \
                 multi-locality | multi-rack | multi-zone",
            ),
            flag_req(
                "reorder-threads",
                "worker threads for OCWF reorder rounds (0 = all cores; composes \
                 with a sweep's --threads via the shared pool budget) [default 1]",
            ),
            flag_req(
                "acc-spec-chunk",
                "fixed OCWF-ACC speculation depth (0 = adaptive) [default 0]",
            ),
            flag_req(
                "engine",
                "execution engine: analytic | des (deterministic DES is \
                 bit-identical to analytic) [default analytic]",
            ),
            flag_req(
                "service",
                "DES service-time model: det | exp:MEAN | pareto:ALPHA:CAP \
                 [default det]",
            ),
            flag_req(
                "locality-penalty",
                "DES multi-level locality: remote tasks run at mu/penalty \
                 (1 = off; needs --engine des) [default 1]",
            ),
            flag_req(
                "topology",
                "network topology for locality tiers: flat | multi-rack | \
                 multi-zone | fat-tree (non-flat needs --engine des) \
                 [default flat]",
            ),
            flag_req(
                "speculate",
                "DES straggler speculation threshold factor (0 = off; needs \
                 --engine des) [default 0]",
            ),
            flag_req(
                "replicas",
                "DES replica-set size K (0 = derive from --speculate: 2 when \
                 armed, else off; 1 = racing off; needs --engine des for \
                 K >= 2) [default 0]",
            ),
            flag_req(
                "replication-budget",
                "what earns an entry its racing replicas: tail | idle | \
                 always (tail = the --speculate threshold; needs --engine \
                 des for non-tail) [default tail]",
            ),
            flag_req(
                "event-queue",
                "DES event core: heap | calendar (bit-identical pop order; \
                 calendar is O(1) amortized at streaming scale; needs \
                 --engine des) [default heap]",
            ),
            flag_req(
                "delay-bound",
                "delay-scheduling bound D in slots: a chunk stays on a \
                 replica holder while its estimated queue is <= D (only \
                 the `delay` policy reads it) [default 2]",
            ),
        ]
    };
    Cli::new("taos", "data-locality-aware task assignment & scheduling")
        .subcommand("simulate", "run one policy over a trace", {
            let mut f = common();
            f.push(flag(
                "alg",
                "nlip | obta | wf | rd | ocwf | ocwf-acc | jsq | jsq-affinity | \
                 delay | maxweight",
                "wf",
            ));
            f.push(switch("json", "emit JSON instead of text"));
            f.push(switch(
                "stream-stats",
                "stream jobs through the run with O(window) memory and report \
                 P\u{b2}-sketch percentiles + throughput telemetry (FIFO only)",
            ));
            f.push(flag_req(
                "trace-out",
                "write a decision trace of the run: Chrome trace-event JSON \
                 (load in Perfetto / chrome://tracing), or JSONL when the \
                 path ends in .jsonl (off by default; not with --stream-stats)",
            ));
            f.push(flag(
                "trace-limit",
                "decision-trace ring capacity in events; when full, the \
                 oldest events are dropped",
                "1000000",
            ));
            f.push(flag_req(
                "metrics-out",
                "write the run's metrics registry: JSON, or Prometheus text \
                 exposition when the path ends in .prom",
            ));
            f.push(flag(
                "progress",
                "heartbeat to stderr every N DES events / streamed jobs \
                 (0 = off; stdout stays byte-identical)",
                "0",
            ));
            f
        })
        .subcommand("compare", "run the policy panel on one setting", {
            let mut f = common();
            f.push(flag_req(
                "policies",
                "comma-separated policy panel, e.g. obta,wf,jsq (see the \
                 README policy table) [default: the paper's six]",
            ));
            f.push(switch("json", "emit JSON instead of text"));
            f
        })
        .subcommand("repro", "regenerate a paper table/figure", {
            let mut f = common();
            f.push(flag(
                "fig",
                "10 | 11 | 12 | 13 | 14 | table1 | scenarios | topology | \
                 replication | baselines",
                "12",
            ));
            f.push(flag_req(
                "policies",
                "comma-separated policy panel for the sweep [default: the \
                 paper's six; `baselines` defaults to the full ten]",
            ));
            f.push(switch("quick", "scaled-down workload for fast runs"));
            f.push(flag("out", "also write JSON to this path", ""));
            f.push(flag("threads", "sweep worker threads (0 = all cores)", "1"));
            f.push(flag("trials", "independent trials per cell, averaged", "1"));
            f
        })
        .subcommand(
            "gen-trace",
            "emit a synthetic trace in batch_task.csv schema",
            vec![
                flag("jobs", "number of jobs", "250"),
                flag("tasks", "total tasks", "113653"),
                flag("seed", "RNG seed", "42"),
                flag("out", "output path", "trace.csv"),
                flag("scenario", "workload shape (alibaba | bursty | heavy-tail | ...)", "alibaba"),
            ],
        )
        .subcommand(
            "live",
            "run the live coordinator on a small workload (needs artifacts)",
            vec![
                flag("servers", "number of worker servers", "4"),
                flag("jobs", "number of jobs", "8"),
                flag("tasks-per-job", "tasks per job", "32"),
                flag("replicas", "chunk replication factor", "3"),
                flag("alg", "assignment algorithm", "wf"),
                flag("artifacts", "artifacts directory", "artifacts"),
            ],
        )
        .subcommand(
            "verify-kernel",
            "cross-check AOT wf kernel vs native WF (needs artifacts)",
            vec![
                flag("artifacts", "artifacts directory", "artifacts"),
                flag("cases", "random instances to check", "64"),
                flag("seed", "RNG seed", "7"),
            ],
        )
}

fn parse_range(s: &str) -> Result<(u64, u64), String> {
    let (lo, hi) = s
        .split_once(':')
        .ok_or_else(|| format!("expected lo:hi, got `{s}`"))?;
    Ok((
        lo.parse().map_err(|_| format!("bad lo `{lo}`"))?,
        hi.parse().map_err(|_| format!("bad hi `{hi}`"))?,
    ))
}

fn config_from(parsed: &taos::cli::Parsed) -> Result<ExperimentConfig, String> {
    let mut cfg = match parsed.get("config") {
        Some(path) if !path.is_empty() => {
            ExperimentConfig::from_file(path).map_err(|e| e.to_string())?
        }
        _ => ExperimentConfig::default(),
    };
    // Scenario before the explicit flags: apply() sets the scenario's
    // characteristic knobs unconditionally, so flag overrides below
    // (e.g. `--scenario hotspot --alpha 0`) always win.
    if let Some(s) = parsed.get("scenario") {
        let sc = taos::trace::scenarios::Scenario::parse(s)
            .ok_or_else(|| format!("unknown scenario `{s}`"))?;
        sc.apply(&mut cfg);
    }
    if let Some(v) = parsed.get_parse::<usize>("servers")? {
        cfg.cluster.servers = v;
    }
    if let Some(v) = parsed.get_parse::<f64>("alpha")? {
        cfg.cluster.zipf_alpha = v;
    }
    if let Some(v) = parsed.get_parse::<f64>("util")? {
        cfg.trace.utilization = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("jobs")? {
        cfg.trace.jobs = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("tasks")? {
        cfg.trace.total_tasks = v;
    }
    if let Some(v) = parsed.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(s) = parsed.get("avail") {
        let (lo, hi) = parse_range(s)?;
        cfg.cluster.avail_lo = lo as usize;
        cfg.cluster.avail_hi = hi as usize;
    }
    if let Some(s) = parsed.get("mu") {
        let (lo, hi) = parse_range(s)?;
        cfg.cluster.mu_lo = lo;
        cfg.cluster.mu_hi = hi;
    }
    if let Some(p) = parsed.get("csv") {
        if !p.is_empty() {
            cfg.trace.csv_path = Some(p.to_string());
        }
    }
    if let Some(v) = parsed.get_parse::<usize>("reorder-threads")? {
        cfg.sim.reorder_threads = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("acc-spec-chunk")? {
        cfg.sim.acc_spec_chunk = v;
    }
    if let Some(s) = parsed.get("policies") {
        cfg.policies = taos::sched::PolicySet::parse(s)?;
    }
    apply_engine_flags(parsed, &mut cfg)?;
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

/// The DES engine flags, shared by `config_from` and `cmd_repro` (which
/// builds its base config without the common trace/cluster flags).
fn apply_engine_flags(
    parsed: &taos::cli::Parsed,
    cfg: &mut ExperimentConfig,
) -> Result<(), String> {
    if let Some(s) = parsed.get("engine") {
        cfg.sim.engine = taos::des::service::EngineKind::parse(s)
            .ok_or_else(|| format!("--engine must be `analytic` or `des`, got `{s}`"))?;
    }
    if let Some(s) = parsed.get("service") {
        cfg.sim.service = taos::des::service::ServiceModel::parse(s).ok_or_else(|| {
            format!("--service must be `det`, `exp:MEAN` or `pareto:ALPHA:CAP`, got `{s}`")
        })?;
    }
    if let Some(v) = parsed.get_parse::<f64>("locality-penalty")? {
        cfg.sim.locality_penalty = v;
    }
    if let Some(s) = parsed.get("topology") {
        cfg.sim.topology = taos::topology::TopologyKind::parse(s).ok_or_else(|| {
            format!(
                "--topology must be `flat`, `multi-rack`, `multi-zone` or \
                 `fat-tree`, got `{s}`"
            )
        })?;
    }
    if let Some(v) = parsed.get_parse::<f64>("speculate")? {
        cfg.sim.speculate = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("replicas")? {
        cfg.sim.replicas = v;
    }
    if let Some(s) = parsed.get("replication-budget") {
        cfg.sim.replication_budget = taos::des::service::ReplicationBudget::parse(s)
            .ok_or_else(|| {
                format!("--replication-budget must be `tail`, `idle` or `always`, got `{s}`")
            })?;
    }
    if let Some(s) = parsed.get("event-queue") {
        cfg.sim.event_queue = taos::des::calendar::EventQueueKind::parse(s)
            .ok_or_else(|| format!("--event-queue must be `heap` or `calendar`, got `{s}`"))?;
    }
    if let Some(v) = parsed.get_parse::<u64>("delay-bound")? {
        cfg.sim.delay_bound = v;
    }
    Ok(())
}

fn cmd_simulate(parsed: &taos::cli::Parsed) -> Result<(), String> {
    let mut cfg = config_from(parsed)?;
    let alg = parsed.get_or("alg", "wf");
    let policy = SchedPolicy::parse(alg).ok_or_else(|| format!("unknown algorithm `{alg}`"))?;
    let streaming = parsed.has_switch("stream-stats");
    if let Some(v) = parsed.get_parse::<u64>("progress")? {
        cfg.sim.progress_every = v;
    }
    let trace_out = parsed.get("trace-out").filter(|p| !p.is_empty());
    let metrics_out = parsed.get("metrics-out").filter(|p| !p.is_empty());
    if streaming && trace_out.is_some() {
        return Err("--trace-out cannot be combined with --stream-stats (the \
                    streaming fold keeps O(window) state and records no \
                    per-job lifecycle events)"
            .into());
    }
    let trace_limit = parsed.get_parse::<usize>("trace-limit")?.unwrap_or(1_000_000);
    // Off unless asked for: ObsSink::off() records nothing and costs
    // nothing; outcomes are bit-identical either way (asserted by
    // rust/tests/obs_trace.rs).
    let mut obs = if trace_out.is_some() || (metrics_out.is_some() && !streaming) {
        taos::obs::ObsSink::new(
            if trace_out.is_some() { trace_limit } else { 0 },
            metrics_out.is_some(),
        )
    } else {
        taos::obs::ObsSink::off()
    };
    let started = std::time::Instant::now();
    let out = if streaming {
        taos::sim::stream::run_stream_experiment(&cfg, policy)
    } else if trace_out.is_some() || metrics_out.is_some() {
        taos::sim::run_experiment_obs(&cfg, policy, &mut obs)
    } else {
        run_experiment(&cfg, policy)
    }
    .map_err(|e| e.to_string())?;
    if let Some(path) = trace_out {
        let body = if path.ends_with(".jsonl") {
            taos::obs::to_jsonl(&obs.trace)
        } else {
            taos::obs::to_chrome_json(&obs.trace, cfg.cluster.servers)
        };
        std::fs::write(path, body).map_err(|e| e.to_string())?;
        eprintln!(
            "wrote {path}: {} trace events ({} dropped by the ring)",
            obs.trace.len(),
            obs.trace.dropped()
        );
    }
    if let Some(path) = metrics_out {
        let reg = taos::obs::registry_from(&out, &obs);
        let body = if path.ends_with(".prom") {
            reg.to_prometheus()
        } else {
            reg.to_json().to_string()
        };
        std::fs::write(path, body).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}: {} metrics", reg.len());
    }
    let wall = started.elapsed().as_secs_f64().max(1e-9);
    let tel = out.telemetry;
    let events_per_sec = tel.events as f64 / wall;
    if parsed.has_switch("json") {
        // Under --stream-stats the percentiles come from the fixed-size
        // P² sketches (same keys, so downstream jq stays agnostic).
        let jct = if streaming {
            let s = taos::sim::stream::StreamStats::from_jcts(&out.jcts);
            Json::obj(vec![
                ("n", Json::num(s.n() as f64)),
                ("mean", Json::num(s.mean())),
                ("p50", Json::num(s.p50())),
                ("p90", Json::num(s.p90())),
                ("p99", Json::num(s.p99())),
                ("max", Json::num(s.max())),
            ])
        } else {
            out.jct_stats().to_json()
        };
        let mut fields = vec![
            ("algorithm", Json::str(policy.name())),
            ("engine", Json::str(cfg.sim.engine.name())),
            ("topology", Json::str(cfg.sim.topology.name())),
            ("jct", jct),
            // JCT = wait + service, both means in slots (deterministic,
            // unlike the wall-clock overhead keys).
            ("mean_wait", Json::num(out.mean_wait())),
            ("mean_service", Json::num(out.mean_service())),
            ("overhead_us", Json::num(out.overhead.mean_us())),
            // Wall-clock tail estimates: CI diffs must del() these
            // alongside .overhead_us and .events_per_sec.
            ("overhead_p50_us", Json::num(out.overhead.p50_us())),
            ("overhead_p99_us", Json::num(out.overhead.p99_us())),
            ("makespan", Json::num(out.makespan as f64)),
            ("wf_evals", Json::num(out.wf_evals as f64)),
            (
                "telemetry",
                Json::obj(vec![
                    ("events", Json::num(tel.events as f64)),
                    ("peak_events", Json::num(tel.peak_events as f64)),
                    ("peak_pool", Json::num(tel.peak_pool as f64)),
                    ("peak_window", Json::num(tel.peak_window as f64)),
                ]),
            ),
            // Wall-clock derived, so non-deterministic: CI diffs must
            // del(.events_per_sec) alongside .overhead_us.
            ("events_per_sec", Json::num(events_per_sec)),
        ];
        if !out.tier_tasks.is_empty() {
            fields.push((
                "tier_tasks",
                Json::arr(out.tier_tasks.iter().map(|&n| Json::num(n as f64))),
            ));
        }
        if out.busy_work > 0 {
            fields.push(("wasted_work", Json::num(out.wasted_work as f64)));
            fields.push(("busy_work", Json::num(out.busy_work as f64)));
            fields.push(("wasted_frac", Json::num(out.wasted_fraction())));
        }
        println!("{}", Json::obj(fields).to_string());
    } else {
        println!("algorithm      : {}", policy.name());
        if cfg.sim.engine == taos::des::service::EngineKind::Des {
            println!(
                "engine         : des (service {}, speculate {}, replicas {}, budget {}, \
                 locality penalty {}, topology {})",
                cfg.sim.service.describe(),
                cfg.sim.speculate,
                cfg.sim.effective_replicas(),
                cfg.sim.replication_budget.name(),
                cfg.sim.locality_penalty,
                cfg.sim.topology.name()
            );
        }
        if streaming {
            let s = taos::sim::stream::StreamStats::from_jcts(&out.jcts);
            println!(
                "jobs           : {} (streamed, peak window {})",
                s.n(),
                tel.peak_window
            );
            println!("mean JCT       : {:.1} slots (P\u{b2} sketch percentiles)", s.mean());
            println!("p50 / p90 / p99: {:.0} / {:.0} / {:.0}", s.p50(), s.p90(), s.p99());
            println!("max JCT        : {:.0}", s.max());
        } else {
            let stats = out.jct_stats();
            println!("jobs           : {}", stats.n);
            println!("mean JCT       : {:.1} slots", stats.mean);
            println!("p50 / p90 / p99: {:.0} / {:.0} / {:.0}", stats.p50, stats.p90, stats.p99);
            println!("max JCT        : {:.0}", stats.max);
        }
        println!("makespan       : {} slots", out.makespan);
        println!(
            "wait / service : {:.1} / {:.1} slots (mean; wait + service = JCT)",
            out.mean_wait(),
            out.mean_service()
        );
        println!(
            "overhead       : {:.1} us/arrival (p50 {:.1}, p99 {:.1})",
            out.overhead.mean_us(),
            out.overhead.p50_us(),
            out.overhead.p99_us()
        );
        if tel.events > 0 {
            println!(
                "DES events     : {} ({}/s, peak queue {}, peak pool {} slots)",
                taos::benchlib::fmt_count(tel.events),
                taos::benchlib::fmt_count(events_per_sec as u64),
                tel.peak_events,
                tel.peak_pool
            );
        }
        if out.wasted_work > 0 {
            println!(
                "wasted work    : {} replica-loser slots ({:.1}% of {} service slots)",
                taos::benchlib::fmt_count(out.wasted_work),
                out.wasted_fraction() * 100.0,
                taos::benchlib::fmt_count(out.busy_work)
            );
        }
        if out.wf_evals > 0 {
            println!(
                "WF evaluations : {} ({} reorder thread(s))",
                taos::benchlib::fmt_count(out.wf_evals),
                if cfg.sim.reorder_threads == 0 {
                    "all".to_string()
                } else {
                    cfg.sim.reorder_threads.to_string()
                }
            );
        }
        if !out.tier_tasks.is_empty() {
            let total: u64 = out.tier_tasks.iter().sum();
            let rates: Vec<String> = out
                .tier_tasks
                .iter()
                .map(|&n| format!("{:.0}%", n as f64 * 100.0 / total.max(1) as f64))
                .collect();
            println!(
                "locality tiers : {} (tier0=data-local .. top)",
                rates.join(" / ")
            );
        }
        if let Some(s) = out.oracle_stats {
            println!(
                "oracle tiers   : flow-infeasible {} / ceil {} / floor+residual {} / ilp {} (unknown {})",
                s.flow_infeasible, s.ceil_feasible, s.floor_residual_feasible, s.ilp_calls, s.ilp_unknown
            );
        }
    }
    Ok(())
}

fn cmd_compare(parsed: &taos::cli::Parsed) -> Result<(), String> {
    let cfg = config_from(parsed)?;
    let mut rows = Vec::new();
    for policy in &cfg.policies {
        let out = run_experiment(&cfg, policy).map_err(|e| e.to_string())?;
        rows.push((
            policy.name(),
            out.mean_jct(),
            out.mean_wait(),
            out.mean_service(),
            out.overhead.mean_us(),
        ));
    }
    if parsed.has_switch("json") {
        let j = Json::arr(rows.iter().map(|(name, jct, wait, service, ov)| {
            Json::obj(vec![
                ("algorithm", Json::str(*name)),
                ("mean_jct", Json::num(*jct)),
                ("mean_wait", Json::num(*wait)),
                ("mean_service", Json::num(*service)),
                ("overhead_us", Json::num(*ov)),
            ])
        }));
        println!("{}", j.to_string());
    } else {
        let mut t = taos::benchlib::TextTable::new(&[
            "algorithm",
            "mean JCT",
            "wait",
            "service",
            "overhead (us)",
        ]);
        for (name, jct, wait, service, ov) in rows {
            t.row(vec![
                name.into(),
                format!("{jct:.0}"),
                format!("{wait:.0}"),
                format!("{service:.0}"),
                format!("{ov:.1}"),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_repro(parsed: &taos::cli::Parsed) -> Result<(), String> {
    use taos::trace::scenarios::Scenario;

    let quick = parsed.has_switch("quick");
    let seed = parsed.get_parse::<u64>("seed")?.unwrap_or(42);
    let fig_id = parsed.get_or("fig", "12");
    let mut base = if quick {
        sweep::quick_base(seed)
    } else {
        sweep::paper_base(seed)
    };
    // A numbered figure can be re-run under a named workload (`--fig 12
    // --scenario bursty`); the catalog sweep already iterates every
    // scenario itself, so combining the two is a user error.
    if let Some(s) = parsed.get("scenario") {
        if fig_id == "scenarios" {
            return Err("--scenario cannot be combined with --fig scenarios \
                        (that sweep runs the whole catalog)"
                .into());
        }
        let sc = Scenario::parse(s).ok_or_else(|| format!("unknown scenario `{s}`"))?;
        sc.apply(&mut base);
    }
    // Within-cell parallelism (OCWF reorder rounds); the schedule is
    // bit-identical at any value and composes freely with --threads:
    // both levels share the process-wide executor, whose admission
    // budget lends nested reorder fan-outs idle workers only, so
    // `--threads N --reorder-threads K` can never oversubscribe the
    // pool.
    if let Some(v) = parsed.get_parse::<usize>("reorder-threads")? {
        base.sim.reorder_threads = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("acc-spec-chunk")? {
        base.sim.acc_spec_chunk = v;
    }
    // Engine flags after the scenario, so `--scenario straggler --engine
    // analytic` is an explicit (rejected) choice and `--fig 13 --engine
    // des` runs a whole figure through the DES oracle. The catalog sweep
    // applies each scenario per cell — which owns and resets the engine
    // knobs — so combining it with explicit engine flags would silently
    // discard them; reject it like the `--scenario` combination above.
    if fig_id == "scenarios" {
        for f in [
            "engine",
            "service",
            "locality-penalty",
            "speculate",
            "topology",
            "event-queue",
            "replicas",
            "replication-budget",
        ] {
            if parsed.get(f).is_some() {
                return Err(format!(
                    "--{f} cannot be combined with --fig scenarios (each \
                     catalog cell's scenario owns the engine knobs)"
                ));
            }
        }
    }
    // The topology figure's x-axis IS the locality penalty, so an explicit
    // penalty flag would be silently overwritten per cell — reject it.
    if fig_id == "topology" && parsed.get("locality-penalty").is_some() {
        return Err("--locality-penalty cannot be combined with --fig topology \
                    (the sweep's x-axis owns the penalty)"
            .into());
    }
    // The replication figure's x-axis is K and it iterates the three
    // service models itself; both flags would be silently overwritten.
    if fig_id == "replication" {
        for f in ["replicas", "service"] {
            if parsed.get(f).is_some() {
                return Err(format!(
                    "--{f} cannot be combined with --fig replication (the \
                     sweep's axes own the replica count and service model)"
                ));
            }
        }
    }
    apply_engine_flags(parsed, &mut base)?;
    // The replication sweep is DES-only; forcing the engine here lets
    // `--speculate` / `--replication-budget` ride along without also
    // requiring an explicit `--engine des`.
    if fig_id == "replication" {
        base.sim.engine = taos::des::service::EngineKind::Des;
    }
    base.validate().map_err(|e| e.to_string())?;
    // The policy panel: explicit --policies wins; the baselines figure
    // defaults to the full extended panel (that's its point); everything
    // else keeps the paper's six so historical exports stay byte-identical.
    let policies = match parsed.get("policies") {
        Some(s) => taos::sched::PolicySet::parse(s)?,
        None if fig_id == "baselines" => taos::sched::PolicySet::extended(),
        None => taos::sched::PolicySet::default(),
    };
    let opts = taos::sweep::SweepOptions::default()
        .with_threads(parsed.get_parse::<usize>("threads")?.unwrap_or(1))
        .with_trials(parsed.get_parse::<usize>("trials")?.unwrap_or(1))
        .with_policies(policies);
    // The replication frontier is three figures (one per service model:
    // det is the no-straggler control, exp and Pareto supply the tails),
    // each sweeping the replica-set size K — so it renders and exports
    // them together instead of going through the single-figure path.
    if fig_id == "replication" {
        use taos::des::service::ServiceModel;
        let services = [
            ServiceModel::Deterministic,
            ServiceModel::Exp { mean: 1.0 },
            ServiceModel::ParetoTail {
                alpha: 1.5,
                cap: 20.0,
            },
        ];
        let mut figs = Vec::new();
        for service in services {
            let f = sweep::fig_replication_opts(&base, service, &[1, 2, 3, 4], &opts)
                .map_err(|e| e.to_string())?;
            println!("{}", f.render());
            figs.push(f);
        }
        if let Some(out) = parsed.get("out") {
            if !out.is_empty() {
                let j = Json::obj(vec![(
                    "figures",
                    Json::arr(figs.iter().map(|f| f.to_json())),
                )]);
                std::fs::write(out, j.to_string()).map_err(|e| e.to_string())?;
                println!("wrote {out}");
            }
        }
        return Ok(());
    }
    let alphas = [0.0, 0.5, 1.0, 1.5, 2.0];
    let fig = match fig_id {
        "10" => sweep::fig_alpha_util_opts(&base, 0.25, &alphas, &opts),
        "11" => sweep::fig_alpha_util_opts(&base, 0.50, &alphas, &opts),
        "12" => sweep::fig_alpha_util_opts(&base, 0.75, &alphas, &opts),
        "13" | "table1" => sweep::fig_servers_opts(&base, &[4, 6, 8, 10, 12], &opts),
        "14" => sweep::fig_capacity_opts(&base, &[2, 3, 4, 5, 6], &opts),
        "topology" => sweep::fig_topology_opts(&base, &[1.0, 2.0, 4.0, 8.0, 16.0], &opts),
        "baselines" => sweep::fig_baselines_opts(&base, &[0.25, 0.5, 0.75, 0.9], &opts),
        "scenarios" => {
            println!("scenario legend:");
            for (i, sc) in Scenario::ALL.iter().enumerate() {
                println!("  {i} = {:<18} {}", sc.name(), sc.describe());
            }
            println!();
            sweep::fig_scenarios(&base, &opts)
        }
        other => return Err(format!("unknown figure `{other}`")),
    }
    .map_err(|e| e.to_string())?;
    println!("{}", fig.render());
    if let Some(out) = parsed.get("out") {
        if !out.is_empty() {
            std::fs::write(out, fig.to_json().to_string()).map_err(|e| e.to_string())?;
            println!("wrote {out}");
        }
    }
    Ok(())
}

fn cmd_gen_trace(parsed: &taos::cli::Parsed) -> Result<(), String> {
    use taos::trace::scenarios::Scenario;
    use taos::util::rng::Rng;
    let jobs = parsed.get_parse::<usize>("jobs")?.unwrap_or(250);
    let tasks = parsed.get_parse::<usize>("tasks")?.unwrap_or(113_653);
    let seed = parsed.get_parse::<u64>("seed")?.unwrap_or(42);
    let out = parsed.get_or("out", "trace.csv");
    let sc_name = parsed.get_or("scenario", "alibaba");
    let scenario =
        Scenario::parse(sc_name).ok_or_else(|| format!("unknown scenario `{sc_name}`"))?;
    if scenario.has_cluster_twist() || scenario.has_engine_twist() {
        eprintln!(
            "note: `{}` includes a {} twist — a CSV trace captures only \
             the workload shape, so pass --scenario {} at simulation time to get \
             the full twist",
            scenario.name(),
            if scenario.has_engine_twist() {
                "DES-engine-side"
            } else {
                "cluster-side"
            },
            scenario.name()
        );
    }
    let mut tcfg = taos::config::TraceConfig::default();
    tcfg.jobs = jobs;
    tcfg.total_tasks = tasks;
    let trace = scenario.synth(&tcfg, &mut Rng::seed_from(seed));
    // Stream rows straight to disk — no all-rows String for large traces.
    let file = std::fs::File::create(out).map_err(|e| e.to_string())?;
    let mut w = std::io::BufWriter::new(file);
    taos::trace::csv::write_batch_task_csv(&trace, &mut w).map_err(|e| e.to_string())?;
    use std::io::Write as _;
    w.flush().map_err(|e| e.to_string())?;
    println!(
        "wrote {out}: {} jobs, {} tasks, {} groups ({} scenario)",
        trace.jobs.len(),
        trace.total_tasks(),
        trace.total_groups(),
        scenario.name()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_live(_parsed: &taos::cli::Parsed) -> Result<(), String> {
    Err("the `live` subcommand needs the PJRT runtime, which is gated off \
         in the dependency-free build; rebuild with `--features pjrt` \
         (requires the vendored `xla` crate)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_live(parsed: &taos::cli::Parsed) -> Result<(), String> {
    use std::path::Path;
    use std::sync::Arc;
    use taos::cluster::Cluster;
    use taos::config::ClusterConfig;
    use taos::coordinator::{AccelHandle, Leader, LiveJobSpec};
    use taos::util::rng::Rng;

    let servers = parsed.get_parse::<usize>("servers")?.unwrap_or(4);
    let jobs = parsed.get_parse::<usize>("jobs")?.unwrap_or(8);
    let tpj = parsed.get_parse::<usize>("tasks-per-job")?.unwrap_or(32);
    let replicas = parsed.get_parse::<usize>("replicas")?.unwrap_or(3);
    let alg = parsed.get_or("alg", "wf");
    let policy = AssignPolicy::parse(alg).ok_or_else(|| format!("unknown assigner `{alg}`"))?;
    let artifacts = parsed.get_or("artifacts", "artifacts");

    let accel =
        Arc::new(AccelHandle::spawn(Path::new(artifacts)).map_err(|e| e.to_string())?);
    let mut ccfg = ClusterConfig::default();
    ccfg.servers = servers;
    ccfg.avail_lo = 1;
    ccfg.avail_hi = replicas.min(servers);
    let cluster = Cluster::generate(&ccfg, &mut Rng::seed_from(1));
    let leader = Leader::start(cluster, Arc::clone(&accel), replicas).map_err(|e| e.to_string())?;

    let mut rng = Rng::seed_from(99);
    let specs: Vec<LiveJobSpec> = (0..jobs)
        .map(|id| LiveJobSpec {
            id,
            chunk_ids: (0..tpj).map(|_| rng.gen_range(10_000)).collect(),
        })
        .collect();
    let report = leader.run_jobs(&specs, policy).map_err(|e| e.to_string())?;
    let lat = report.latency_summary();
    println!("live run: {} jobs x {} tasks on {} workers ({})", jobs, tpj, servers, policy.name());
    println!("throughput : {:.0} tasks/s", report.throughput_tps());
    println!("job latency: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms", lat.mean, lat.p50, lat.p99);
    println!("checksum   : {:.4} (payload kernel really ran)", report.checksum);
    leader.shutdown();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_verify_kernel(_parsed: &taos::cli::Parsed) -> Result<(), String> {
    Err("the `verify-kernel` subcommand needs the PJRT runtime, which is \
         gated off in the dependency-free build; rebuild with `--features \
         pjrt` (requires the vendored `xla` crate)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_verify_kernel(parsed: &taos::cli::Parsed) -> Result<(), String> {
    let artifacts = parsed.get_or("artifacts", "artifacts");
    let cases = parsed.get_parse::<usize>("cases")?.unwrap_or(64);
    let seed = parsed.get_parse::<u64>("seed")?.unwrap_or(7);
    let (checked, max_b) = taos::coordinator::verify::verify_wf_kernel(
        std::path::Path::new(artifacts),
        cases,
        seed,
    )
    .map_err(|e| e.to_string())?;
    println!("verified {checked} random instances (batches of {max_b}): AOT kernel == native WF");
    Ok(())
}
