//! The persistent worker-pool executor.
//!
//! Both parallel fan-outs in the scheduler — the sweep engine's
//! (policy × setting × trial) cells and the OCWF reorder driver's
//! candidate Φ evaluations — used to spawn **scoped threads per chunk**
//! (`std::thread::scope`). A thread spawn costs tens of microseconds,
//! which dominates exactly the regime where OCWF-ACC should be cheapest:
//! small outstanding sets evaluate a handful of candidates per round, so
//! the per-round spawn overhead exceeded the work being fanned out.
//!
//! This module replaces the per-chunk spawns with a pool of **parked
//! worker threads** created once and reused for every batch:
//!
//! - Submission pushes one epoch-tagged [`Batch`] descriptor into a
//!   mutex-guarded queue and rings the **per-worker doorbells** of up to
//!   `stripes − 1` *idle* workers (see below).
//! - A batch is divided into `stripes` logical units. Workers (and the
//!   submitter itself, see below) claim stripes through an atomic ticket
//!   counter, so each stripe runs **exactly once** on exactly one thread.
//! - Completion is counted on an atomic and the submitter is released via
//!   `thread::park`/`unpark` — no allocation, no channels.
//!
//! ## Per-worker doorbells and the admission budget
//!
//! Earlier versions woke helpers through a shared condvar with up to
//! `stripes − 1` `notify_one` calls per batch — wakeups that raced each
//! other to the ticket counter and, when the sweep level already occupied
//! the pool, accomplished nothing at all. Handoff is now a **parked-thread
//! doorbell** per worker: one state word plus the worker's `Thread`
//! handle. A worker with nothing to do pushes its index onto an
//! **idle stack** (guarded by the queue mutex) and parks; a submitter pops
//! exactly the helpers it admits and wakes each with one targeted
//! `unpark`. A 2-stripe reorder round therefore wakes **at most one
//! worker with one unpark**, and a fully busy pool wakes nobody.
//!
//! The idle stack doubles as the executor-wide **admission budget** that
//! lets the two parallelism levels (`--threads` sweep cells ×
//! `--reorder-threads` reorder rounds) compose: helpers are borrowed from
//! the idle set only, so concurrent helpers can never exceed the pool
//! size no matter how many batches are in flight, and a nested reorder
//! fan-out submitted from a busy pool admits zero helpers — its submitter
//! drains the batch alone (the submitter-helps rule below), which is the
//! correct degeneration: every core is already doing scheduler work.
//! Outstanding claimed stripes are tracked in [`Executor::stripes_in_flight`],
//! and the budget's decisions are exported next to
//! [`Executor::epochs_dispatched`] as [`Executor::helpers_woken_total`]
//! (doorbells actually rung) and [`Executor::wakeups_trimmed_total`]
//! (helper wakeups the budget suppressed because no worker was idle).
//!
//! ## Why the submitter helps
//!
//! After enqueueing, the submitting thread claims and runs stripes of its
//! own batch before blocking. This makes nested submission — a sweep cell
//! running *on* a pool worker that itself fans a reorder round out —
//! deadlock-free by construction: even if every pool worker is busy, the
//! submitter alone drains its batch. It also means a batch never waits
//! for a worker to wake before making progress.
//!
//! ## Determinism
//!
//! Which *thread* runs a stripe is scheduling-dependent; which *work* a
//! stripe performs is a pure function of the stripe index. Both callers
//! ([`crate::sweep::pool::parallel_map`] re-sorts by index,
//! [`crate::sweep::pool::parallel_for_each`] stripes worker states
//! statically) keep their outputs bit-identical at any thread count — and
//! at any admission decision, since an unadmitted helper only means fewer
//! threads execute the same stripes — as asserted by `sweep_determinism`
//! and `reorder_equivalence` (including their combined sweep × reorder
//! cases).
//!
//! ## Panics and shutdown
//!
//! A panic inside a stripe is caught, recorded in the batch, and
//! re-thrown on the submitting thread after the batch completes — the
//! same observable behavior as a scoped-thread panic, except the pool
//! workers survive and keep serving later batches. Dropping an
//! [`Executor`] parks no new work, rings every doorbell, and joins the
//! workers; the process-wide [`Executor::global`] pool lives for the
//! process lifetime. Thread creation is counted in a process-wide counter
//! ([`threads_spawned_total`]) so the allocation-stability suite can
//! assert the pool spawns **zero threads after warmup**.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::{JoinHandle, Thread};

/// Process-wide count of pool worker threads ever spawned. Monotonic;
/// frozen once every executor in use is warm — the property
/// `rust/tests/alloc_stability.rs` asserts.
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total pool worker threads spawned by all executors so far.
pub fn threads_spawned_total() -> u64 {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// One submitted batch: a type-erased task run once per stripe.
///
/// The descriptor lives on the **submitter's stack**; workers reach it
/// through a raw pointer published via the queue mutex. Safety rests on
/// one invariant: the submitter does not return from
/// [`Executor::run_batch`] until every stripe has completed *and* the
/// queue entry has been removed, so any pointer a worker can still reach
/// refers to a live batch (see `run_claimed` for the claim-ordering that
/// upholds this across stripe boundaries).
struct Batch {
    /// Type-erased `F: Fn(usize)` invoker.
    call: unsafe fn(*const (), usize),
    data: *const (),
    stripes: usize,
    /// Ticket counter: `fetch_add` hands out stripe indices exactly once.
    next: AtomicUsize,
    /// Stripes not yet completed; the submitter parks until it reaches 0.
    remaining: AtomicUsize,
    /// The submitting thread, unparked by the final completion.
    waiter: Thread,
    /// First panic payload observed in any stripe (re-thrown by the
    /// submitter).
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// A queue entry. Sendable by the invariant documented on [`Batch`].
#[derive(Clone, Copy)]
struct BatchPtr(*const Batch);
unsafe impl Send for BatchPtr {}

/// Doorbell states (the per-worker handoff word).
const DB_PARKED: u32 = 0;
const DB_RUNG: u32 = 1;

/// One worker's handoff slot: its `Thread` handle (registered once at
/// startup, before the worker can ever appear on the idle stack) and a
/// state word flipped `PARKED → RUNG` by whoever pops the worker off the
/// idle stack. Only the popper may ring: popping transfers ownership of
/// the wakeup, so a doorbell is never rung twice for one park.
struct Doorbell {
    state: AtomicU32,
    handle: OnceLock<Thread>,
}

struct Queue {
    items: VecDeque<BatchPtr>,
    /// Indices of parked workers (each appears at most once: a worker
    /// pushes itself immediately before parking, a submitter pops it when
    /// ringing its doorbell). This stack **is** the admission budget:
    /// helpers are only ever borrowed from it.
    idle: Vec<usize>,
    shutdown: bool,
}

struct Inner {
    queue: Mutex<Queue>,
    doorbells: Vec<Doorbell>,
    /// Epochs (batches) dispatched — telemetry for the handoff cost the
    /// executor amortizes.
    epochs: AtomicU64,
    /// Claimed-but-uncompleted stripes across all in-flight batches (the
    /// budget's view of current demand). Telemetry only: admission is
    /// decided by the idle stack, which can never over-lend.
    in_flight: AtomicUsize,
    /// Doorbells actually rung (helpers admitted by the budget).
    helpers_woken: AtomicU64,
    /// Helper wakeups the budget suppressed (wanted − admitted, summed):
    /// each is a condvar notify the pre-doorbell executor would have
    /// issued into a busy pool.
    wakeups_trimmed: AtomicU64,
}

/// A persistent pool of parked worker threads executing striped batches.
pub struct Executor {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Executor {
    /// Spawn a pool with `threads` parked workers (at least one).
    pub fn new(threads: usize) -> Executor {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(Queue {
                items: VecDeque::new(),
                idle: Vec::with_capacity(threads),
                shutdown: false,
            }),
            doorbells: (0..threads)
                .map(|_| Doorbell {
                    state: AtomicU32::new(DB_PARKED),
                    handle: OnceLock::new(),
                })
                .collect(),
            epochs: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            helpers_woken: AtomicU64::new(0),
            wakeups_trimmed: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|w| {
                let inner = Arc::clone(&inner);
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("taos-exec-{w}"))
                    .spawn(move || worker_loop(&inner, w))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor { inner, workers }
    }

    /// The process-wide pool, created on first use with one worker per
    /// hardware thread. All library fan-outs go through this instance;
    /// after its lazy construction the process never spawns another pool
    /// thread.
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            Executor::new(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            )
        })
    }

    /// Number of pooled worker threads (fixed at construction).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Batches dispatched so far (telemetry).
    pub fn epochs_dispatched(&self) -> u64 {
        self.inner.epochs.load(Ordering::Relaxed)
    }

    /// Claimed-but-uncompleted stripes across all in-flight batches right
    /// now (budget telemetry; 0 when the executor is quiescent).
    pub fn stripes_in_flight(&self) -> usize {
        self.inner.in_flight.load(Ordering::Relaxed)
    }

    /// Doorbells rung so far — helpers the admission budget let batches
    /// borrow (telemetry, next to [`Executor::epochs_dispatched`]).
    pub fn helpers_woken_total(&self) -> u64 {
        self.inner.helpers_woken.load(Ordering::Relaxed)
    }

    /// Helper wakeups the admission budget suppressed because no worker
    /// was idle — nested fan-outs submitted from a saturated pool land
    /// here and are drained by their submitters alone (telemetry).
    pub fn wakeups_trimmed_total(&self) -> u64 {
        self.inner.wakeups_trimmed.load(Ordering::Relaxed)
    }

    /// Workers currently parked on the idle stack (budget headroom).
    pub fn idle_workers(&self) -> usize {
        self.inner.queue.lock().unwrap().idle.len()
    }

    /// Run `task(stripe)` once for every `stripe in 0..stripes`, blocking
    /// until all stripes completed. `stripes` may exceed the pool size —
    /// stripes are logical work units, not threads. A single stripe runs
    /// inline. Panics in any stripe are re-thrown here after the batch
    /// drains.
    pub fn run_batch<F>(&self, stripes: usize, task: &F)
    where
        F: Fn(usize) + Sync,
    {
        if stripes == 0 {
            return;
        }
        if stripes == 1 {
            task(0);
            return;
        }
        unsafe fn thunk<F: Fn(usize)>(data: *const (), stripe: usize) {
            (*(data as *const F))(stripe)
        }
        let batch = Batch {
            call: thunk::<F>,
            data: task as *const F as *const (),
            stripes,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(stripes),
            waiter: std::thread::current(),
            panic: Mutex::new(None),
        };
        self.inner.epochs.fetch_add(1, Ordering::Relaxed);
        let ptr = BatchPtr(&batch as *const Batch);
        // At most `stripes - 1` helpers are useful (the submitter covers
        // the rest), and the admission budget trims that to the workers
        // actually idle: ringing a busy pool would thrash exactly the
        // small-set regime this pool exists for, and lending more than
        // the pool size is impossible by construction.
        //
        // Helpers are *popped* under the queue lock but *rung* after it
        // is released: a popped worker can only sit in its doorbell spin
        // until we ring it, and ringing (an unpark syscall) under the
        // lock would make the woken worker's first action — re-locking
        // the queue — contend with this very critical section. The
        // on-stack chunk keeps the hot path allocation-free; pools wider
        // than a chunk just loop (each pass pops at most CHUNK helpers).
        let wanted = (stripes - 1).min(self.workers.len());
        const CHUNK: usize = 16;
        let mut admitted = 0usize;
        loop {
            let mut rung = [0usize; CHUNK];
            let n;
            {
                let mut q = self.inner.queue.lock().unwrap();
                if admitted == 0 {
                    q.items.push_back(ptr);
                }
                let take = (wanted - admitted).min(CHUNK).min(q.idle.len());
                for slot in rung.iter_mut().take(take) {
                    *slot = q.idle.pop().expect("idle stack underflow");
                }
                n = take;
            }
            for &w in &rung[..n] {
                let db = &self.inner.doorbells[w];
                db.state.store(DB_RUNG, Ordering::Release);
                db.handle
                    .get()
                    .expect("worker registered before idling")
                    .unpark();
            }
            admitted += n;
            if n < CHUNK || admitted >= wanted {
                break;
            }
        }
        if admitted > 0 {
            self.inner
                .helpers_woken
                .fetch_add(admitted as u64, Ordering::Relaxed);
        }
        if wanted > admitted {
            self.inner
                .wakeups_trimmed
                .fetch_add((wanted - admitted) as u64, Ordering::Relaxed);
        }
        // Help: claim and run stripes of our own batch. Guarantees
        // progress even when the budget admitted zero helpers (nested
        // submission from a saturated pool).
        let first = batch.next.fetch_add(1, Ordering::Relaxed);
        if first < stripes {
            self.inner.in_flight.fetch_add(1, Ordering::Relaxed);
            run_claimed(&self.inner, &batch, first);
        }
        // Wait for straggler stripes claimed by workers.
        while batch.remaining.load(Ordering::Acquire) != 0 {
            std::thread::park();
        }
        // Remove our entry if no worker consumed it; after this point no
        // thread can reach the batch and it may safely drop.
        {
            let mut q = self.inner.queue.lock().unwrap();
            if let Some(pos) = q.items.iter().position(|p| p.0 == ptr.0) {
                let _ = q.items.remove(pos);
            }
        }
        let payload = batch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Flag shutdown and ring every parked worker. A worker is either
        // on the idle stack (pushed under the same lock, so visible here)
        // or busy — busy workers observe the flag on their next scan.
        let mut parked: Vec<usize> = Vec::new();
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.shutdown = true;
            parked.append(&mut q.idle);
        }
        for w in parked {
            let db = &self.inner.doorbells[w];
            db.state.store(DB_RUNG, Ordering::Release);
            if let Some(t) = db.handle.get() {
                t.unpark();
            }
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run stripe `first` and keep claiming follow-up stripes until the
/// ticket counter is exhausted. The caller must have incremented
/// `in_flight` for `first` when it claimed the ticket.
///
/// Claim-ordering invariant: the *next* ticket is always claimed **before
/// completing the current stripe**. While a claimed stripe is
/// uncompleted, `remaining > 0`, so the submitter cannot return and the
/// batch cannot drop — making the follow-up `fetch_add` safe. Once a
/// completion might be the last (ticket exhausted), the batch is never
/// touched again: `stripes` is copied to a local and the waiter handle is
/// cloned out before the final `fetch_sub`.
fn run_claimed(inner: &Inner, batch: &Batch, first: usize) {
    let stripes = batch.stripes;
    let mut s = first;
    loop {
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (batch.call)(batch.data, s) }));
        if let Err(payload) = result {
            let mut slot = batch.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let next = batch.next.fetch_add(1, Ordering::Relaxed);
        if next < stripes {
            inner.in_flight.fetch_add(1, Ordering::Relaxed);
        }
        let waiter = batch.waiter.clone();
        // Stripe `s` completes here: retire its in-flight claim before
        // the `remaining` decrement that may release the submitter, so a
        // quiescent executor always reads `in_flight == 0`.
        inner.in_flight.fetch_sub(1, Ordering::Relaxed);
        if batch.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Final completion: `batch` may be dropped by the submitter
            // the instant this fetch_sub lands. Only locals (and `inner`,
            // which outlives every batch) from here on.
            waiter.unpark();
            return;
        }
        if next >= stripes {
            return;
        }
        s = next;
    }
}

fn worker_loop(inner: &Inner, w: usize) {
    // Register the doorbell handle before the first idle push: a popper
    // can only see this worker on the idle stack afterwards.
    let _ = inner.doorbells[w].handle.set(std::thread::current());
    loop {
        // Claim a stripe while holding the queue lock: an entry present
        // in the queue is always live (the submitter removes its entry
        // before returning), and a successful claim keeps the batch live
        // past the unlock.
        let (ptr, first) = {
            let mut q = inner.queue.lock().unwrap();
            'scan: loop {
                if q.shutdown {
                    return;
                }
                while let Some(&p) = q.items.front() {
                    let b = unsafe { &*p.0 };
                    let s = b.next.fetch_add(1, Ordering::Relaxed);
                    if s < b.stripes {
                        inner.in_flight.fetch_add(1, Ordering::Relaxed);
                        break 'scan (p, s);
                    }
                    // Fully claimed: no work left to hand out.
                    let _ = q.items.pop_front();
                }
                // Nothing to do: park on the doorbell. State is reset and
                // the index pushed under the lock, so any submitter that
                // pops this worker afterwards rings a PARKED doorbell.
                let db = &inner.doorbells[w];
                db.state.store(DB_PARKED, Ordering::Relaxed);
                q.idle.push(w);
                drop(q);
                // `park` can return spuriously (or consume a stale token
                // from an earlier nested-submitter wait), so spin on the
                // state word; only the popper flips it to RUNG.
                while db.state.load(Ordering::Acquire) == DB_PARKED {
                    std::thread::park();
                }
                q = inner.queue.lock().unwrap();
            }
        };
        let batch = unsafe { &*ptr.0 };
        run_claimed(inner, batch, first);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn every_stripe_runs_exactly_once() {
        let ex = Executor::new(3);
        for stripes in [1, 2, 3, 7, 64] {
            let counts: Vec<AtomicU32> = (0..stripes).map(|_| AtomicU32::new(0)).collect();
            let task = |s: usize| {
                counts[s].fetch_add(1, Ordering::Relaxed);
            };
            ex.run_batch(stripes, &task);
            for (s, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "stripe {s} of {stripes}");
            }
        }
    }

    #[test]
    fn oversubscribed_stripes_complete_on_small_pool() {
        let ex = Executor::new(1);
        let total = AtomicU32::new(0);
        ex.run_batch(100, &|_s| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // A stripe submitting its own batch to the same (single-worker!)
        // pool must complete: the submitter-helps rule drains it even
        // when the admission budget lends zero helpers.
        let ex = Executor::new(1);
        let inner_runs = AtomicU32::new(0);
        ex.run_batch(3, &|_s| {
            ex.run_batch(4, &|_t| {
                inner_runs.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_runs.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let ex = Executor::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            ex.run_batch(8, &|s| {
                if s == 5 {
                    panic!("stripe boom");
                }
            });
        }));
        assert!(caught.is_err(), "stripe panic must reach the submitter");
        // The pool keeps working after a stripe panicked.
        let ok = AtomicU32::new(0);
        ex.run_batch(4, &|_s| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn shutdown_joins_promptly() {
        // The CI matrix gates the suite with a timeout; this is the
        // in-repo watchdog for the same hang class (now covering the
        // doorbell wakeups at drop).
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let ex = Executor::new(4);
            ex.run_batch(16, &|_s| {});
            drop(ex);
            tx.send(()).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(30))
            .expect("executor shutdown hung");
    }

    #[test]
    fn epoch_counter_advances_per_batch() {
        let ex = Executor::new(2);
        let before = ex.epochs_dispatched();
        ex.run_batch(4, &|_s| {});
        ex.run_batch(4, &|_s| {});
        assert_eq!(ex.epochs_dispatched(), before + 2);
        // Single-stripe batches run inline and are not dispatched.
        ex.run_batch(1, &|_s| {});
        assert_eq!(ex.epochs_dispatched(), before + 2);
    }

    #[test]
    fn budget_quiesces_and_counts_helpers() {
        let ex = Executor::new(2);
        assert_eq!(ex.stripes_in_flight(), 0);
        for _ in 0..50 {
            ex.run_batch(8, &|_s| {});
            // Every stripe completed before run_batch returned, and the
            // in-flight retirement precedes the completion count, so a
            // quiescent pool must always read zero.
            assert_eq!(ex.stripes_in_flight(), 0);
        }
        // Telemetry is exported and consistent: every wanted helper
        // (min(stripes-1, pool) = 2 per batch) was either admitted from
        // the idle stack or trimmed by the budget.
        assert_eq!(
            ex.helpers_woken_total() + ex.wakeups_trimmed_total(),
            50 * 2,
            "wanted helpers must split into admitted + trimmed"
        );
    }

    #[test]
    fn saturated_pool_admits_zero_helpers_for_nested_batches() {
        // One worker, pinned busy by an outer stripe while the other
        // stripe submits a nested batch: the nested submission must see
        // an empty idle stack, admit zero helpers, and still complete
        // (drained by its submitter alone).
        let ex = Executor::new(1);
        let barrier = std::sync::Barrier::new(2);
        let inner_runs = AtomicU32::new(0);
        // Baselines are captured INSIDE stripe 0, bracketing the nested
        // submission: the outer submission may itself trim a wakeup (the
        // worker races its first park), and that must not satisfy the
        // assertion on the nested path.
        let trimmed = (AtomicU64::new(0), AtomicU64::new(0));
        ex.run_batch(2, &|s| {
            // Both stripes rendezvous: submitter and worker are now both
            // engaged, so the pool is saturated.
            barrier.wait();
            if s == 0 {
                trimmed.0.store(ex.wakeups_trimmed_total(), Ordering::Relaxed);
                ex.run_batch(3, &|_t| {
                    inner_runs.fetch_add(1, Ordering::Relaxed);
                });
                trimmed.1.store(ex.wakeups_trimmed_total(), Ordering::Relaxed);
            }
            // Hold the other stripe until the nested batch finished, so
            // the other thread cannot re-park mid-submission.
            barrier.wait();
        });
        assert_eq!(inner_runs.load(Ordering::Relaxed), 3);
        // The nested batch wanted min(3 − 1, pool = 1) = 1 helper and the
        // whole pool was provably busy between the barriers, so exactly
        // one wakeup was trimmed by the nested submission itself.
        assert_eq!(
            trimmed.1.load(Ordering::Relaxed),
            trimmed.0.load(Ordering::Relaxed) + 1,
            "nested submission from a saturated pool must trim its helper wakeup"
        );
    }

    #[test]
    fn global_pool_is_one_instance() {
        // The frozen-thread-count property is asserted in
        // `rust/tests/alloc_stability.rs`, where no test-local pools run
        // concurrently; here we check identity and reusability.
        let a = Executor::global();
        a.run_batch(4, &|_s| {});
        for _ in 0..16 {
            let b = Executor::global();
            assert!(std::ptr::eq(a, b), "global pool must be a singleton");
            b.run_batch(8, &|_s| {});
        }
        assert!(a.threads() >= 1);
        assert!(threads_spawned_total() >= a.threads() as u64);
    }

    #[test]
    fn idle_workers_bounded_by_pool_size() {
        let ex = Executor::new(3);
        // Give the workers a moment to park; the count is racy by nature
        // so only the invariant bound is asserted.
        for _ in 0..10 {
            ex.run_batch(4, &|_s| {});
            assert!(ex.idle_workers() <= ex.threads());
        }
    }
}
