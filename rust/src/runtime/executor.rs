//! The persistent worker-pool executor.
//!
//! Both parallel fan-outs in the scheduler — the sweep engine's
//! (policy × setting × trial) cells and the OCWF reorder driver's
//! candidate Φ evaluations — used to spawn **scoped threads per chunk**
//! (`std::thread::scope`). A thread spawn costs tens of microseconds,
//! which dominates exactly the regime where OCWF-ACC should be cheapest:
//! small outstanding sets evaluate a handful of candidates per round, so
//! the per-round spawn overhead exceeded the work being fanned out.
//!
//! This module replaces the per-chunk spawns with a pool of **parked
//! worker threads** created once and reused for every batch:
//!
//! - Submission pushes one epoch-tagged [`Batch`] descriptor into a
//!   mutex-guarded queue and wakes up to `stripes − 1` parked workers
//!   through a condvar.
//! - A batch is divided into `stripes` logical units. Workers (and the
//!   submitter itself, see below) claim stripes through an atomic ticket
//!   counter, so each stripe runs **exactly once** on exactly one thread.
//! - Completion is counted on an atomic and the submitter is released via
//!   `thread::park`/`unpark` — no allocation, no channels.
//!
//! ## Why the submitter helps
//!
//! After enqueueing, the submitting thread claims and runs stripes of its
//! own batch before blocking. This makes nested submission — a sweep cell
//! running *on* a pool worker that itself fans a reorder round out —
//! deadlock-free by construction: even if every pool worker is busy, the
//! submitter alone drains its batch. It also means a batch never waits
//! for a worker to wake before making progress.
//!
//! ## Determinism
//!
//! Which *thread* runs a stripe is scheduling-dependent; which *work* a
//! stripe performs is a pure function of the stripe index. Both callers
//! ([`crate::sweep::pool::parallel_map`] re-sorts by index,
//! [`crate::sweep::pool::parallel_for_each`] stripes worker states
//! statically) keep their outputs bit-identical at any thread count, as
//! asserted by `sweep_determinism` and `reorder_equivalence`.
//!
//! ## Panics and shutdown
//!
//! A panic inside a stripe is caught, recorded in the batch, and
//! re-thrown on the submitting thread after the batch completes — the
//! same observable behavior as a scoped-thread panic, except the pool
//! workers survive and keep serving later batches. Dropping an
//! [`Executor`] parks no new work, wakes every worker, and joins them;
//! the process-wide [`Executor::global`] pool lives for the process
//! lifetime. Thread creation is counted in a process-wide counter
//! ([`threads_spawned_total`]) so the allocation-stability suite can
//! assert the pool spawns **zero threads after warmup**.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{JoinHandle, Thread};

/// Process-wide count of pool worker threads ever spawned. Monotonic;
/// frozen once every executor in use is warm — the property
/// `rust/tests/alloc_stability.rs` asserts.
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total pool worker threads spawned by all executors so far.
pub fn threads_spawned_total() -> u64 {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// One submitted batch: a type-erased task run once per stripe.
///
/// The descriptor lives on the **submitter's stack**; workers reach it
/// through a raw pointer published via the queue mutex. Safety rests on
/// one invariant: the submitter does not return from
/// [`Executor::run_batch`] until every stripe has completed *and* the
/// queue entry has been removed, so any pointer a worker can still reach
/// refers to a live batch (see `run_claimed` for the claim-ordering that
/// upholds this across stripe boundaries).
struct Batch {
    /// Type-erased `F: Fn(usize)` invoker.
    call: unsafe fn(*const (), usize),
    data: *const (),
    stripes: usize,
    /// Ticket counter: `fetch_add` hands out stripe indices exactly once.
    next: AtomicUsize,
    /// Stripes not yet completed; the submitter parks until it reaches 0.
    remaining: AtomicUsize,
    /// The submitting thread, unparked by the final completion.
    waiter: Thread,
    /// First panic payload observed in any stripe (re-thrown by the
    /// submitter).
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// A queue entry. Sendable by the invariant documented on [`Batch`].
#[derive(Clone, Copy)]
struct BatchPtr(*const Batch);
unsafe impl Send for BatchPtr {}

struct Queue {
    items: VecDeque<BatchPtr>,
    shutdown: bool,
}

struct Inner {
    queue: Mutex<Queue>,
    work_cv: Condvar,
    /// Epochs (batches) dispatched — telemetry for the handoff cost the
    /// executor amortizes.
    epochs: AtomicU64,
}

/// A persistent pool of parked worker threads executing striped batches.
pub struct Executor {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Executor {
    /// Spawn a pool with `threads` parked workers (at least one).
    pub fn new(threads: usize) -> Executor {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(Queue {
                items: VecDeque::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            epochs: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|w| {
                let inner = Arc::clone(&inner);
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("taos-exec-{w}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor { inner, workers }
    }

    /// The process-wide pool, created on first use with one worker per
    /// hardware thread. All library fan-outs go through this instance;
    /// after its lazy construction the process never spawns another pool
    /// thread.
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            Executor::new(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            )
        })
    }

    /// Number of pooled worker threads (fixed at construction).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Batches dispatched so far (telemetry).
    pub fn epochs_dispatched(&self) -> u64 {
        self.inner.epochs.load(Ordering::Relaxed)
    }

    /// Run `task(stripe)` once for every `stripe in 0..stripes`, blocking
    /// until all stripes completed. `stripes` may exceed the pool size —
    /// stripes are logical work units, not threads. A single stripe runs
    /// inline. Panics in any stripe are re-thrown here after the batch
    /// drains.
    pub fn run_batch<F>(&self, stripes: usize, task: &F)
    where
        F: Fn(usize) + Sync,
    {
        if stripes == 0 {
            return;
        }
        if stripes == 1 {
            task(0);
            return;
        }
        unsafe fn thunk<F: Fn(usize)>(data: *const (), stripe: usize) {
            (*(data as *const F))(stripe)
        }
        let batch = Batch {
            call: thunk::<F>,
            data: task as *const F as *const (),
            stripes,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(stripes),
            waiter: std::thread::current(),
            panic: Mutex::new(None),
        };
        self.inner.epochs.fetch_add(1, Ordering::Relaxed);
        let ptr = BatchPtr(&batch as *const Batch);
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.items.push_back(ptr);
        }
        // At most `stripes - 1` helpers are useful (the submitter covers
        // the rest); waking the whole pool for a 2-stripe reorder round
        // would thrash exactly the small-set regime this pool exists for.
        for _ in 0..(stripes - 1).min(self.workers.len()) {
            self.inner.work_cv.notify_one();
        }
        // Help: claim and run stripes of our own batch. Guarantees
        // progress even when every worker is busy (nested submission).
        let first = batch.next.fetch_add(1, Ordering::Relaxed);
        if first < stripes {
            run_claimed(&batch, first);
        }
        // Wait for straggler stripes claimed by workers.
        while batch.remaining.load(Ordering::Acquire) != 0 {
            std::thread::park();
        }
        // Remove our entry if no worker consumed it; after this point no
        // thread can reach the batch and it may safely drop.
        {
            let mut q = self.inner.queue.lock().unwrap();
            if let Some(pos) = q.items.iter().position(|p| p.0 == ptr.0) {
                let _ = q.items.remove(pos);
            }
        }
        let payload = batch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run stripe `first` and keep claiming follow-up stripes until the
/// ticket counter is exhausted.
///
/// Claim-ordering invariant: the *next* ticket is always claimed **before
/// completing the current stripe**. While a claimed stripe is
/// uncompleted, `remaining > 0`, so the submitter cannot return and the
/// batch cannot drop — making the follow-up `fetch_add` safe. Once a
/// completion might be the last (ticket exhausted), the batch is never
/// touched again: `stripes` is copied to a local and the waiter handle is
/// cloned out before the final `fetch_sub`.
fn run_claimed(batch: &Batch, first: usize) {
    let stripes = batch.stripes;
    let mut s = first;
    loop {
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (batch.call)(batch.data, s) }));
        if let Err(payload) = result {
            let mut slot = batch.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let next = batch.next.fetch_add(1, Ordering::Relaxed);
        let waiter = batch.waiter.clone();
        if batch.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Final completion: `batch` may be dropped by the submitter
            // the instant this fetch_sub lands. Only locals from here on.
            waiter.unpark();
            return;
        }
        if next >= stripes {
            return;
        }
        s = next;
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        // Claim a stripe while holding the queue lock: an entry present
        // in the queue is always live (the submitter removes its entry
        // before returning), and a successful claim keeps the batch live
        // past the unlock.
        let (ptr, first) = {
            let mut q = inner.queue.lock().unwrap();
            'scan: loop {
                if q.shutdown {
                    return;
                }
                while let Some(&p) = q.items.front() {
                    let b = unsafe { &*p.0 };
                    let s = b.next.fetch_add(1, Ordering::Relaxed);
                    if s < b.stripes {
                        break 'scan (p, s);
                    }
                    // Fully claimed: no work left to hand out.
                    let _ = q.items.pop_front();
                }
                q = inner.work_cv.wait(q).unwrap();
            }
        };
        let batch = unsafe { &*ptr.0 };
        run_claimed(batch, first);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;
    use std::time::Duration;

    #[test]
    fn every_stripe_runs_exactly_once() {
        let ex = Executor::new(3);
        for stripes in [1, 2, 3, 7, 64] {
            let counts: Vec<AtomicU32> = (0..stripes).map(|_| AtomicU32::new(0)).collect();
            let task = |s: usize| {
                counts[s].fetch_add(1, Ordering::Relaxed);
            };
            ex.run_batch(stripes, &task);
            for (s, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "stripe {s} of {stripes}");
            }
        }
    }

    #[test]
    fn oversubscribed_stripes_complete_on_small_pool() {
        let ex = Executor::new(1);
        let total = AtomicU32::new(0);
        ex.run_batch(100, &|_s| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // A stripe submitting its own batch to the same (single-worker!)
        // pool must complete: the submitter-helps rule drains it.
        let ex = Executor::new(1);
        let inner_runs = AtomicU32::new(0);
        ex.run_batch(3, &|_s| {
            ex.run_batch(4, &|_t| {
                inner_runs.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_runs.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let ex = Executor::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            ex.run_batch(8, &|s| {
                if s == 5 {
                    panic!("stripe boom");
                }
            });
        }));
        assert!(caught.is_err(), "stripe panic must reach the submitter");
        // The pool keeps working after a stripe panicked.
        let ok = AtomicU32::new(0);
        ex.run_batch(4, &|_s| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn shutdown_joins_promptly() {
        // The CI matrix gates the suite with a timeout; this is the
        // in-repo watchdog for the same hang class.
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let ex = Executor::new(4);
            ex.run_batch(16, &|_s| {});
            drop(ex);
            tx.send(()).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(30))
            .expect("executor shutdown hung");
    }

    #[test]
    fn epoch_counter_advances_per_batch() {
        let ex = Executor::new(2);
        let before = ex.epochs_dispatched();
        ex.run_batch(4, &|_s| {});
        ex.run_batch(4, &|_s| {});
        assert_eq!(ex.epochs_dispatched(), before + 2);
        // Single-stripe batches run inline and are not dispatched.
        ex.run_batch(1, &|_s| {});
        assert_eq!(ex.epochs_dispatched(), before + 2);
    }

    #[test]
    fn global_pool_is_one_instance() {
        // The frozen-thread-count property is asserted in
        // `rust/tests/alloc_stability.rs`, where no test-local pools run
        // concurrently; here we check identity and reusability.
        let a = Executor::global();
        a.run_batch(4, &|_s| {});
        for _ in 0..16 {
            let b = Executor::global();
            assert!(std::ptr::eq(a, b), "global pool must be a singleton");
            b.run_batch(8, &|_s| {});
        }
        assert!(a.threads() >= 1);
        assert!(threads_spawned_total() >= a.threads() as u64);
    }
}
