//! In-process execution runtimes.
//!
//! - [`executor`] — the persistent worker-pool executor behind every
//!   parallel fan-out in the library ([`crate::sweep::pool`] and, through
//!   it, the OCWF reorder driver). Always built; std-only.
//! - `engine` (feature `pjrt`) — the PJRT engine that loads AOT-compiled
//!   HLO-text artifacts produced by `python/compile/aot.py` and executes
//!   them on the CPU PJRT client. Gated behind the `pjrt` cargo feature
//!   because it needs the `xla` crate, which the offline, dependency-free
//!   build does not vendor; enable the feature only after adding that
//!   dependency.

pub mod executor;

#[cfg(feature = "pjrt")]
mod engine;

#[cfg(feature = "pjrt")]
pub use engine::{ArtifactIndex, Executable, PjrtRuntime};

pub use executor::Executor;
