//! PJRT runtime: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Implemented in `engine.rs`; this module re-exports the public surface.

mod engine;

pub use engine::{ArtifactIndex, Executable, PjrtRuntime};
