//! The PJRT execution engine.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): loads HLO **text**
//! artifacts — the interchange format, because jax ≥ 0.5 emits serialized
//! `HloModuleProto`s with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids — compiles them once, and
//! executes them from the rust hot path. Python never runs at request
//! time; `make artifacts` is the only compile step.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// A PJRT runtime holding the CPU client and the compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| Error::Runtime(format!("parse {path_str}: {e}")))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&computation)
            .map_err(|e| Error::Runtime(format!("compile {path_str}: {e}")))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled XLA executable. All artifacts are lowered with
/// `return_tuple=True`, so outputs arrive as one tuple literal.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given input literals; returns the flattened tuple
    /// elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result {}: {e}", self.name)))?;
        literal
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple {}: {e}", self.name)))
    }

    /// Convenience: run with `i32` tensors, returning `i32` outputs.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<Vec<i32>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| make_literal_i32(data, dims))
            .collect::<Result<Vec<_>>>()?;
        let outs = self.run(&literals)?;
        outs.into_iter()
            .map(|l| {
                l.to_vec::<i32>()
                    .map_err(|e| Error::Runtime(format!("read i32 output: {e}")))
            })
            .collect()
    }

    /// Convenience: run with `f32` tensors, returning `f32` outputs.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| make_literal_f32(data, dims))
            .collect::<Result<Vec<_>>>()?;
        let outs = self.run(&literals)?;
        outs.into_iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("read f32 output: {e}")))
            })
            .collect()
    }
}

fn make_literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {dims:?} wants {n} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::Runtime(format!("reshape: {e}")))
}

fn make_literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {dims:?} wants {n} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::Runtime(format!("reshape: {e}")))
}

/// One artifact entry from `artifacts/manifest.json` (written by
/// `python/compile/aot.py`): file name plus its static shape parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub file: String,
    pub params: BTreeMap<String, i64>,
}

/// Index over the artifacts directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactIndex {
    dir: PathBuf,
    specs: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactIndex {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactIndex> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "{} not found ({e}); run `make artifacts` first",
                manifest.display()
            ))
        })?;
        let json =
            Json::parse(&text).map_err(|e| Error::Runtime(format!("manifest parse: {e}")))?;
        let obj = match &json {
            Json::Obj(map) => map,
            _ => return Err(Error::Runtime("manifest must be an object".into())),
        };
        let mut specs = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| Error::Runtime(format!("artifact {name}: missing file")))?
                .to_string();
            let mut params = BTreeMap::new();
            if let Some(Json::Obj(p)) = entry.get("params") {
                for (k, v) in p {
                    let x = v
                        .as_f64()
                        .ok_or_else(|| Error::Runtime(format!("{name}.{k}: not a number")))?;
                    params.insert(k.clone(), x as i64);
                }
            }
            specs.insert(name.clone(), ArtifactSpec { file, params });
        }
        Ok(ArtifactIndex {
            dir: dir.to_path_buf(),
            specs,
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact `{name}`")))
    }

    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.spec(name)?.file))
    }

    pub fn param(&self, name: &str, key: &str) -> Result<i64> {
        self.spec(name)?
            .params
            .get(key)
            .copied()
            .ok_or_else(|| Error::Runtime(format!("artifact {name}: missing param {key}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(make_literal_i32(&[1, 2, 3], &[2, 2]).is_err());
        assert!(make_literal_f32(&[1.0; 4], &[2, 2]).is_ok());
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("taos_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"wf_small":{"file":"wf_small.hlo.txt","params":{"B":8,"K":8,"M":32}}}"#,
        )
        .unwrap();
        let idx = ArtifactIndex::load(&dir).unwrap();
        assert_eq!(idx.names(), vec!["wf_small"]);
        assert_eq!(idx.param("wf_small", "B").unwrap(), 8);
        assert!(idx.path_of("wf_small").unwrap().ends_with("wf_small.hlo.txt"));
        assert!(idx.spec("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = ArtifactIndex::load(Path::new("/nonexistent-taos")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
