//! OBTA — Optimal Balanced Task Assignment (paper §III-A, Algorithm 1).
//!
//! OBTA solves program `P` exactly, but only searches Φ inside the
//! narrowed window `[Φ⁻, Φ⁺]` of §III-A2. Within the window, feasibility
//! is monotone in Φ (capacity only grows), so the subrange walk of
//! §III-A3 — check sub-intervals `[Φ⁻, b'_i), [b'_i, b'_{i+1}), …` in
//! ascending order and stop at the first feasible one — is realized here
//! as a binary search that the feasibility oracle answers exactly; the
//! first feasible Φ is the global optimum, matching the paper's "the
//! remaining sub-intervals cannot contain a smaller Φ_c".

use super::bounds::{phi_lower, phi_upper};
use super::feasible::{Oracle, OracleStats, OracleWorkspace};
use super::{program_phi, Assigner, Assignment, Instance};

/// The OBTA assigner. Carries the pooled [`OracleWorkspace`] so the
/// per-arrival flow network is rebuilt into recycled arenas instead of
/// freshly allocated ones.
#[derive(Debug, Default)]
pub struct Obta {
    /// Accumulated oracle tier counters (perf telemetry).
    pub stats: OracleStats,
    ws: OracleWorkspace,
}

impl Obta {
    pub fn new() -> Self {
        Obta::default()
    }

    /// Reserved capacity of the pooled oracle arenas
    /// (allocation-stability tests).
    pub fn workspace_footprint(&self) -> usize {
        self.ws.footprint()
    }
}

impl Assigner for Obta {
    fn name(&self) -> &'static str {
        "obta"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        if inst.total_tasks() == 0 {
            return Assignment {
                per_group: vec![Vec::new(); inst.groups.len()],
                phi: 0,
            };
        }
        let lo = phi_lower(inst);
        let hi = phi_upper(inst);
        let mut oracle = Oracle::with_workspace(inst, std::mem::take(&mut self.ws));
        // Φ⁺ assumes each group can pile onto a single server; with
        // integer slots per (group, server) pair the bound can be short
        // by at most K_c − 1 slots when groups collide — search_min_phi
        // widens lazily if that ever binds.
        let (phi, per_group) = oracle.search_min_phi(lo, hi, inst.groups.len() as u64 + 1);
        self.stats.merge(&oracle.stats);
        self.ws = oracle.into_workspace();
        debug_assert_eq!(program_phi(inst, &per_group), phi);
        Assignment { per_group, phi }
    }

    fn oracle_stats(&self) -> Option<OracleStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::testutil::{brute_force_opt_phi, random_instance};
    use crate::assign::{validate_assignment, AssignPolicy};
    use crate::job::TaskGroup;
    use crate::util::rng::Rng;

    #[test]
    fn single_group_balances_perfectly() {
        let groups = vec![TaskGroup::new(12, vec![0, 1, 2])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Obta::new().assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.phi, 2);
    }

    #[test]
    fn optimal_beats_wf_on_nested_groups() {
        // Two groups, the second's servers nested in the first's. WF fills
        // greedily and stacks; OPT reserves the private servers.
        let groups = vec![
            TaskGroup::new(8, vec![0, 1, 2, 3]),
            TaskGroup::new(4, vec![2, 3]),
        ];
        let mu = vec![1, 1, 1, 1];
        let busy = vec![0, 0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let opt = Obta::new().assign(&inst);
        let wf = AssignPolicy::Wf.build(0).assign(&inst);
        validate_assignment(&inst, &opt).unwrap();
        // Total 12 tasks over 4 unit servers → Φ* = 3.
        assert_eq!(opt.phi, 3);
        // WF: group 1 levels at 2 everywhere; group 2 then stacks to 4.
        assert_eq!(wf.phi, 4);
    }

    #[test]
    fn empty_job() {
        let groups: Vec<TaskGroup> = vec![];
        let mu = vec![1];
        let busy = vec![9];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(Obta::new().assign(&inst).phi, 0);
    }

    #[test]
    fn matches_brute_force_on_random_small_instances() {
        let mut rng = Rng::seed_from(99);
        for case in 0..30 {
            let owned = random_instance(&mut rng, 3, 3, 6, 2);
            let inst = owned.view();
            let a = Obta::new().assign(&inst);
            validate_assignment(&inst, &a).unwrap();
            let brute = brute_force_opt_phi(&inst);
            assert_eq!(a.phi, brute, "case {case}: {owned:?}");
        }
    }

    #[test]
    fn never_worse_than_wf_and_rd() {
        let mut rng = Rng::seed_from(101);
        for _ in 0..60 {
            let owned = random_instance(&mut rng, 6, 4, 40, 8);
            let inst = owned.view();
            let opt = Obta::new().assign(&inst);
            let wf = AssignPolicy::Wf.build(0).assign(&inst);
            let rd = AssignPolicy::Rd.build(7).assign(&inst);
            assert!(opt.phi <= wf.phi, "OBTA {} vs WF {}", opt.phi, wf.phi);
            assert!(opt.phi <= rd.phi, "OBTA {} vs RD {}", opt.phi, rd.phi);
        }
    }
}
