//! Water-filling task assignment (paper §III-B, Algorithm 2).
//!
//! Groups are processed sequentially. For group k, the water level ξ_k is
//! the minimal integer satisfying eq. (9); every available server below
//! the level participates and receives `(ξ_k − b_m(k−1))·μ_m` tasks (the
//! last participating server takes the remainder), after which busy times
//! are raised to the level (eq. 10). WF is K_c-approximate and the bound
//! is tight (Theorems 1–2) — both facts are property-tested in
//! `rust/tests/`.
//!
//! Complexity: O(Σ_k |S_c^k| log |T_c^k|) — a binary search per group plus
//! a walk over its servers.

use crate::job::Slots;

use super::bounds::water_level;
use super::{Assigner, Assignment, Instance};

/// The WF assigner. Stateless; a fresh busy-time scratch vector is built
/// per call.
#[derive(Clone, Debug, Default)]
pub struct Wf {
    /// Scratch: per-server busy times b_m(k), reused across calls to
    /// avoid re-allocating on the hot path.
    scratch_busy: Vec<Slots>,
}

impl Wf {
    pub fn new() -> Self {
        Wf::default()
    }

    /// Assign and also return the final per-server busy times b_m(K_c)
    /// (needed by the OCWF reordering driver to accumulate state across
    /// jobs in the new order).
    pub fn assign_with_busy(&mut self, inst: &Instance) -> (Assignment, Vec<Slots>) {
        self.scratch_busy.clear();
        self.scratch_busy.extend_from_slice(inst.busy);
        let busy = &mut self.scratch_busy;

        let mut per_group = Vec::with_capacity(inst.groups.len());
        // WF's estimated completion time (paper's WF(I)): the maximum
        // estimated busy time over participating servers, i.e. the largest
        // water level reached (eq. 15 with WF = WF_{K_c}).
        let mut phi: Slots = 0;
        for g in inst.groups {
            if g.size == 0 {
                per_group.push(Vec::new());
                continue;
            }
            let xi = water_level(&g.servers, g.size, busy, inst.mu);
            phi = phi.max(xi);
            // Participating servers: estimated busy strictly below the
            // level.
            let mut remaining = g.size;
            let mut alloc = Vec::new();
            let participating: Vec<usize> = g
                .servers
                .iter()
                .copied()
                .filter(|&m| busy[m] < xi)
                .collect();
            debug_assert!(!participating.is_empty());
            for (i, &m) in participating.iter().enumerate() {
                let cap = (xi - busy[m]) * inst.mu[m];
                let take = if i + 1 == participating.len() {
                    // Last participating server: all the remaining tasks
                    // (≤ cap by minimality of ξ).
                    debug_assert!(remaining <= cap, "xi not minimal?");
                    remaining
                } else {
                    cap.min(remaining)
                };
                if take > 0 {
                    alloc.push((m, take));
                    remaining -= take;
                }
                if remaining == 0 {
                    break;
                }
            }
            debug_assert_eq!(remaining, 0);
            // eq. (10): raise participating servers to the level.
            for &m in &participating {
                busy[m] = xi;
            }
            per_group.push(alloc);
        }

        let final_busy = busy.clone();
        (Assignment { per_group, phi }, final_busy)
    }
}

impl Assigner for Wf {
    fn name(&self) -> &'static str {
        "wf"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        self.assign_with_busy(inst).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::{validate_assignment, AssignPolicy};
    use crate::job::TaskGroup;

    #[test]
    fn single_group_balances_idle_servers() {
        let groups = vec![TaskGroup::new(12, vec![0, 1, 2])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut wf = Wf::new();
        let a = wf.assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        // Level = 2 slots: every server takes 4 tasks.
        assert_eq!(a.phi, 2);
        assert_eq!(a.per_group[0], vec![(0, 4), (1, 4), (2, 4)]);
    }

    #[test]
    fn busy_server_excluded_until_level_reaches_it() {
        // Server 0 busy until slot 10; 4 tasks fit on server 1 alone.
        let groups = vec![TaskGroup::new(4, vec![0, 1])];
        let mu = vec![1, 1];
        let busy = vec![10, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.per_group[0], vec![(1, 4)]);
        assert_eq!(a.phi, 4);
    }

    #[test]
    fn sequential_groups_stack() {
        // Group 1 fills servers {0,1} to level 2; group 2 on {1,2} then
        // sees server 1 at 2.
        let groups = vec![
            TaskGroup::new(4, vec![0, 1]),
            TaskGroup::new(4, vec![1, 2]),
        ];
        let mu = vec![1, 1, 1];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let (a, final_busy) = Wf::new().assign_with_busy(&inst);
        validate_assignment(&inst, &a).unwrap();
        // Group 1: level 2, 2 tasks each on 0 and 1.
        assert_eq!(a.per_group[0], vec![(0, 2), (1, 2)]);
        // Group 2: server 1 at 2, server 2 at 0. Level 3: (3-2) + 3 = 4 ≥ 4.
        assert_eq!(a.per_group[1], vec![(1, 1), (2, 3)]);
        assert_eq!(final_busy, vec![2, 3, 3]);
        assert_eq!(a.phi, 3);
    }

    #[test]
    fn empty_groups_skipped() {
        let groups = vec![TaskGroup::new(0, vec![0]), TaskGroup::new(2, vec![0])];
        let mu = vec![1];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        assert!(a.per_group[0].is_empty());
        assert_eq!(a.per_group[1], vec![(0, 2)]);
        assert_eq!(a.phi, 2);
    }

    #[test]
    fn theorem1_instance_ratio_approaches_kc() {
        // The Thm-1 construction: K groups, θ ≥ 2,
        // |S_k| = Σ_{k'=1..K-k+1} θ^{k'}, nested S_1 ⊃ S_2 ⊃ … ⊃ S_K,
        // |T_k| = θ·|S_k|, μ ≡ 1, b ≡ 0. WF yields K·θ; OPT yields θ+2.
        let theta: u64 = 4;
        let k_c = 3usize;
        let sizes: Vec<u64> = (1..=k_c)
            .map(|k| (1..=(k_c - k + 1) as u32).map(|e| theta.pow(e)).sum())
            .collect();
        let m_total = sizes[0] as usize;
        // S_k = the first |S_k| servers (nested).
        let groups: Vec<TaskGroup> = (0..k_c)
            .map(|k| {
                TaskGroup::new(theta * sizes[k], (0..sizes[k] as usize).collect())
            })
            .collect();
        let mu = vec![1u64; m_total];
        let busy = vec![0u64; m_total];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        // WF fills every group across all its servers: θ slots per group,
        // stacked K_c deep on the innermost servers.
        assert_eq!(a.phi, k_c as u64 * theta, "WF = K_c·θ on the construction");
        // The optimum (θ+2, eq. 13) is achievable — check with OBTA.
        let mut obta = AssignPolicy::Obta.build(0);
        let opt = obta.assign(&inst);
        validate_assignment(&inst, &opt).unwrap();
        assert_eq!(opt.phi, theta + 2, "OPT = θ+2 on the construction");
    }
}
