//! Water-filling task assignment (paper §III-B, Algorithm 2).
//!
//! Groups are processed sequentially. For group k, the water level ξ_k is
//! the minimal integer satisfying eq. (9); every available server below
//! the level participates and receives `(ξ_k − b_m(k−1))·μ_m` tasks (the
//! last participating server takes the remainder), after which busy times
//! are raised to the level (eq. 10). WF is K_c-approximate and the bound
//! is tight (Theorems 1–2) — both facts are property-tested in
//! `rust/tests/`.
//!
//! Complexity: O(Σ_k |S_c^k| log |T_c^k|) — a binary search per group plus
//! a walk over its servers.
//!
//! ## Zero-allocation hot path
//!
//! WF is the inner loop of the OCWF reordering driver (one evaluation per
//! candidate per round, §IV), so the steady-state path must not touch the
//! allocator. [`Wf::assign_into`] writes into a caller-owned
//! [`WfOutcome`] whose buffers (per-group allocation lists, final busy
//! vector) are reused across calls; the internal scratch (busy vector,
//! participating-server list) is pooled in the `Wf` value. After warmup
//! no call allocates — asserted by the capacity-stability test in
//! `rust/tests/alloc_stability.rs`. The [`Assigner`] entry point and
//! [`Wf::assign_with_busy`] wrap `assign_into` and clone the outcome into
//! owned values for callers that want them.

use crate::job::{ServerId, Slots, TaskCount};

use super::bounds::water_level;
use super::{Assigner, Assignment, Instance};

/// A reusable WF evaluation result: the per-group allocation, the WF
/// estimate Φ, and the post-assignment busy vector `b_m(K_c)`. The
/// per-group buffer pool never shrinks (`groups_len` tracks the logical
/// arity), so alternating between jobs of different shapes stays
/// allocation-free once warmed.
#[derive(Clone, Debug, Default)]
pub struct WfOutcome {
    /// Physical row pool; rows `0..groups_len` are the live allocation.
    per_group: Vec<Vec<(ServerId, TaskCount)>>,
    groups_len: usize,
    /// WF's estimated completion time (the largest water level reached).
    pub phi: Slots,
    final_busy: Vec<Slots>,
}

impl WfOutcome {
    /// `per_group()[k]` lists `(server, tasks)` with tasks > 0, aligned
    /// with the instance's groups.
    pub fn per_group(&self) -> &[Vec<(ServerId, TaskCount)>] {
        &self.per_group[..self.groups_len]
    }

    /// Final per-server busy times `b_m(K_c)` after this assignment.
    pub fn final_busy(&self) -> &[Slots] {
        &self.final_busy
    }

    /// Clone into an owned [`Assignment`].
    pub fn to_assignment(&self) -> Assignment {
        Assignment {
            per_group: self.per_group().to_vec(),
            phi: self.phi,
        }
    }

    /// Copy into an existing [`Assignment`], reusing its nested buffers.
    pub fn write_assignment(&self, dst: &mut Assignment) {
        dst.phi = self.phi;
        let src = self.per_group();
        dst.per_group.truncate(src.len());
        for (d, s) in dst.per_group.iter_mut().zip(src) {
            d.clear();
            d.extend_from_slice(s);
        }
        while dst.per_group.len() < src.len() {
            dst.per_group.push(src[dst.per_group.len()].clone());
        }
    }

    /// Reserved capacity of every internal buffer (allocation-stability
    /// tests).
    pub fn footprint(&self) -> usize {
        self.final_busy.capacity()
            + self.per_group.capacity()
            + self.per_group.iter().map(|g| g.capacity()).sum::<usize>()
    }

    /// Prepare for `k` groups: grow the row pool as needed, clear the
    /// live rows, keep every allocation.
    fn begin(&mut self, k: usize) {
        while self.per_group.len() < k {
            self.per_group.push(Vec::new());
        }
        for row in self.per_group.iter_mut().take(k) {
            row.clear();
        }
        self.groups_len = k;
        self.phi = 0;
    }
}

/// The WF assigner with its pooled scratch (busy vector, participating
/// list, and a spare outcome backing the owned-result wrappers).
#[derive(Clone, Debug, Default)]
pub struct Wf {
    /// Scratch: per-server busy times b_m(k), reused across calls.
    scratch_busy: Vec<Slots>,
    /// Scratch: the group's participating servers (busy < level).
    participating: Vec<ServerId>,
    /// Backing buffer for the cloning wrappers ([`Wf::assign_with_busy`]).
    outcome: WfOutcome,
}

impl Wf {
    pub fn new() -> Self {
        Wf::default()
    }

    /// Run WF and write the result into `out`, reusing both the caller's
    /// outcome buffers and the internal scratch — the allocation-free
    /// steady-state path.
    pub fn assign_into(&mut self, inst: &Instance, out: &mut WfOutcome) {
        let busy = &mut self.scratch_busy;
        let participating = &mut self.participating;
        busy.clear();
        busy.extend_from_slice(inst.busy);
        out.begin(inst.groups.len());

        for (gi, g) in inst.groups.iter().enumerate() {
            if g.size == 0 {
                continue; // row gi stays empty
            }
            let xi = water_level(&g.servers, g.size, busy, inst.mu);
            out.phi = out.phi.max(xi);
            // Participating servers: estimated busy strictly below the
            // level.
            let mut remaining = g.size;
            participating.clear();
            participating.extend(g.servers.iter().copied().filter(|&m| busy[m] < xi));
            debug_assert!(!participating.is_empty());
            let alloc = &mut out.per_group[gi];
            for (i, &m) in participating.iter().enumerate() {
                let cap = (xi - busy[m]) * inst.mu[m];
                let take = if i + 1 == participating.len() {
                    // Last participating server: all the remaining tasks
                    // (≤ cap by minimality of ξ).
                    debug_assert!(remaining <= cap, "xi not minimal?");
                    remaining
                } else {
                    cap.min(remaining)
                };
                if take > 0 {
                    alloc.push((m, take));
                    remaining -= take;
                }
                if remaining == 0 {
                    break;
                }
            }
            debug_assert_eq!(remaining, 0);
            // eq. (10): raise participating servers to the level.
            for &m in participating.iter() {
                busy[m] = xi;
            }
        }

        out.final_busy.clear();
        out.final_busy.extend_from_slice(busy);
    }

    /// Assign and also return the final per-server busy times b_m(K_c)
    /// as owned values (clones of the pooled outcome).
    pub fn assign_with_busy(&mut self, inst: &Instance) -> (Assignment, Vec<Slots>) {
        let mut out = std::mem::take(&mut self.outcome);
        self.assign_into(inst, &mut out);
        let assignment = out.to_assignment();
        let final_busy = out.final_busy.clone();
        self.outcome = out;
        (assignment, final_busy)
    }

    /// Reserved capacity of the internal scratch (allocation-stability
    /// tests).
    pub fn scratch_footprint(&self) -> usize {
        self.scratch_busy.capacity() + self.participating.capacity() + self.outcome.footprint()
    }
}

impl Assigner for Wf {
    fn name(&self) -> &'static str {
        "wf"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        self.assign_with_busy(inst).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::{validate_assignment, AssignPolicy};
    use crate::job::TaskGroup;

    #[test]
    fn single_group_balances_idle_servers() {
        let groups = vec![TaskGroup::new(12, vec![0, 1, 2])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut wf = Wf::new();
        let a = wf.assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        // Level = 2 slots: every server takes 4 tasks.
        assert_eq!(a.phi, 2);
        assert_eq!(a.per_group[0], vec![(0, 4), (1, 4), (2, 4)]);
    }

    #[test]
    fn busy_server_excluded_until_level_reaches_it() {
        // Server 0 busy until slot 10; 4 tasks fit on server 1 alone.
        let groups = vec![TaskGroup::new(4, vec![0, 1])];
        let mu = vec![1, 1];
        let busy = vec![10, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.per_group[0], vec![(1, 4)]);
        assert_eq!(a.phi, 4);
    }

    #[test]
    fn sequential_groups_stack() {
        // Group 1 fills servers {0,1} to level 2; group 2 on {1,2} then
        // sees server 1 at 2.
        let groups = vec![
            TaskGroup::new(4, vec![0, 1]),
            TaskGroup::new(4, vec![1, 2]),
        ];
        let mu = vec![1, 1, 1];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let (a, final_busy) = Wf::new().assign_with_busy(&inst);
        validate_assignment(&inst, &a).unwrap();
        // Group 1: level 2, 2 tasks each on 0 and 1.
        assert_eq!(a.per_group[0], vec![(0, 2), (1, 2)]);
        // Group 2: server 1 at 2, server 2 at 0. Level 3: (3-2) + 3 = 4 ≥ 4.
        assert_eq!(a.per_group[1], vec![(1, 1), (2, 3)]);
        assert_eq!(final_busy, vec![2, 3, 3]);
        assert_eq!(a.phi, 3);
    }

    #[test]
    fn empty_groups_skipped() {
        let groups = vec![TaskGroup::new(0, vec![0]), TaskGroup::new(2, vec![0])];
        let mu = vec![1];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        assert!(a.per_group[0].is_empty());
        assert_eq!(a.per_group[1], vec![(0, 2)]);
        assert_eq!(a.phi, 2);
    }

    #[test]
    fn assign_into_reuses_buffers_across_shapes() {
        // Alternating between a 3-group and a 1-group job must keep the
        // outcome's row pool intact (logical arity shrinks, capacity
        // does not) and keep results correct.
        let big = vec![
            TaskGroup::new(4, vec![0, 1]),
            TaskGroup::new(2, vec![1]),
            TaskGroup::new(3, vec![0]),
        ];
        let small = vec![TaskGroup::new(5, vec![0, 1])];
        let mu = vec![1, 1];
        let busy = vec![0, 0];
        let mut wf = Wf::new();
        let mut out = WfOutcome::default();
        for _ in 0..3 {
            let inst = Instance {
                groups: &big,
                mu: &mu,
                busy: &busy,
            };
            wf.assign_into(&inst, &mut out);
            assert_eq!(out.per_group().len(), 3);
            let a = out.to_assignment();
            validate_assignment(&inst, &a).unwrap();

            let inst = Instance {
                groups: &small,
                mu: &mu,
                busy: &busy,
            };
            wf.assign_into(&inst, &mut out);
            assert_eq!(out.per_group().len(), 1);
            let a = out.to_assignment();
            validate_assignment(&inst, &a).unwrap();
            assert_eq!(a.phi, 3); // 5 tasks over two μ=1 servers
        }
    }

    #[test]
    fn write_assignment_matches_to_assignment() {
        let groups = vec![
            TaskGroup::new(6, vec![0, 1, 2]),
            TaskGroup::new(2, vec![2]),
        ];
        let mu = vec![2, 2, 2];
        let busy = vec![1, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut wf = Wf::new();
        let mut out = WfOutcome::default();
        wf.assign_into(&inst, &mut out);
        let owned = out.to_assignment();
        // Write into a dirty, differently-shaped assignment.
        let mut reused = Assignment {
            per_group: vec![vec![(9, 9)], vec![(8, 8)], vec![(7, 7)]],
            phi: 99,
        };
        out.write_assignment(&mut reused);
        assert_eq!(owned, reused);
    }

    #[test]
    fn theorem1_instance_ratio_approaches_kc() {
        // The Thm-1 construction: K groups, θ ≥ 2,
        // |S_k| = Σ_{k'=1..K-k+1} θ^{k'}, nested S_1 ⊃ S_2 ⊃ … ⊃ S_K,
        // |T_k| = θ·|S_k|, μ ≡ 1, b ≡ 0. WF yields K·θ; OPT yields θ+2.
        let theta: u64 = 4;
        let k_c = 3usize;
        let sizes: Vec<u64> = (1..=k_c)
            .map(|k| (1..=(k_c - k + 1) as u32).map(|e| theta.pow(e)).sum())
            .collect();
        let m_total = sizes[0] as usize;
        // S_k = the first |S_k| servers (nested).
        let groups: Vec<TaskGroup> = (0..k_c)
            .map(|k| {
                TaskGroup::new(theta * sizes[k], (0..sizes[k] as usize).collect())
            })
            .collect();
        let mu = vec![1u64; m_total];
        let busy = vec![0u64; m_total];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Wf::new().assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        // WF fills every group across all its servers: θ slots per group,
        // stacked K_c deep on the innermost servers.
        assert_eq!(a.phi, k_c as u64 * theta, "WF = K_c·θ on the construction");
        // The optimum (θ+2, eq. 13) is achievable — check with OBTA.
        let mut obta = AssignPolicy::Obta.build(0);
        let opt = obta.assign(&inst);
        validate_assignment(&inst, &opt).unwrap();
        assert_eq!(opt.phi, theta + 2, "OPT = θ+2 on the construction");
    }
}
