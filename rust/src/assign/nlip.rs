//! NLIP — the paper's unnarrowed baseline (§V-A, "Algorithms").
//!
//! NLIP solves the same non-linear integer program `P` as OBTA but
//! "directly, without narrowing the search space of Φ_c and dividing it
//! into subranges". We model the absent narrowing by searching Φ over the
//! *trivial* window `[1, Φ⁺_trivial]` (the widest bracket a solver can
//! assume without §III-A2's analysis), using the same exact feasibility
//! oracle. NLIP therefore finds the identical optimum as OBTA — the two
//! curves coincide in Figs 10–12 — while paying roughly twice the
//! computation, which is precisely the efficiency gap the paper reports.

use super::bounds::phi_upper_trivial;
use super::feasible::{Oracle, OracleStats, OracleWorkspace};
use super::{Assigner, Assignment, Instance};

/// The NLIP assigner. Like OBTA it pools an [`OracleWorkspace`] across
/// arrivals.
#[derive(Debug, Default)]
pub struct Nlip {
    pub stats: OracleStats,
    ws: OracleWorkspace,
}

impl Nlip {
    pub fn new() -> Self {
        Nlip::default()
    }

    /// Reserved capacity of the pooled oracle arenas
    /// (allocation-stability tests).
    pub fn workspace_footprint(&self) -> usize {
        self.ws.footprint()
    }
}

impl Assigner for Nlip {
    fn name(&self) -> &'static str {
        "nlip"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        if inst.total_tasks() == 0 {
            return Assignment {
                per_group: vec![Vec::new(); inst.groups.len()],
                phi: 0,
            };
        }
        let hi = phi_upper_trivial(inst);
        let mut oracle = Oracle::with_workspace(inst, std::mem::take(&mut self.ws));
        let (phi, per_group) = oracle.search_min_phi(1, hi, inst.groups.len() as u64 + 1);
        self.stats.merge(&oracle.stats);
        self.ws = oracle.into_workspace();
        Assignment { per_group, phi }
    }

    fn oracle_stats(&self) -> Option<OracleStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::testutil::random_instance;
    use crate::assign::{validate_assignment, AssignPolicy};
    use crate::util::rng::Rng;

    #[test]
    fn nlip_and_obta_agree_on_phi() {
        let mut rng = Rng::seed_from(111);
        for case in 0..50 {
            let owned = random_instance(&mut rng, 6, 4, 30, 6);
            let inst = owned.view();
            let n = Nlip::new().assign(&inst);
            let o = AssignPolicy::Obta.build(0).assign(&inst);
            validate_assignment(&inst, &n).unwrap();
            assert_eq!(n.phi, o.phi, "case {case}: {owned:?}");
        }
    }

    #[test]
    fn nlip_empty_job() {
        let groups: Vec<crate::job::TaskGroup> = vec![];
        let mu = vec![2];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(Nlip::new().assign(&inst).phi, 0);
    }
}
