//! Join-shortest-estimated-queue baselines.
//!
//! [`Jsq`] is the classic locality-oblivious policy (Winston 1977): each
//! task group joins the server with the shortest *estimated completion*
//! among its available servers, whole group at once. [`JsqAffinity`] is
//! the affinity-scheduling variant (arXiv 1705.03125): work is routed in
//! capacity-sized chunks to the shortest queue *among replica holders*,
//! spilling to the full eligible set only when every holder's queue is
//! strictly longer than the global shortest — JSQ with overflow
//! fallback.
//!
//! Both are deterministic pure functions of the [`Instance`] (integer
//! arithmetic, no RNG), so the analytic and DES engines produce
//! bit-identical schedules for free. Selection keys order by
//! `(queue, fastest-μ, server id)` so that whenever `(busy, μ)` pairs
//! are pairwise distinguishable the choice is label-independent — the
//! property the metamorphic relabeling suite pins down.

use std::cmp::Reverse;

use super::{Assigner, Assignment, Instance};
use crate::job::{ServerId, Slots, TaskCount};
use crate::util::ceil_div;

/// Shortest-queue server of `set`: minimal `(eff, Reverse(μ), id)` —
/// shortest estimated queue, faster server on ties, lowest id last.
pub(super) fn shortest_queue(eff: &[Slots], mu: &[u64], set: &[ServerId]) -> ServerId {
    let mut best: Option<(Slots, Reverse<u64>, ServerId)> = None;
    for &s in set {
        let key = (eff[s], Reverse(mu[s]), s);
        if best.map_or(true, |b| key < b) {
            best = Some(key);
        }
    }
    best.expect("non-empty server set").2
}

/// Emit one group's accumulated per-server counts as a sorted sparse
/// row, resetting the touched counters (the pooled-workspace contract:
/// `counts` is all-zero between groups).
pub(super) fn emit_row(
    counts: &mut [TaskCount],
    servers: &[ServerId],
) -> Vec<(ServerId, TaskCount)> {
    let mut row = Vec::new();
    for &s in servers {
        if counts[s] > 0 {
            row.push((s, counts[s]));
            counts[s] = 0;
        }
    }
    row
}

/// Locality-oblivious join-shortest-estimated-queue: every group goes,
/// whole, to the available server minimizing its estimated completion
/// `eff_m + ceil(n/μ_m)` (self-load aware across the job's groups).
pub struct Jsq {
    eff: Vec<Slots>,
}

impl Jsq {
    pub fn new() -> Self {
        Jsq { eff: Vec::new() }
    }

    /// Reserved workspace capacity (allocation-stability tests).
    pub fn scratch_footprint(&self) -> usize {
        self.eff.capacity()
    }
}

impl Default for Jsq {
    fn default() -> Self {
        Self::new()
    }
}

impl Assigner for Jsq {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        self.eff.clear();
        self.eff.extend_from_slice(inst.busy);
        let mut per_group = Vec::with_capacity(inst.groups.len());
        let mut phi: Slots = 0;
        for g in inst.groups {
            if g.size == 0 {
                per_group.push(Vec::new());
                continue;
            }
            let mut best: Option<(Slots, Slots, Reverse<u64>, ServerId)> = None;
            for &s in &g.servers {
                let est = self.eff[s] + ceil_div(g.size, inst.mu[s]);
                let key = (est, self.eff[s], Reverse(inst.mu[s]), s);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
            let (est, _, _, s) = best.expect("non-empty group server set");
            self.eff[s] = est;
            phi = phi.max(est);
            per_group.push(vec![(s, g.size)]);
        }
        Assignment { per_group, phi }
    }
}

/// JSQ restricted to replica holders with overflow fallback: work is
/// routed chunk by chunk (one slot's worth, `μ_m` tasks) to the
/// shortest-queue *holder*; when every holder's queue is strictly longer
/// than the global shortest among the group's eligible servers, the
/// chunk overflows to that global shortest instead. Under the flat model
/// (holders == eligible set) this degenerates to chunked JSQ.
pub struct JsqAffinity {
    eff: Vec<Slots>,
    counts: Vec<TaskCount>,
}

impl JsqAffinity {
    pub fn new() -> Self {
        JsqAffinity {
            eff: Vec::new(),
            counts: Vec::new(),
        }
    }

    /// Reserved workspace capacity (allocation-stability tests).
    pub fn scratch_footprint(&self) -> usize {
        self.eff.capacity() + self.counts.capacity()
    }
}

impl Default for JsqAffinity {
    fn default() -> Self {
        Self::new()
    }
}

impl Assigner for JsqAffinity {
    fn name(&self) -> &'static str {
        "jsq-affinity"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        let m = inst.busy.len();
        self.eff.clear();
        self.eff.extend_from_slice(inst.busy);
        self.counts.resize(m, 0);
        let mut per_group = Vec::with_capacity(inst.groups.len());
        let mut phi: Slots = 0;
        for g in inst.groups {
            if g.size == 0 {
                per_group.push(Vec::new());
                continue;
            }
            let holders = g.holders();
            let mut remaining = g.size;
            while remaining > 0 {
                let local = shortest_queue(&self.eff, inst.mu, holders);
                let global = shortest_queue(&self.eff, inst.mu, &g.servers);
                // A holder matching the global shortest queue keeps the
                // chunk local; otherwise it overflows.
                let target = if self.eff[local] == self.eff[global] {
                    local
                } else {
                    global
                };
                let chunk = remaining.min(inst.mu[target]);
                self.counts[target] += chunk;
                self.eff[target] += 1;
                phi = phi.max(self.eff[target]);
                remaining -= chunk;
            }
            per_group.push(emit_row(&mut self.counts, &g.servers));
        }
        Assignment { per_group, phi }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{program_phi, validate_assignment};
    use super::*;
    use crate::job::TaskGroup;

    fn inst<'a>(groups: &'a [TaskGroup], mu: &'a [u64], busy: &'a [Slots]) -> Instance<'a> {
        Instance { groups, mu, busy }
    }

    #[test]
    fn jsq_joins_shortest_estimated_completion() {
        // Server 1 has the longer queue but is fast enough to win on the
        // completion estimate: 3 + ceil(8/8) = 4 < 0 + ceil(8/1) = 8.
        let groups = vec![TaskGroup::new(8, vec![0, 1])];
        let mu = vec![1, 8];
        let busy = vec![0, 3];
        let mut a = Jsq::new();
        let out = a.assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(1, 8)]]);
        assert_eq!(out.phi, 4);
    }

    #[test]
    fn jsq_ties_prefer_faster_then_lower_id() {
        // Equal estimates and queues: the faster server wins.
        let groups = vec![TaskGroup::new(4, vec![0, 1])];
        let mu = vec![2, 4];
        let busy = vec![1, 2];
        // est0 = 1 + 2 = 3, est1 = 2 + 1 = 3; eff0 = 1 < eff1 = 2.
        let out = Jsq::new().assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(0, 4)]]);
        // Fully symmetric servers: lowest id.
        let groups = vec![TaskGroup::new(4, vec![2, 1])];
        let mu = vec![3, 3, 3];
        let busy = vec![0, 0, 0];
        let out = Jsq::new().assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(1, 4)]]);
    }

    #[test]
    fn jsq_is_self_load_aware_across_groups() {
        // Two identical groups, two symmetric servers: the second group
        // must see the first group's load and take the other server.
        let groups = vec![TaskGroup::new(3, vec![0, 1]), TaskGroup::new(3, vec![0, 1])];
        let mu = vec![3, 3];
        let busy = vec![0, 0];
        let out = Jsq::new().assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(0, 3)], vec![(1, 3)]]);
        assert_eq!(out.phi, 1);
    }

    #[test]
    fn affinity_stays_local_until_holders_overflow() {
        // Group eligible on {0,1,2} but only 0 holds a replica. With the
        // holder idle, chunks go local; once its queue passes the best
        // remote queue, chunks spill.
        let groups = vec![TaskGroup::with_local(6, vec![0, 1, 2], vec![0])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 1, 1];
        let out = JsqAffinity::new().assign(&inst(&groups, &mu, &busy));
        // Chunks of 2: s0 (eff 0→1), s0 ties global min 1 (holders win
        // ties) → s0 (1→2), now best remote eff is 1 < 2 → spill to s1.
        assert_eq!(out.per_group, vec![vec![(0, 4), (1, 2)]]);
        assert_eq!(out.phi, 2);
        let v = validate_assignment(&inst(&groups, &mu, &busy), &out);
        assert!(v.is_ok(), "{v:?}");
    }

    #[test]
    fn affinity_without_local_set_is_chunked_jsq() {
        // Flat model: holders == servers, so the overflow rule never
        // fires and the allocation water-levels across the set.
        let groups = vec![TaskGroup::new(9, vec![0, 1, 2])];
        let mu = vec![3, 3, 3];
        let busy = vec![0, 0, 0];
        let out = JsqAffinity::new().assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(0, 3), (1, 3), (2, 3)]]);
        assert_eq!(out.phi, 1);
    }

    #[test]
    fn phi_is_exact_program_phi_on_random_instances() {
        use crate::assign::testutil::random_instance;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0x15_0_5);
        for _ in 0..300 {
            let oi = random_instance(&mut rng, 6, 4, 12, 6);
            let inst = oi.view();
            for out in [
                Jsq::new().assign(&inst),
                JsqAffinity::new().assign(&inst),
            ] {
                validate_assignment(&inst, &out).unwrap();
                assert_eq!(out.phi, program_phi(&inst, &out.per_group));
            }
        }
    }
}
