//! A small exact integer-feasibility solver — the stand-in for CPLEX.
//!
//! The paper solves program `P` (eq. 4) with a commercial solver. At a
//! fixed candidate Φ the program becomes a pure *integer feasibility*
//! question over the slot counts `n_m^k`:
//!
//! ```text
//!   Σ_k  y_{k,m}        ≤ cap_m   for every server m   (slot budget)
//!   Σ_m  μ_m · y_{k,m}  ≥ T_k     for every group  k   (task coverage)
//!   y ≥ 0, integer
//! ```
//!
//! The LP relaxation is *not* integral (slots cannot be shared between
//! groups: with cap = 1 slot, μ = 4 and two groups demanding 2 tasks each,
//! the LP is feasible but the IP is not), so a real solver is needed:
//! phase-1 dense simplex (Bland's rule, guaranteed termination) plus
//! depth-first branch-and-bound on fractional variables. Instances here
//! are tiny (K·|S| ≲ a few hundred variables) and near-integral, so the
//! tree rarely branches more than a handful of nodes.

/// Row sense of a linear constraint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
}

/// A linear constraint `Σ coef_i · x_i  (≤|≥)  rhs` over sparse columns.
#[derive(Clone, Debug)]
pub struct Constraint {
    /// (variable index, coefficient) pairs.
    pub terms: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// Outcome of the integer feasibility search.
#[derive(Clone, Debug, PartialEq)]
pub enum IlpOutcome {
    /// A feasible integer point (values per variable).
    Feasible(Vec<u64>),
    Infeasible,
    /// The node budget ran out before a certificate either way. Callers
    /// treat this conservatively (as infeasible — the search then settles
    /// on a slightly larger, still-valid Φ) and count it in telemetry.
    Unknown,
}

const EPS: f64 = 1e-7;
/// Default B&B node budget. Program-`P` feasibility is NP-hard in general
/// (the paper hands it to CPLEX, which has the same worst case); the
/// budget bounds the tail while the flow/floor tiers keep it from being
/// reached in practice. 2k nodes decide every brute-force-checkable
/// instance we generate; see EXPERIMENTS.md §Perf for the tier telemetry.
pub const DEFAULT_NODE_LIMIT: usize = 100;

/// Integer feasibility of the given system with `nvars` non-negative
/// integer variables, within a B&B node budget.
pub fn ilp_feasible(nvars: usize, constraints: &[Constraint]) -> IlpOutcome {
    ilp_feasible_budget(nvars, constraints, DEFAULT_NODE_LIMIT)
}

/// [`ilp_feasible`] with an explicit node budget.
pub fn ilp_feasible_budget(
    nvars: usize,
    constraints: &[Constraint],
    budget: usize,
) -> IlpOutcome {
    let mut nodes = 0usize;
    let mut extra: Vec<Constraint> = Vec::new();
    match branch(nvars, constraints, &mut extra, &mut nodes, budget) {
        Ok(Some(sol)) => IlpOutcome::Feasible(sol),
        Ok(None) => IlpOutcome::Infeasible,
        Err(()) => IlpOutcome::Unknown,
    }
}

/// `Err(())` = budget exhausted (undecided).
fn branch(
    nvars: usize,
    base: &[Constraint],
    extra: &mut Vec<Constraint>,
    nodes: &mut usize,
    budget: usize,
) -> Result<Option<Vec<u64>>, ()> {
    *nodes += 1;
    if *nodes > budget {
        return Err(());
    }
    let Some(relax) = lp_feasible_point2(nvars, base, extra) else {
        return Ok(None);
    };

    // Find the most fractional variable.
    let mut pick: Option<(usize, f64)> = None;
    for (i, &v) in relax.iter().enumerate() {
        let frac = (v - v.round()).abs();
        if frac > EPS {
            let dist = (v.fract() - 0.5).abs();
            match pick {
                Some((_, best_dist)) if best_dist <= dist => {}
                _ => pick = Some((i, dist)),
            }
        }
    }
    let Some((bi, _)) = pick else {
        // Integral (within tolerance) — round and return.
        return Ok(Some(
            relax.iter().map(|&v| v.round().max(0.0) as u64).collect(),
        ));
    };

    let v = relax[bi];
    // Branch UP first: y_bi >= ceil(v). For pure covering/packing
    // feasibility, rounding demand-side variables up reaches integer
    // points faster than shaving them down.
    extra.push(Constraint {
        terms: vec![(bi, 1.0)],
        sense: Sense::Ge,
        rhs: v.ceil(),
    });
    let up = branch(nvars, base, extra, nodes, budget);
    extra.pop();
    match up {
        Ok(Some(sol)) => return Ok(Some(sol)),
        Err(()) => return Err(()),
        Ok(None) => {}
    }
    // Branch DOWN: y_bi <= floor(v).
    extra.push(Constraint {
        terms: vec![(bi, 1.0)],
        sense: Sense::Le,
        rhs: v.floor(),
    });
    let down = branch(nvars, base, extra, nodes, budget);
    extra.pop();
    down
}

/// Phase-1 simplex: return a feasible point of the LP relaxation (x ≥ 0),
/// or `None` if the LP itself is infeasible.
pub fn lp_feasible_point(nvars: usize, constraints: &[Constraint]) -> Option<Vec<f64>> {
    lp_feasible_point2(nvars, constraints, &[])
}

/// [`lp_feasible_point`] over two constraint slices (avoids concatenating
/// base constraints with branching bounds on every B&B node).
pub fn lp_feasible_point2(
    nvars: usize,
    base: &[Constraint],
    extra: &[Constraint],
) -> Option<Vec<f64>> {
    // Standard form: every row becomes an equality with slack (Le) or
    // surplus+artificial (Ge). Rows with negative rhs are flipped first.
    let nrows = base.len() + extra.len();
    if nrows == 0 {
        return Some(vec![0.0; nvars]);
    }

    // Normalize rows to non-negative rhs.
    let mut rows: Vec<(Vec<(usize, f64)>, Sense, f64)> = base
        .iter()
        .chain(extra.iter())
        .map(|c| {
            if c.rhs < 0.0 {
                let terms = c.terms.iter().map(|&(i, a)| (i, -a)).collect();
                let sense = match c.sense {
                    Sense::Le => Sense::Ge,
                    Sense::Ge => Sense::Le,
                };
                (terms, sense, -c.rhs)
            } else {
                (c.terms.clone(), c.sense, c.rhs)
            }
        })
        .collect();

    // Column layout: [x (nvars)] [slack/surplus (nrows)] [artificial (na)].
    // Le rows get slack (+1, basic). Ge rows get surplus (-1) + artificial
    // (+1, basic). Ge rows with rhs == 0 can use the surplus as... the
    // surplus has coefficient -1 so it cannot be basic at rhs 0 without
    // negativity; keep the artificial uniformly for simplicity.
    let mut n_art = 0;
    for (_, sense, _) in rows.iter() {
        if *sense == Sense::Ge {
            n_art += 1;
        }
    }
    let ncols = nvars + nrows + n_art;
    // Dense tableau: nrows x (ncols + 1 rhs), plus objective row.
    let mut t = vec![vec![0.0f64; ncols + 1]; nrows];
    let mut basis = vec![0usize; nrows];
    let mut art_cols: Vec<usize> = Vec::with_capacity(n_art);
    let mut next_art = nvars + nrows;
    for (r, (terms, sense, rhs)) in rows.drain(..).enumerate() {
        for (i, a) in terms {
            debug_assert!(i < nvars, "variable index out of range");
            t[r][i] += a;
        }
        t[r][ncols] = rhs;
        match sense {
            Sense::Le => {
                t[r][nvars + r] = 1.0;
                basis[r] = nvars + r;
            }
            Sense::Ge => {
                t[r][nvars + r] = -1.0; // surplus
                t[r][next_art] = 1.0; // artificial
                basis[r] = next_art;
                art_cols.push(next_art);
                next_art += 1;
            }
        }
    }

    // Phase-1 objective: minimize Σ artificials. Objective row z holds
    // reduced costs; start with z = Σ (rows with artificial basis).
    let mut z = vec![0.0f64; ncols + 1];
    for r in 0..nrows {
        if basis[r] >= nvars + nrows {
            for c in 0..=ncols {
                z[c] += t[r][c];
            }
        }
    }
    // Reduced cost of basic artificials must be zeroed: by construction
    // z[artificial col] = 1 from its own row; subtract cost vector (cost 1
    // on artificials) => handled implicitly: we seek to drive z[rhs] to 0
    // by pivoting on columns with positive z-coefficient.
    for &ac in &art_cols {
        z[ac] = 0.0;
    }

    // Simplex iterations. Dantzig's rule (most positive reduced cost)
    // for speed, falling back to Bland's rule (smallest index — finite by
    // the anti-cycling theorem) if the iteration count suggests cycling.
    let bland_after = 50 * (nrows + ncols);
    let mut iters = 0usize;
    loop {
        iters += 1;
        let use_bland = iters > bland_after;
        // Entering column among structural + slack/surplus columns
        // (artificials never re-enter in phase 1).
        let mut enter = None;
        if use_bland {
            for c in 0..nvars + nrows {
                if z[c] > EPS {
                    enter = Some(c);
                    break;
                }
            }
        } else {
            let mut best = EPS;
            for c in 0..nvars + nrows {
                if z[c] > best {
                    best = z[c];
                    enter = Some(c);
                }
            }
        }
        let Some(e) = enter else { break };
        // Ratio test.
        let mut leave: Option<(usize, f64)> = None;
        for r in 0..nrows {
            if t[r][e] > EPS {
                let ratio = t[r][ncols] / t[r][e];
                match leave {
                    Some((lr, lratio)) => {
                        if ratio < lratio - EPS
                            || (ratio < lratio + EPS && basis[r] < basis[lr])
                        {
                            leave = Some((r, ratio));
                        }
                    }
                    None => leave = Some((r, ratio)),
                }
            }
        }
        let Some((lr, _)) = leave else {
            // Unbounded in phase 1 cannot happen (objective bounded below
            // by 0); defensive break.
            break;
        };
        // Pivot on (lr, e).
        let piv = t[lr][e];
        for c in 0..=ncols {
            t[lr][c] /= piv;
        }
        for r in 0..nrows {
            if r != lr && t[r][e].abs() > 1e-12 {
                let f = t[r][e];
                for c in 0..=ncols {
                    t[r][c] -= f * t[lr][c];
                }
            }
        }
        let f = z[e];
        if f.abs() > 1e-12 {
            for c in 0..=ncols {
                z[c] -= f * t[lr][c];
            }
        }
        basis[lr] = e;
    }

    // Feasible iff phase-1 objective (z rhs) is ~0.
    if z[ncols] > 1e-6 {
        return None;
    }
    let mut x = vec![0.0; nvars];
    for r in 0..nrows {
        if basis[r] < nvars {
            x[basis[r]] = t[r][ncols];
        }
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn le(terms: Vec<(usize, f64)>, rhs: f64) -> Constraint {
        Constraint { terms, sense: Sense::Le, rhs }
    }
    fn ge(terms: Vec<(usize, f64)>, rhs: f64) -> Constraint {
        Constraint { terms, sense: Sense::Ge, rhs }
    }

    #[test]
    fn lp_simple_feasible() {
        // x0 + x1 <= 10, x0 >= 3, x1 >= 4.
        let cs = vec![
            le(vec![(0, 1.0), (1, 1.0)], 10.0),
            ge(vec![(0, 1.0)], 3.0),
            ge(vec![(1, 1.0)], 4.0),
        ];
        let x = lp_feasible_point(2, &cs).expect("feasible");
        assert!(x[0] >= 3.0 - 1e-6 && x[1] >= 4.0 - 1e-6);
        assert!(x[0] + x[1] <= 10.0 + 1e-6);
    }

    #[test]
    fn lp_simple_infeasible() {
        let cs = vec![le(vec![(0, 1.0)], 2.0), ge(vec![(0, 1.0)], 3.0)];
        assert!(lp_feasible_point(1, &cs).is_none());
    }

    #[test]
    fn lp_empty_constraints() {
        assert_eq!(lp_feasible_point(3, &[]), Some(vec![0.0; 3]));
    }

    #[test]
    fn ilp_integral_when_lp_fractional() {
        // The slot-sharing example from the module docs: one server with
        // cap 1 slot, mu = 4; two groups each need 2 tasks.
        // Variables: y0 = slots for group A, y1 = slots for group B.
        let cs = vec![
            le(vec![(0, 1.0), (1, 1.0)], 1.0),
            ge(vec![(0, 4.0)], 2.0),
            ge(vec![(1, 4.0)], 2.0),
        ];
        // LP is feasible (0.5, 0.5)...
        assert!(lp_feasible_point(2, &cs).is_some());
        // ...but the IP is not.
        assert_eq!(ilp_feasible(2, &cs), IlpOutcome::Infeasible);
    }

    #[test]
    fn ilp_finds_integer_point() {
        // cap 2 slots, mu = 4, two groups of 2 tasks: y0 = y1 = 1 works.
        let cs = vec![
            le(vec![(0, 1.0), (1, 1.0)], 2.0),
            ge(vec![(0, 4.0)], 2.0),
            ge(vec![(1, 4.0)], 2.0),
        ];
        match ilp_feasible(2, &cs) {
            IlpOutcome::Feasible(y) => {
                assert!(y[0] >= 1 && y[1] >= 1 && y[0] + y[1] <= 2, "{y:?}");
            }
            other => panic!("expected feasible, got {other:?}"),
        }
    }

    #[test]
    fn ilp_respects_all_constraints() {
        // Two servers (caps 3 and 2 slots; mu 3 and 5), two groups
        // demanding 9 and 10 tasks, both groups on both servers.
        // Variables y[k][m] flattened as y0=(g0,s0) y1=(g0,s1) y2=(g1,s0) y3=(g1,s1).
        let cs = vec![
            le(vec![(0, 1.0), (2, 1.0)], 3.0),
            le(vec![(1, 1.0), (3, 1.0)], 2.0),
            ge(vec![(0, 3.0), (1, 5.0)], 9.0),
            ge(vec![(2, 3.0), (3, 5.0)], 10.0),
        ];
        match ilp_feasible(4, &cs) {
            IlpOutcome::Feasible(y) => {
                assert!(y[0] + y[2] <= 3);
                assert!(y[1] + y[3] <= 2);
                assert!(3 * y[0] + 5 * y[1] >= 9);
                assert!(3 * y[2] + 5 * y[3] >= 10);
            }
            other => panic!("expected feasible, got {other:?}"),
        }
    }

    #[test]
    fn ilp_large_caps_fast() {
        // Degenerate-free sanity: big caps, trivially feasible.
        let cs = vec![
            le(vec![(0, 1.0), (1, 1.0)], 10_000.0),
            ge(vec![(0, 4.0), (1, 3.0)], 25_000.0),
        ];
        assert!(matches!(ilp_feasible(2, &cs), IlpOutcome::Feasible(_)));
    }

    #[test]
    fn ilp_matches_bruteforce_on_random_small_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(55);
        for case in 0..40 {
            // 2 servers, 2 groups, random small caps/demands/mu.
            let cap = [rng.gen_range_incl(0, 4), rng.gen_range_incl(0, 4)];
            let mu = [rng.gen_range_incl(1, 4), rng.gen_range_incl(1, 4)];
            let demand = [rng.gen_range_incl(0, 12), rng.gen_range_incl(0, 12)];
            let cs = vec![
                le(vec![(0, 1.0), (2, 1.0)], cap[0] as f64),
                le(vec![(1, 1.0), (3, 1.0)], cap[1] as f64),
                ge(vec![(0, mu[0] as f64), (1, mu[1] as f64)], demand[0] as f64),
                ge(vec![(2, mu[0] as f64), (3, mu[1] as f64)], demand[1] as f64),
            ];
            // Brute force over all slot splits.
            let mut brute = false;
            for a0 in 0..=cap[0] {
                for a1 in 0..=cap[1] {
                    let g0 = a0 * mu[0] + a1 * mu[1];
                    if g0 < demand[0] {
                        continue;
                    }
                    let g1 = (cap[0] - a0) * mu[0] + (cap[1] - a1) * mu[1];
                    if g1 >= demand[1] {
                        brute = true;
                    }
                }
            }
            let got = matches!(ilp_feasible(4, &cs), IlpOutcome::Feasible(_));
            assert_eq!(got, brute, "case {case}: cap {cap:?} mu {mu:?} demand {demand:?}");
        }
    }
}
