//! Task assignment for one arriving job (paper §III).
//!
//! Given the job's task groups, the per-server capacities `μ_m^c` and the
//! servers' estimated busy times `b_m^c`, an [`Assigner`] decides how many
//! tasks of each group go to each available server, minimizing (exactly or
//! approximately) the job's estimated completion time Φ_c of program `P`
//! (eq. 4).
//!
//! Implemented assigners:
//! - [`nlip::Nlip`] — exact, no search-space narrowing (the paper's NLIP
//!   baseline, CPLEX replaced by [`ilp`]).
//! - [`obta::Obta`] — exact, with the narrowed `[Φ⁻, Φ⁺]` search of
//!   §III-A2/A3 (the paper's OBTA).
//! - [`wf::Wf`] — the water-filling approximation (§III-B, Alg 2), tight
//!   K_c-approximate (Thms 1–2).
//! - [`rd::Rd`] — the replica-deletion heuristic (§III-C).
//!
//! Classic baselines beyond the paper (the `--policies` panel):
//! - [`jsq::Jsq`] — join-shortest-estimated-queue, locality-oblivious.
//! - [`jsq::JsqAffinity`] — JSQ restricted to replica holders with
//!   overflow fallback (affinity scheduling, arXiv 1705.03125).
//! - [`delay::Delay`] — delay scheduling (Zaharia et al., EuroSys 2010):
//!   prefer replica holders, go remote only when the estimated local
//!   wait exceeds the delay bound D ([`AssignParams::delay_bound`]).
//! - [`maxweight::MaxWeight`] — queue-length × locality-weight priority
//!   routing (JSQ-MaxWeight flavor, arXiv 1705.03125).

pub mod bounds;
pub mod brute;
pub mod delay;
pub mod feasible;
pub mod ilp;
pub mod jsq;
pub mod maxweight;
pub mod nlip;
pub mod obta;
pub mod rd;
pub mod wf;

use crate::job::{ServerId, Slots, TaskCount, TaskGroup};
use crate::util::ceil_div;

/// A task-assignment problem instance: the state an assigner sees when job
/// `c` arrives (or when an outstanding job is re-assigned during
/// reordering).
#[derive(Clone, Copy, Debug)]
pub struct Instance<'a> {
    /// The job's task groups (sizes = *remaining* tasks).
    pub groups: &'a [TaskGroup],
    /// Per-server capacity μ_m^c, length M.
    pub mu: &'a [u64],
    /// Per-server estimated busy time b_m^c (eq. 2), length M.
    pub busy: &'a [Slots],
}

impl<'a> Instance<'a> {
    pub fn total_tasks(&self) -> TaskCount {
        self.groups.iter().map(|g| g.size).sum()
    }

    /// Union of available servers over non-empty groups, sorted.
    pub fn union_servers(&self) -> Vec<ServerId> {
        let mut all: Vec<ServerId> = self
            .groups
            .iter()
            .filter(|g| g.size > 0)
            .flat_map(|g| g.servers.iter().copied())
            .collect();
        all.sort_unstable();
        all.dedup();
        all
    }
}

/// The result of assigning one job: for each group, the `(server, tasks)`
/// allocation, plus the estimated completion time Φ under program `P`'s
/// objective.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// `per_group[k]` lists `(server, tasks)` with tasks > 0.
    pub per_group: Vec<Vec<(ServerId, TaskCount)>>,
    /// Estimated completion time (slots, relative to the job's arrival).
    pub phi: Slots,
}

impl Assignment {
    /// Total tasks assigned to each server (summed over groups), as a
    /// sparse `(server, tasks)` list sorted by server.
    pub fn per_server(&self) -> Vec<(ServerId, TaskCount)> {
        let mut acc: std::collections::BTreeMap<ServerId, TaskCount> = Default::default();
        for g in &self.per_group {
            for &(m, n) in g {
                *acc.entry(m).or_insert(0) += n;
            }
        }
        acc.into_iter().collect()
    }

    pub fn total_assigned(&self) -> TaskCount {
        self.per_group.iter().flatten().map(|&(_, n)| n).sum()
    }
}

/// Program `P`'s objective value for a concrete allocation: every group's
/// tasks at a server occupy an integer number of slots
/// (`Σ_k ceil(n_{k,m}/μ_m)` per server), and Φ is the latest finish over
/// servers that received tasks. This is the metric NLIP/OBTA optimize and
/// the one used to compare assigners.
pub fn program_phi(inst: &Instance, per_group: &[Vec<(ServerId, TaskCount)>]) -> Slots {
    let mut slots: std::collections::BTreeMap<ServerId, u64> = Default::default();
    for g in per_group {
        for &(m, n) in g {
            if n > 0 {
                *slots.entry(m).or_insert(0) += ceil_div(n, inst.mu[m]);
            }
        }
    }
    slots
        .into_iter()
        .map(|(m, s)| inst.busy[m] + s)
        .max()
        .unwrap_or(0)
}

/// The *execution-model* completion estimate for a concrete allocation:
/// the simulator merges all of a job's tasks at a server into one queue
/// entry costing `ceil(total/μ_m)` slots (eq. 2), so this is what the job
/// will actually experience under FIFO. Always ≤ [`program_phi`].
pub fn realized_phi(inst: &Instance, per_group: &[Vec<(ServerId, TaskCount)>]) -> Slots {
    let mut tasks: std::collections::BTreeMap<ServerId, u64> = Default::default();
    for g in per_group {
        for &(m, n) in g {
            if n > 0 {
                *tasks.entry(m).or_insert(0) += n;
            }
        }
    }
    tasks
        .into_iter()
        .map(|(m, n)| inst.busy[m] + ceil_div(n, inst.mu[m]))
        .max()
        .unwrap_or(0)
}

/// A task-assignment algorithm.
pub trait Assigner {
    fn name(&self) -> &'static str;
    /// Assign all tasks of the instance; must assign every task of every
    /// non-empty group to one of the group's available servers.
    fn assign(&mut self, inst: &Instance) -> Assignment;
    /// Accumulated feasibility-oracle telemetry (exact assigners only).
    fn oracle_stats(&self) -> Option<feasible::OracleStats> {
        None
    }
}

/// Which assignment algorithm to run (CLI/config-level selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignPolicy {
    Nlip,
    Obta,
    Wf,
    Rd,
    Jsq,
    JsqAffinity,
    Delay,
    MaxWeight,
}

/// Knobs an assigner may need beyond the RNG seed. Threaded from
/// [`crate::config::SimConfig`] at every engine build site; `build`
/// without params uses the defaults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AssignParams {
    /// Delay scheduling's bound D (slots): [`delay::Delay`] goes remote
    /// only when the best replica holder's estimated wait exceeds D.
    pub delay_bound: Slots,
}

/// Default delay bound D: tolerate a short local queue (the classic
/// delay-scheduling sweet spot of "wait a little, win locality").
pub const DEFAULT_DELAY_BOUND: Slots = 2;

impl Default for AssignParams {
    fn default() -> Self {
        AssignParams {
            delay_bound: DEFAULT_DELAY_BOUND,
        }
    }
}

impl AssignPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AssignPolicy::Nlip => "nlip",
            AssignPolicy::Obta => "obta",
            AssignPolicy::Wf => "wf",
            AssignPolicy::Rd => "rd",
            AssignPolicy::Jsq => "jsq",
            AssignPolicy::JsqAffinity => "jsq-affinity",
            AssignPolicy::Delay => "delay",
            AssignPolicy::MaxWeight => "maxweight",
        }
    }

    pub fn parse(s: &str) -> Option<AssignPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "nlip" => Some(AssignPolicy::Nlip),
            "obta" => Some(AssignPolicy::Obta),
            "wf" => Some(AssignPolicy::Wf),
            "rd" => Some(AssignPolicy::Rd),
            "jsq" => Some(AssignPolicy::Jsq),
            "jsq-affinity" | "jsq_affinity" | "jsqaffinity" | "jsqa" => {
                Some(AssignPolicy::JsqAffinity)
            }
            "delay" | "delay-sched" | "delay_sched" => Some(AssignPolicy::Delay),
            "maxweight" | "max-weight" | "max_weight" => Some(AssignPolicy::MaxWeight),
            _ => None,
        }
    }

    /// Instantiate the assigner with default [`AssignParams`]. `seed`
    /// only affects RD's random tie-breaking (paper §III-C: ties among
    /// equal-copy replicas are broken randomly).
    pub fn build(&self, seed: u64) -> Box<dyn Assigner> {
        self.build_with(seed, &AssignParams::default())
    }

    /// Instantiate the assigner with explicit parameters (the engines
    /// call this with [`crate::config::SimConfig::assign_params`]).
    pub fn build_with(&self, seed: u64, params: &AssignParams) -> Box<dyn Assigner> {
        match self {
            AssignPolicy::Nlip => Box::new(nlip::Nlip::new()),
            AssignPolicy::Obta => Box::new(obta::Obta::new()),
            AssignPolicy::Wf => Box::new(wf::Wf::new()),
            AssignPolicy::Rd => Box::new(rd::Rd::new(seed)),
            AssignPolicy::Jsq => Box::new(jsq::Jsq::new()),
            AssignPolicy::JsqAffinity => Box::new(jsq::JsqAffinity::new()),
            AssignPolicy::Delay => Box::new(delay::Delay::new(params.delay_bound)),
            AssignPolicy::MaxWeight => Box::new(maxweight::MaxWeight::new()),
        }
    }

    /// The paper's four assignment algorithms (§III).
    pub const ALL: [AssignPolicy; 4] = [
        AssignPolicy::Nlip,
        AssignPolicy::Obta,
        AssignPolicy::Wf,
        AssignPolicy::Rd,
    ];

    /// The classic baseline assigners beyond the paper.
    pub const BASELINES: [AssignPolicy; 4] = [
        AssignPolicy::Jsq,
        AssignPolicy::JsqAffinity,
        AssignPolicy::Delay,
        AssignPolicy::MaxWeight,
    ];
}

/// Validate that an assignment is structurally correct for the instance:
/// every task assigned exactly once, only to available servers. Used by
/// tests and debug assertions.
pub fn validate_assignment(inst: &Instance, a: &Assignment) -> Result<(), String> {
    if a.per_group.len() != inst.groups.len() {
        return Err(format!(
            "group arity mismatch: {} vs {}",
            a.per_group.len(),
            inst.groups.len()
        ));
    }
    for (k, (g, alloc)) in inst.groups.iter().zip(&a.per_group).enumerate() {
        let total: TaskCount = alloc.iter().map(|&(_, n)| n).sum();
        if total != g.size {
            return Err(format!(
                "group {k}: assigned {total} of {} tasks",
                g.size
            ));
        }
        for &(m, n) in alloc {
            if n == 0 {
                return Err(format!("group {k}: zero-task allocation on server {m}"));
            }
            if !g.servers.contains(&m) {
                return Err(format!("group {k}: server {m} not available"));
            }
        }
        // No duplicate servers within one group's allocation.
        let mut servers: Vec<ServerId> = alloc.iter().map(|&(m, _)| m).collect();
        servers.sort_unstable();
        let len = servers.len();
        servers.dedup();
        if servers.len() != len {
            return Err(format!("group {k}: duplicate server in allocation"));
        }
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for assigner tests: random instance generation and
    //! the brute-force optimal Φ (re-exported from [`super::brute`], the
    //! public oracle behind the differential test harness).

    use super::*;
    use crate::util::rng::Rng;

    pub use super::brute::brute_force_opt_phi;

    /// An owned instance for test generation.
    #[derive(Clone, Debug)]
    pub struct OwnedInstance {
        pub groups: Vec<TaskGroup>,
        pub mu: Vec<u64>,
        pub busy: Vec<Slots>,
    }

    impl OwnedInstance {
        pub fn view(&self) -> Instance<'_> {
            Instance {
                groups: &self.groups,
                mu: &self.mu,
                busy: &self.busy,
            }
        }
    }

    /// Random small instance: up to `max_m` servers, `max_k` groups,
    /// `max_size` tasks per group.
    pub fn random_instance(
        rng: &mut Rng,
        max_m: usize,
        max_k: usize,
        max_size: u64,
        max_busy: u64,
    ) -> OwnedInstance {
        let m = 1 + rng.gen_range(max_m as u64) as usize;
        let k = 1 + rng.gen_range(max_k as u64) as usize;
        let mu: Vec<u64> = (0..m).map(|_| rng.gen_range_incl(1, 5)).collect();
        let busy: Vec<Slots> = (0..m).map(|_| rng.gen_range_incl(0, max_busy)).collect();
        let groups = (0..k)
            .map(|_| {
                let ns = 1 + rng.gen_range(m as u64) as usize;
                let mut servers: Vec<ServerId> = (0..m).collect();
                rng.shuffle(&mut servers);
                servers.truncate(ns);
                TaskGroup::new(rng.gen_range_incl(1, max_size), servers)
            })
            .collect();
        OwnedInstance { groups, mu, busy }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_server_merges_groups() {
        let a = Assignment {
            per_group: vec![vec![(0, 5), (2, 1)], vec![(0, 3)]],
            phi: 4,
        };
        assert_eq!(a.per_server(), vec![(0, 8), (2, 1)]);
        assert_eq!(a.total_assigned(), 9);
    }

    #[test]
    fn program_phi_counts_per_group_slots() {
        let groups = vec![
            TaskGroup::new(2, vec![0]),
            TaskGroup::new(2, vec![0, 1]),
        ];
        let mu = vec![4, 4];
        let busy = vec![1, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        // Both groups on server 0: 2 groups × ceil(2/4)=1 slot each = 2
        // slots + busy 1 = 3 under the program objective...
        let alloc = vec![vec![(0, 2)], vec![(0, 2)]];
        assert_eq!(program_phi(&inst, &alloc), 3);
        // ...but merged execution finishes in ceil(4/4)=1 slot + busy 1 = 2.
        assert_eq!(realized_phi(&inst, &alloc), 2);
    }

    #[test]
    fn validate_catches_errors() {
        let groups = vec![TaskGroup::new(3, vec![0, 1])];
        let mu = vec![1, 1];
        let busy = vec![0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        // OK.
        let ok = Assignment {
            per_group: vec![vec![(0, 1), (1, 2)]],
            phi: 2,
        };
        assert!(validate_assignment(&inst, &ok).is_ok());
        // Under-assigned.
        let under = Assignment {
            per_group: vec![vec![(0, 1)]],
            phi: 1,
        };
        assert!(validate_assignment(&inst, &under).is_err());
        // Wrong server.
        let wrong = Assignment {
            per_group: vec![vec![(5, 3)]],
            phi: 3,
        };
        assert!(validate_assignment(&inst, &wrong).is_err());
        // Duplicate server entries.
        let dup = Assignment {
            per_group: vec![vec![(0, 1), (0, 2)]],
            phi: 3,
        };
        assert!(validate_assignment(&inst, &dup).is_err());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in AssignPolicy::ALL.into_iter().chain(AssignPolicy::BASELINES) {
            assert_eq!(AssignPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AssignPolicy::parse("bogus"), None);
        assert_eq!(AssignPolicy::parse("jsqa"), Some(AssignPolicy::JsqAffinity));
        assert_eq!(
            AssignPolicy::parse("max-weight"),
            Some(AssignPolicy::MaxWeight)
        );
    }
}
