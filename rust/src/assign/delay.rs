//! Delay scheduling (Zaharia et al., EuroSys 2010).
//!
//! Launch on a replica-holding server whenever the estimated local wait
//! is tolerable; fall back to the shortest remote queue only when the
//! best holder's wait exceeds the configured delay bound D
//! ([`crate::assign::AssignParams::delay_bound`], CLI `--delay-bound`).
//! The original system waits in *time* for a local slot; in this slotted
//! model the wait is the holder's estimated queue length, so D is
//! expressed in slots. Under the flat model (holders == eligible set)
//! the rule degenerates to chunked JSQ — the locality trade-off only
//! bites once the DES topology expansion widens the eligible set beyond
//! the holders ([`crate::job::TaskGroup::holders`]).
//!
//! Deterministic integer arithmetic, no RNG: the analytic and DES
//! engines produce bit-identical schedules.

use super::jsq::{emit_row, shortest_queue};
use super::{Assigner, Assignment, Instance};
use crate::job::{Slots, TaskCount};

/// Delay scheduling with bound D, pooled chunk-routing workspace.
pub struct Delay {
    bound: Slots,
    eff: Vec<Slots>,
    counts: Vec<TaskCount>,
}

impl Delay {
    pub fn new(bound: Slots) -> Self {
        Delay {
            bound,
            eff: Vec::new(),
            counts: Vec::new(),
        }
    }

    /// Reserved workspace capacity (allocation-stability tests).
    pub fn scratch_footprint(&self) -> usize {
        self.eff.capacity() + self.counts.capacity()
    }
}

impl Assigner for Delay {
    fn name(&self) -> &'static str {
        "delay"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        let m = inst.busy.len();
        self.eff.clear();
        self.eff.extend_from_slice(inst.busy);
        self.counts.resize(m, 0);
        let mut per_group = Vec::with_capacity(inst.groups.len());
        let mut phi: Slots = 0;
        for g in inst.groups {
            if g.size == 0 {
                per_group.push(Vec::new());
                continue;
            }
            let holders = g.holders();
            let mut remaining = g.size;
            while remaining > 0 {
                let local = shortest_queue(&self.eff, inst.mu, holders);
                // Tolerable local wait → stay on the holder; otherwise
                // the chunk goes to the globally shortest eligible queue
                // (which may still be the holder when remote is no
                // better).
                let target = if self.eff[local] <= self.bound {
                    local
                } else {
                    shortest_queue(&self.eff, inst.mu, &g.servers)
                };
                let chunk = remaining.min(inst.mu[target]);
                self.counts[target] += chunk;
                self.eff[target] += 1;
                phi = phi.max(self.eff[target]);
                remaining -= chunk;
            }
            per_group.push(emit_row(&mut self.counts, &g.servers));
        }
        Assignment { per_group, phi }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{program_phi, validate_assignment, DEFAULT_DELAY_BOUND};
    use super::*;
    use crate::job::TaskGroup;

    fn inst<'a>(groups: &'a [TaskGroup], mu: &'a [u64], busy: &'a [Slots]) -> Instance<'a> {
        Instance { groups, mu, busy }
    }

    #[test]
    fn waits_out_short_local_queues() {
        // Holder 0 is busy (2 slots) but every chunk's wait — including
        // the self-load of earlier chunks — stays within D = 3, so the
        // idle remote server never sees a task.
        let groups = vec![TaskGroup::with_local(4, vec![0, 1], vec![0])];
        let mu = vec![2, 2];
        let busy = vec![2, 0];
        let out = Delay::new(3).assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(0, 4)]]);
        assert_eq!(out.phi, 4);
    }

    #[test]
    fn spills_remote_past_the_bound() {
        // Same instance with D = 1: the local wait (2) exceeds the bound,
        // so chunks go to the shortest eligible queue instead.
        let groups = vec![TaskGroup::with_local(4, vec![0, 1], vec![0])];
        let mu = vec![2, 2];
        let busy = vec![2, 0];
        let out = Delay::new(1).assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(1, 4)]]);
        assert_eq!(out.phi, 2);
    }

    #[test]
    fn bound_zero_is_work_conserving_jsq() {
        // D = 0 tolerates no local queue at all: the first chunk lands on
        // the idle holder, subsequent chunks chase the shortest queue.
        let groups = vec![TaskGroup::with_local(6, vec![0, 1, 2], vec![0])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let out = Delay::new(0).assign(&inst(&groups, &mu, &busy));
        assert_eq!(out.per_group, vec![vec![(0, 2), (1, 2), (2, 2)]]);
        assert_eq!(out.phi, 1);
    }

    #[test]
    fn phi_is_exact_program_phi_on_random_instances() {
        use crate::assign::testutil::random_instance;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0xDE1A_7);
        for _ in 0..300 {
            let oi = random_instance(&mut rng, 6, 4, 12, 6);
            let inst = oi.view();
            for bound in [0, DEFAULT_DELAY_BOUND, 50] {
                let out = Delay::new(bound).assign(&inst);
                validate_assignment(&inst, &out).unwrap();
                assert_eq!(out.phi, program_phi(&inst, &out.per_group));
            }
        }
    }
}
