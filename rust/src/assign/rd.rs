//! RD — replica-deletion task assignment (paper §III-C).
//!
//! Every task starts replicated on *all* of its available servers; RD then
//! iteratively deletes redundant replicas from the most-loaded (*target*)
//! server(s), always removing the replicas with the largest remaining copy
//! counts (ties across target servers broken toward the larger *initial*
//! busy time, then randomly), until no target server holds a deletable
//! replica. A final phase strips the remaining duplicates from the
//! most-loaded holders so every task ends on exactly one server.
//!
//! Unlike WF, which balances only within each task group, RD looks at all
//! groups and all servers at once — globally balancing at the cost of a
//! higher complexity, O(M²·n·log n) worst case (§III-C2). Implemented
//! with lazy max-heaps (stale entries validated on pop), matching the
//! paper's priority-queue design.

use std::collections::BinaryHeap;

use crate::job::{ServerId, Slots, TaskCount};
use crate::util::ceil_div;
use crate::util::rng::Rng;

use super::{program_phi, Assigner, Assignment, Instance};

/// Pooled replica tables and per-server heaps, reused across arrivals so
/// the steady-state RD rebuild is allocation-free once warmed.
#[derive(Clone, Debug, Default)]
struct RdWorkspace {
    /// Group index of each task.
    task_group: Vec<usize>,
    /// Current copy count per task.
    copies: Vec<u32>,
    /// Per-task live holder list (row pool; rows `0..task_group.len()`
    /// are live).
    holders: Vec<Vec<ServerId>>,
    /// Live replica count per server.
    load: Vec<u64>,
    /// Per-server lazy max-heap of (copies_at_push, tiebreak, task).
    heaps: Vec<BinaryHeap<(u32, u32, usize)>>,
}

/// The RD assigner. Carries an RNG for the paper's random tie-breaking
/// plus the pooled workspace.
#[derive(Clone, Debug)]
pub struct Rd {
    rng: Rng,
    ws: RdWorkspace,
}

impl Rd {
    pub fn new(seed: u64) -> Self {
        Rd {
            rng: Rng::seed_from(seed ^ 0x5D_D3_1E_57),
            ws: RdWorkspace::default(),
        }
    }

    /// Reserved capacity of the pooled buffers (allocation-stability
    /// tests).
    pub fn scratch_footprint(&self) -> usize {
        self.ws.task_group.capacity()
            + self.ws.copies.capacity()
            + self.ws.load.capacity()
            + self.ws.holders.capacity()
            + self.ws.holders.iter().map(|h| h.capacity()).sum::<usize>()
            + self.ws.heaps.capacity()
            + self.ws.heaps.iter().map(|h| h.capacity()).sum::<usize>()
    }
}

/// Replica state for one job's assignment: a view over the pooled
/// workspace.
struct RdState<'a> {
    inst: &'a Instance<'a>,
    ws: &'a mut RdWorkspace,
}

impl<'a> RdState<'a> {
    fn bind(inst: &'a Instance<'a>, ws: &'a mut RdWorkspace, rng: &mut Rng) -> Self {
        let m = inst.mu.len();
        ws.task_group.clear();
        ws.copies.clear();
        ws.load.clear();
        ws.load.resize(m, 0);
        while ws.heaps.len() < m {
            ws.heaps.push(BinaryHeap::new());
        }
        for h in ws.heaps.iter_mut() {
            h.clear();
        }
        for (k, g) in inst.groups.iter().enumerate() {
            for _ in 0..g.size {
                let t = ws.task_group.len();
                ws.task_group.push(k);
                ws.copies.push(g.servers.len() as u32);
                if t == ws.holders.len() {
                    ws.holders.push(Vec::new());
                }
                ws.holders[t].clear();
                ws.holders[t].extend_from_slice(&g.servers);
                for &s in &g.servers {
                    ws.load[s] += 1;
                    ws.heaps[s].push((g.servers.len() as u32, rng.next_u64() as u32, t));
                }
            }
        }
        RdState { inst, ws }
    }

    #[inline]
    fn busy(&self, m: ServerId) -> Slots {
        if self.ws.load[m] == 0 {
            self.inst.busy[m]
        } else {
            self.inst.busy[m] + ceil_div(self.ws.load[m], self.inst.mu[m])
        }
    }

    /// Peek server m's best deletable replica (copies ≥ 2), lazily
    /// discarding stale heap entries. Returns its current copy count.
    fn peek_deletable(&mut self, m: ServerId) -> Option<u32> {
        while let Some(&(c, tb, t)) = self.ws.heaps[m].peek() {
            let live = self.ws.holders[t].contains(&m);
            if !live {
                self.ws.heaps[m].pop();
                continue;
            }
            let cur = self.ws.copies[t];
            if cur != c {
                // Stale count: reinsert with the current count.
                self.ws.heaps[m].pop();
                self.ws.heaps[m].push((cur, tb, t));
                continue;
            }
            if cur < 2 {
                // Top is a single-copy task: nothing deletable remains on
                // this server (heap is max-ordered by copies).
                return None;
            }
            return Some(cur);
        }
        None
    }

    /// Delete server m's best deletable replica. Returns false when none.
    fn delete_one(&mut self, m: ServerId) -> bool {
        if self.peek_deletable(m).is_none() {
            return false;
        }
        let (_, _, t) = self.ws.heaps[m].pop().unwrap();
        let pos = self.ws.holders[t].iter().position(|&x| x == m).unwrap();
        self.ws.holders[t].swap_remove(pos);
        self.ws.copies[t] -= 1;
        self.ws.load[m] -= 1;
        true
    }

    /// Servers currently holding at least one replica, with max busy.
    fn target_servers(&self) -> Vec<ServerId> {
        let max = (0..self.ws.load.len())
            .filter(|&m| self.ws.load[m] > 0)
            .map(|m| self.busy(m))
            .max();
        match max {
            None => Vec::new(),
            Some(mx) => (0..self.ws.load.len())
                .filter(|&m| self.ws.load[m] > 0 && self.busy(m) == mx)
                .collect(),
        }
    }

    /// Phase 1: delete from target servers until none has a deletable
    /// replica.
    fn deletion_phase(&mut self) {
        loop {
            let targets = self.target_servers();
            if targets.is_empty() {
                return;
            }
            // Best (copies, initial busy) across targets.
            let mut best: Option<(u32, Slots, ServerId)> = None;
            for &m in &targets {
                if let Some(c) = self.peek_deletable(m) {
                    let key = (c, self.inst.busy[m], m);
                    match best {
                        Some((bc, bb, _)) if (bc, bb) >= (key.0, key.1) => {}
                        _ => best = Some(key),
                    }
                }
            }
            let Some((_, _, m)) = best else {
                // Exit condition (§III-C1): every task on every target
                // server is down to one replica.
                return;
            };
            // Remove enough replicas from m to drop its busy time by one
            // slot (up to μ_m replicas), stopping early if deletables run
            // out.
            let slots = ceil_div(self.ws.load[m], self.inst.mu[m]);
            let want = self.ws.load[m] - self.inst.mu[m] * (slots - 1);
            for _ in 0..want {
                if !self.delete_one(m) {
                    break;
                }
            }
        }
    }

    /// Phase 2: strip remaining duplicates — repeatedly pick the busiest
    /// server still holding a deletable replica and delete from it.
    fn cleanup_phase(&mut self) {
        loop {
            let mut best: Option<(Slots, Slots, ServerId)> = None;
            for m in 0..self.ws.load.len() {
                if self.ws.load[m] == 0 {
                    continue;
                }
                if self.peek_deletable(m).is_some() {
                    let key = (self.busy(m), self.inst.busy[m], m);
                    match best {
                        Some((bb, bi, _)) if (bb, bi) >= (key.0, key.1) => {}
                        _ => best = Some(key),
                    }
                }
            }
            let Some((_, _, m)) = best else { return };
            self.delete_one(m);
        }
    }

    /// Collect the final one-replica-per-task allocation per group.
    fn extract(&self) -> Vec<Vec<(ServerId, TaskCount)>> {
        let mut acc: Vec<std::collections::BTreeMap<ServerId, TaskCount>> =
            vec![Default::default(); self.inst.groups.len()];
        for t in 0..self.ws.task_group.len() {
            debug_assert_eq!(self.ws.copies[t], 1, "task {t} not reduced to one replica");
            debug_assert_eq!(self.ws.holders[t].len(), 1);
            let m = self.ws.holders[t][0];
            *acc[self.ws.task_group[t]].entry(m).or_insert(0) += 1;
        }
        acc.into_iter()
            .map(|m| m.into_iter().collect())
            .collect()
    }
}

impl Assigner for Rd {
    fn name(&self) -> &'static str {
        "rd"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        let mut st = RdState::bind(inst, &mut self.ws, &mut self.rng);
        st.deletion_phase();
        st.cleanup_phase();
        let per_group = st.extract();
        let phi = program_phi(inst, &per_group);
        Assignment { per_group, phi }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::testutil::random_instance;
    use crate::assign::{validate_assignment, AssignPolicy};
    use crate::job::TaskGroup;

    #[test]
    fn every_task_assigned_exactly_once() {
        let mut rng = Rng::seed_from(200);
        for _ in 0..50 {
            let owned = random_instance(&mut rng, 6, 4, 30, 6);
            let inst = owned.view();
            let a = Rd::new(1).assign(&inst);
            validate_assignment(&inst, &a).unwrap();
        }
    }

    #[test]
    fn single_group_unit_mu_balances() {
        // 9 tasks over 3 idle unit-capacity servers: perfect balance = 3.
        let groups = vec![TaskGroup::new(9, vec![0, 1, 2])];
        let mu = vec![1, 1, 1];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Rd::new(2).assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.phi, 3);
    }

    #[test]
    fn respects_single_replica_tasks() {
        // A group pinned to one server cannot move; RD must keep it there.
        let groups = vec![
            TaskGroup::new(5, vec![0]),
            TaskGroup::new(3, vec![0, 1]),
        ];
        let mu = vec![1, 1];
        let busy = vec![0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Rd::new(3).assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.per_group[0], vec![(0, 5)]);
        // Group 2's flexible tasks should flee the loaded server 0.
        assert_eq!(a.per_group[1], vec![(1, 3)]);
        assert_eq!(a.phi, 5);
    }

    #[test]
    fn prefers_deleting_from_larger_initial_busy_on_ties() {
        // Two idle-capacity servers with equal current busy but different
        // initial busy; the flexible task should end on the lower-initial
        // server (Fig. 9's rule).
        let groups = vec![TaskGroup::new(1, vec![0, 1])];
        let mu = vec![1, 1];
        let busy = vec![4, 1];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Rd::new(4).assign(&inst);
        validate_assignment(&inst, &a).unwrap();
        assert_eq!(a.per_group[0], vec![(1, 1)], "task should land on server 1");
    }

    #[test]
    fn rd_between_wf_and_opt_on_nested_instance() {
        // The nested-group instance where WF stacks badly; RD's global
        // view should do at least as well as WF.
        let groups = vec![
            TaskGroup::new(8, vec![0, 1, 2, 3]),
            TaskGroup::new(4, vec![2, 3]),
        ];
        let mu = vec![1, 1, 1, 1];
        let busy = vec![0, 0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let rd = Rd::new(5).assign(&inst);
        let wf = AssignPolicy::Wf.build(0).assign(&inst);
        validate_assignment(&inst, &rd).unwrap();
        assert!(rd.phi <= wf.phi, "RD {} vs WF {}", rd.phi, wf.phi);
        assert_eq!(rd.phi, 3, "RD finds the balanced optimum here");
    }

    #[test]
    fn busy_accounting_uses_mu() {
        // μ = 3: 7 replicas = 3 slots (ceil), busy 0.
        let groups = vec![TaskGroup::new(7, vec![0])];
        let mu = vec![3];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let a = Rd::new(6).assign(&inst);
        assert_eq!(a.phi, 3);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut rng = Rng::seed_from(201);
        let owned = random_instance(&mut rng, 6, 4, 30, 6);
        let inst = owned.view();
        let a1 = Rd::new(42).assign(&inst);
        let a2 = Rd::new(42).assign(&inst);
        assert_eq!(a1, a2);
    }
}
