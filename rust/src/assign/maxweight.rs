//! MaxWeight-style priority routing (Tassiulas–Ephremides; the
//! JSQ-MaxWeight affinity flavor of arXiv 1705.03125).
//!
//! Each capacity-sized chunk goes to the eligible server maximizing a
//! locality-weighted service-to-backlog priority
//! `w_m · μ_m / (1 + eff_m)`, where `w_m = 2` for replica holders and
//! `1` for remote servers: fast, data-local, short-queue servers win.
//! The ratio comparison is done by u128 cross-multiplication so the rule
//! is exact integer arithmetic — deterministic, engine-agnostic, and
//! invariant under uniform rate scaling (both sides carry exactly one μ
//! factor). Ties fall back to the shortest-queue key `(eff, Reverse(μ),
//! id)`.

use std::cmp::Reverse;

use super::jsq::emit_row;
use super::{Assigner, Assignment, Instance};
use crate::job::{ServerId, Slots, TaskCount};

/// Locality weight: replica holders count double.
const LOCAL_WEIGHT: u64 = 2;
const REMOTE_WEIGHT: u64 = 1;

/// MaxWeight router with pooled chunk-routing workspace.
pub struct MaxWeight {
    eff: Vec<Slots>,
    counts: Vec<TaskCount>,
}

impl MaxWeight {
    pub fn new() -> Self {
        MaxWeight {
            eff: Vec::new(),
            counts: Vec::new(),
        }
    }

    /// Reserved workspace capacity (allocation-stability tests).
    pub fn scratch_footprint(&self) -> usize {
        self.eff.capacity() + self.counts.capacity()
    }
}

impl Default for MaxWeight {
    fn default() -> Self {
        Self::new()
    }
}

impl Assigner for MaxWeight {
    fn name(&self) -> &'static str {
        "maxweight"
    }

    fn assign(&mut self, inst: &Instance) -> Assignment {
        let m = inst.busy.len();
        self.eff.clear();
        self.eff.extend_from_slice(inst.busy);
        self.counts.resize(m, 0);
        let mut per_group = Vec::with_capacity(inst.groups.len());
        let mut phi: Slots = 0;
        for g in inst.groups {
            if g.size == 0 {
                per_group.push(Vec::new());
                continue;
            }
            let holders = g.holders();
            let mut remaining = g.size;
            while remaining > 0 {
                // argmax of w·μ/(1+eff) over the eligible set; exact via
                // cross-multiplication, ties broken shortest-queue-first.
                let mut best: Option<(ServerId, u64, Slots)> = None; // (id, w·μ, eff)
                for &s in &g.servers {
                    let w = if holders.binary_search(&s).is_ok() {
                        LOCAL_WEIGHT
                    } else {
                        REMOTE_WEIGHT
                    };
                    let wmu = w * inst.mu[s];
                    let better = match best {
                        None => true,
                        Some((bs, bwmu, beff)) => {
                            let cand = wmu as u128 * (1 + beff) as u128;
                            let incumbent = bwmu as u128 * (1 + self.eff[s]) as u128;
                            cand > incumbent
                                || (cand == incumbent
                                    && (self.eff[s], Reverse(inst.mu[s]), s)
                                        < (beff, Reverse(inst.mu[bs]), bs))
                        }
                    };
                    if better {
                        best = Some((s, wmu, self.eff[s]));
                    }
                }
                let (target, _, _) = best.expect("non-empty group server set");
                let chunk = remaining.min(inst.mu[target]);
                self.counts[target] += chunk;
                self.eff[target] += 1;
                phi = phi.max(self.eff[target]);
                remaining -= chunk;
            }
            per_group.push(emit_row(&mut self.counts, &g.servers));
        }
        Assignment { per_group, phi }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{program_phi, validate_assignment};
    use super::*;
    use crate::job::TaskGroup;

    fn inst<'a>(groups: &'a [TaskGroup], mu: &'a [u64], busy: &'a [Slots]) -> Instance<'a> {
        Instance { groups, mu, busy }
    }

    #[test]
    fn prefers_holders_at_equal_queue_and_rate() {
        // Symmetric servers, but only 1 holds a replica: the double
        // locality weight routes the first chunks there until its queue
        // halves the priority below the remote servers'.
        let groups = vec![TaskGroup::with_local(6, vec![0, 1, 2], vec![1])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let out = MaxWeight::new().assign(&inst(&groups, &mu, &busy));
        // Priorities: s1 = 4/1 wins; then s1 = 4/2 = 2/1 ties remote w·μ
        // ratio... 4/(1+1) = 2 vs 2/(1+0) = 2 → tie, shortest queue wins
        // (s0); then s1 4/2 vs s2 2/1 tie → s2 shorter queue; repeat.
        assert_eq!(out.total_assigned(), 6);
        let row = &out.per_group[0];
        let s1 = row.iter().find(|&&(s, _)| s == 1).map(|&(_, n)| n);
        assert!(s1.is_some(), "holder must receive work: {row:?}");
        validate_assignment(&inst(&groups, &mu, &busy), &out).unwrap();
    }

    #[test]
    fn weighs_rate_against_backlog() {
        // No locality split (flat): a 4× faster server absorbs chunks
        // until its backlog erodes the priority ratio below the slow
        // server's.
        let groups = vec![TaskGroup::new(10, vec![0, 1])];
        let mu = vec![8, 2];
        let busy = vec![0, 0];
        let out = MaxWeight::new().assign(&inst(&groups, &mu, &busy));
        let row = &out.per_group[0];
        let fast = row.iter().find(|&&(s, _)| s == 0).map_or(0, |&(_, n)| n);
        let slow = row.iter().find(|&&(s, _)| s == 1).map_or(0, |&(_, n)| n);
        assert!(fast > slow, "fast server must take the bulk: {row:?}");
        assert_eq!(fast + slow, 10);
    }

    #[test]
    fn phi_is_exact_program_phi_on_random_instances() {
        use crate::assign::testutil::random_instance;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0x3A_11);
        for _ in 0..300 {
            let oi = random_instance(&mut rng, 6, 4, 12, 6);
            let inst = oi.view();
            let out = MaxWeight::new().assign(&inst);
            validate_assignment(&inst, &out).unwrap();
            assert_eq!(out.phi, program_phi(&inst, &out.per_group));
        }
    }
}
