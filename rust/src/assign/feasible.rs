//! The per-Φ feasibility oracle behind NLIP and OBTA.
//!
//! At a fixed candidate Φ, program `P` (eq. 4) asks whether every group's
//! tasks fit into the slot budgets `cap_m = max{Φ − b_m, 0}`. The oracle
//! answers exactly, in three tiers (fast → slow), returning the concrete
//! allocation when feasible:
//!
//! 1. **Flow relaxation** (task units): bipartite max-flow with server
//!    capacity `cap_m·μ_m` tasks. The LP relaxation of `P` at fixed Φ is
//!    *equivalent* to this flow (substitute `t = μ·y`), so an unsaturated
//!    flow certifies the integer program infeasible — *certified no*.
//! 2. **Ceil extraction**: round each `(group, server)` flow quantity up
//!    to whole slots; if every server still fits its slot budget —
//!    *certified yes* with that allocation.
//! 3. **Floor + residual ILP**: floor the flow to whole slots (never
//!    exceeds budgets), then cover the per-group residuals (each < Σ μ)
//!    with the spare slots via a *small* exact branch & bound. Certified
//!    yes when it covers.
//! 4. **Full ILP** ([`super::ilp`]): slot-unit branch & bound over the
//!    whole instance, within a node budget. `Unknown` (budget exhausted)
//!    is treated as infeasible: the surrounding Φ search then settles on
//!    a slightly larger but still valid Φ — a bounded, telemetered
//!    deviation from exactness (`stats.ilp_unknown`), never observed on
//!    the brute-force-checked instance sizes.
//!
//! Tiers 1–3 resolve virtually every real instance (group sizes ≫ μ);
//! the tier counters feed the perf report (EXPERIMENTS.md §Perf).

use crate::flow::{Dinic, EdgeRef};
use crate::job::{ServerId, Slots, TaskCount};
use crate::util::ceil_div;

use super::ilp::{ilp_feasible, Constraint, IlpOutcome, Sense};
use super::Instance;

/// Per-process counters of which tier decided feasibility (perf telemetry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OracleStats {
    pub flow_infeasible: u64,
    pub ceil_feasible: u64,
    pub floor_residual_feasible: u64,
    pub ilp_calls: u64,
    /// Full-ILP budget exhaustions treated as infeasible (see module docs).
    pub ilp_unknown: u64,
}

impl OracleStats {
    pub fn merge(&mut self, other: &OracleStats) {
        self.flow_infeasible += other.flow_infeasible;
        self.ceil_feasible += other.ceil_feasible;
        self.floor_residual_feasible += other.floor_residual_feasible;
        self.ilp_calls += other.ilp_calls;
        self.ilp_unknown += other.ilp_unknown;
    }
}

/// Reusable buffers behind an [`Oracle`]: the Dinic arena plus every
/// topology vector. An exact assigner builds one oracle per job arrival;
/// pooling these buffers in the assigner ([`super::obta::Obta`],
/// [`super::nlip::Nlip`]) makes the steady-state rebuild allocation-free
/// (the graph is re-derived per instance, but into recycled arenas).
#[derive(Debug, Default)]
pub struct OracleWorkspace {
    net: Dinic,
    /// Non-empty group indices.
    groups: Vec<usize>,
    /// Union of available servers, sorted; `server_pos[m]` is its index.
    union: Vec<ServerId>,
    server_pos: std::collections::HashMap<ServerId, usize>,
    /// Per group (in `groups` order): the (server, edge) pairs. Row pool
    /// never shrinks.
    group_edges: Vec<Vec<(ServerId, EdgeRef)>>,
    /// Per union server: the server→sink edge (capacity = f(Φ)).
    sink_edges: Vec<EdgeRef>,
}

impl OracleWorkspace {
    /// Reserved capacity across the pooled buffers (allocation-stability
    /// tests). The `server_pos` hash map is excluded: `HashMap` exposes
    /// no stable capacity accessor, but it is cleared (not dropped)
    /// between instances just like the vectors.
    pub fn footprint(&self) -> usize {
        self.net.footprint()
            + self.groups.capacity()
            + self.union.capacity()
            + self.group_edges.capacity()
            + self.group_edges.iter().map(|r| r.capacity()).sum::<usize>()
            + self.sink_edges.capacity()
    }
}

/// Feasibility oracle for one instance; reusable across candidate Φ values
/// (binary search). The flow network is built once — only the sink-edge
/// capacities depend on Φ, so each probe is a reset + recapacitate +
/// max-flow, with zero graph construction.
pub struct Oracle<'a> {
    inst: &'a Instance<'a>,
    ws: OracleWorkspace,
    total: TaskCount,
    pub stats: OracleStats,
}

impl<'a> Oracle<'a> {
    pub fn new(inst: &'a Instance<'a>) -> Self {
        Self::with_workspace(inst, OracleWorkspace::default())
    }

    /// Build the oracle into a recycled workspace (see
    /// [`OracleWorkspace`]); reclaim it afterwards with
    /// [`Oracle::into_workspace`].
    pub fn with_workspace(inst: &'a Instance<'a>, mut ws: OracleWorkspace) -> Self {
        ws.groups.clear();
        ws.groups
            .extend((0..inst.groups.len()).filter(|&k| inst.groups[k].size > 0));
        ws.union.clear();
        for &k in &ws.groups {
            ws.union.extend(inst.groups[k].servers.iter().copied());
        }
        ws.union.sort_unstable();
        ws.union.dedup();
        ws.server_pos.clear();
        for (i, &m) in ws.union.iter().enumerate() {
            ws.server_pos.insert(m, i);
        }
        let total = inst.total_tasks();

        // Build the bipartite flow network into the recycled arena.
        // Nodes: 0 = source, 1..=G groups, G+1..=G+S servers, last = sink.
        let g_n = ws.groups.len();
        let s_n = ws.union.len();
        ws.net.reinit(2 + g_n + s_n);
        let src = 0;
        while ws.group_edges.len() < g_n {
            ws.group_edges.push(Vec::new());
        }
        for row in ws.group_edges.iter_mut() {
            row.clear();
        }
        for (gi, &k) in ws.groups.iter().enumerate() {
            let g = &inst.groups[k];
            ws.net.add_edge(src, 1 + gi, g.size);
            for &m in &g.servers {
                let si = ws.server_pos[&m];
                let e = ws.net.add_edge(1 + gi, 1 + g_n + si, g.size);
                ws.group_edges[gi].push((m, e));
            }
        }
        let sink = 1 + g_n + s_n;
        ws.sink_edges.clear();
        for si in 0..s_n {
            let e = ws.net.add_edge(1 + g_n + si, sink, 0);
            ws.sink_edges.push(e);
        }

        Oracle {
            inst,
            ws,
            total,
            stats: OracleStats::default(),
        }
    }

    /// Reclaim the workspace for the next instance.
    pub fn into_workspace(self) -> OracleWorkspace {
        self.ws
    }

    /// Decide feasibility at Φ; on success return the per-group
    /// `(server, tasks)` allocation (aligned with `inst.groups`, empty
    /// groups get empty allocations).
    pub fn check(&mut self, phi: Slots) -> Option<Vec<Vec<(ServerId, TaskCount)>>> {
        if self.total == 0 {
            return Some(vec![Vec::new(); self.inst.groups.len()]);
        }
        let caps: Vec<Slots> = self
            .ws
            .union
            .iter()
            .map(|&m| phi.saturating_sub(self.inst.busy[m]))
            .collect();

        // --- Tier 1: max-flow relaxation in task units ---
        let g_n = self.ws.groups.len();
        let s_n = self.ws.union.len();
        let src = 0;
        let sink = 1 + g_n + s_n;
        self.ws.net.reset();
        for (si, &m) in self.ws.union.iter().enumerate() {
            let task_cap = caps[si].saturating_mul(self.inst.mu[m]);
            self.ws.net.set_cap(self.ws.sink_edges[si], task_cap);
        }
        let flow = self.ws.net.max_flow(src, sink);
        if flow < self.total {
            self.stats.flow_infeasible += 1;
            return None;
        }
        let net = &self.ws.net;
        let group_edges = &self.ws.group_edges;

        // --- Tier 2: ceil extraction ---
        let mut alloc: Vec<Vec<(ServerId, TaskCount)>> =
            vec![Vec::new(); self.inst.groups.len()];
        let mut slot_use = vec![0u64; s_n];
        // Per (group, server): the flow amount, for tiers 2–3.
        let mut flows: Vec<Vec<(ServerId, TaskCount)>> = vec![Vec::new(); g_n];
        for (gi, &k) in self.ws.groups.iter().enumerate() {
            for &(m, e) in &group_edges[gi] {
                let f = net.flow_of(e);
                if f > 0 {
                    alloc[k].push((m, f));
                    flows[gi].push((m, f));
                    slot_use[self.ws.server_pos[&m]] += ceil_div(f, self.inst.mu[m]);
                }
            }
        }
        if slot_use.iter().zip(&caps).all(|(&used, &cap)| used <= cap) {
            self.stats.ceil_feasible += 1;
            return Some(alloc);
        }

        // --- Tier 3: floor the flow, cover residuals with a small ILP ---
        if let Some(alloc) = self.floor_residual(&flows, &caps) {
            self.stats.floor_residual_feasible += 1;
            return Some(alloc);
        }

        // --- Tier 4: exact slot-unit ILP over the whole instance ---
        self.stats.ilp_calls += 1;
        // Variables: one per (group, server) edge, in deterministic order.
        let mut var_of: Vec<Vec<usize>> = Vec::with_capacity(g_n);
        let mut nvars = 0;
        for &k in &self.ws.groups {
            let g = &self.inst.groups[k];
            var_of.push((0..g.servers.len()).map(|j| nvars + j).collect());
            nvars += g.servers.len();
        }
        let mut constraints = Vec::new();
        // Slot budgets per server.
        for (si, &m) in self.ws.union.iter().enumerate() {
            let mut terms = Vec::new();
            for (gi, &k) in self.ws.groups.iter().enumerate() {
                let g = &self.inst.groups[k];
                if let Some(j) = g.servers.iter().position(|&x| x == m) {
                    terms.push((var_of[gi][j], 1.0));
                }
            }
            if !terms.is_empty() {
                constraints.push(Constraint {
                    terms,
                    sense: Sense::Le,
                    rhs: caps[si] as f64,
                });
            }
        }
        // Coverage per group.
        for (gi, &k) in self.ws.groups.iter().enumerate() {
            let g = &self.inst.groups[k];
            let terms = g
                .servers
                .iter()
                .enumerate()
                .map(|(j, &m)| (var_of[gi][j], self.inst.mu[m] as f64))
                .collect();
            constraints.push(Constraint {
                terms,
                sense: Sense::Ge,
                rhs: g.size as f64,
            });
        }
        match ilp_feasible(nvars, &constraints) {
            IlpOutcome::Infeasible => None,
            IlpOutcome::Unknown => {
                self.stats.ilp_unknown += 1;
                None
            }
            IlpOutcome::Feasible(y) => {
                // Convert slot counts to task counts: walk each group's
                // servers, taking up to y·μ tasks, last taker absorbs the
                // remainder (coverage guarantees enough capacity).
                let mut alloc: Vec<Vec<(ServerId, TaskCount)>> =
                    vec![Vec::new(); self.inst.groups.len()];
                for (gi, &k) in self.ws.groups.iter().enumerate() {
                    let g = &self.inst.groups[k];
                    let mut remaining = g.size;
                    for (j, &m) in g.servers.iter().enumerate() {
                        if remaining == 0 {
                            break;
                        }
                        let cap = y[var_of[gi][j]] * self.inst.mu[m];
                        let take = cap.min(remaining);
                        if take > 0 {
                            alloc[k].push((m, take));
                            remaining -= take;
                        }
                    }
                    debug_assert_eq!(remaining, 0, "ILP coverage violated");
                }
                Some(alloc)
            }
        }
    }

    /// Tier 3: floor every flow quantity to whole slots (never exceeds
    /// any slot budget), then try to cover the small per-group residual
    /// demands with the spare slots via an exact ILP on the *residual*
    /// instance only. Residuals are < μ per (group, server) pair, so the
    /// residual ILP is tiny and its B&B converges immediately.
    fn floor_residual(
        &self,
        flows: &[Vec<(ServerId, TaskCount)>],
        caps: &[Slots],
    ) -> Option<Vec<Vec<(ServerId, TaskCount)>>> {
        let g_n = self.ws.groups.len();
        // Floored allocation + spare capacity.
        let mut floored: Vec<Vec<(ServerId, TaskCount)>> = vec![Vec::new(); g_n];
        let mut used_slots = vec![0u64; self.ws.union.len()];
        let mut residual = vec![0u64; g_n];
        for (gi, f) in flows.iter().enumerate() {
            for &(m, t) in f {
                let mu = self.inst.mu[m];
                let whole = t / mu;
                if whole > 0 {
                    floored[gi].push((m, whole * mu));
                    used_slots[self.ws.server_pos[&m]] += whole;
                }
                residual[gi] += t % mu;
            }
        }
        let spare: Vec<u64> = caps
            .iter()
            .zip(&used_slots)
            .map(|(&c, &u)| c - u) // floors cannot exceed the budget
            .collect();

        // Residual ILP: cover residual[gi] tasks from the group's servers
        // using spare slots. Only groups with a residual get variables —
        // the others are already fully served by their floors.
        let active: Vec<usize> = (0..g_n).filter(|&gi| residual[gi] > 0).collect();
        if active.is_empty() {
            // Floors alone cover everything (flow was slot-aligned).
            let mut alloc: Vec<Vec<(ServerId, TaskCount)>> =
                vec![Vec::new(); self.inst.groups.len()];
            for (gi, &k) in self.ws.groups.iter().enumerate() {
                let g = &self.inst.groups[k];
                let mut remaining = g.size;
                for &(m, t) in &floored[gi] {
                    let take = t.min(remaining);
                    if take > 0 {
                        alloc[k].push((m, take));
                        remaining -= take;
                    }
                }
                if remaining > 0 {
                    return None;
                }
            }
            return Some(alloc);
        }
        // Exact residual cover by DFS + memoization — *simplex-free*.
        // Residual demands are < μ per (group, server) pair, so per-group
        // slot needs are tiny and the memoized search resolves in
        // microseconds; this is what keeps the boundary probes of the Φ
        // search cheap (EXPERIMENTS.md §Perf).
        match residual_cover_dfs(
            &active,
            &residual,
            &spare,
            &self.ws.groups,
            self.inst,
            &self.ws.server_pos,
        ) {
            Some(cover) => self.combine_floor_cover(&floored, &cover),
            None => None,
        }
    }

    /// Merge the floored flow allocation with a residual slot cover
    /// (`cover[gi]` = (server index within the group, slots)) into the
    /// final per-group `(server, tasks)` allocation.
    pub(crate) fn combine_floor_cover(
        &self,
        floored: &[Vec<(ServerId, TaskCount)>],
        cover: &[Vec<(usize, u64)>],
    ) -> Option<Vec<Vec<(ServerId, TaskCount)>>> {
        let mut alloc: Vec<Vec<(ServerId, TaskCount)>> =
            vec![Vec::new(); self.inst.groups.len()];
        for (gi, &k) in self.ws.groups.iter().enumerate() {
            let g = &self.inst.groups[k];
            // Capacity per server: floored amount + residual slots · μ.
            let mut cap_here: std::collections::BTreeMap<ServerId, u64> = Default::default();
            for &(m, t) in &floored[gi] {
                *cap_here.entry(m).or_insert(0) += t;
            }
            for &(j, slots) in &cover[gi] {
                let m = g.servers[j];
                *cap_here.entry(m).or_insert(0) += slots * self.inst.mu[m];
            }
            let mut remaining = g.size;
            for (&m, &cap) in &cap_here {
                if remaining == 0 {
                    break;
                }
                let take = cap.min(remaining);
                if take > 0 {
                    alloc[k].push((m, take));
                    remaining -= take;
                }
            }
            if remaining > 0 {
                return None; // defensive: cover fell short
            }
        }
        Some(alloc)
    }

    /// Smallest feasible Φ in `[lo, hi]` (monotone binary search), with
    /// its allocation.
    ///
    /// `hi` is a *hint*: it is expected to be feasible (Φ⁺ or the
    /// trivial bound) but is only probed if the search actually converges
    /// onto it; if it then proves infeasible (possible because Φ⁺ ignores
    /// integer-slot collisions between groups, by at most K_c − 1 slots),
    /// the bracket is widened by `expand` and the search resumes. Probing
    /// lazily saves one boundary-priced feasibility check per call —
    /// which is most of OBTA's per-arrival cost, since its narrowed
    /// window means *every* probe lands in the expensive tight zone.
    pub fn search_min_phi(
        &mut self,
        lo: Slots,
        mut hi: Slots,
        expand: Slots,
    ) -> (Slots, Vec<Vec<(ServerId, TaskCount)>>) {
        debug_assert!(lo <= hi);
        // The lower bound Φ⁻ is tight for most arrivals (one bottleneck
        // group); probing it first turns the common case into a single
        // feasibility check.
        if let Some(alloc) = self.check(lo) {
            return (lo, alloc);
        }
        let mut lo = lo + 1;
        let mut best: Option<(Slots, Vec<Vec<(ServerId, TaskCount)>>)> = None;
        let mut guard = 0;
        loop {
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                match self.check(mid) {
                    Some(a) => {
                        best = Some((mid, a));
                        hi = mid;
                    }
                    None => lo = mid + 1,
                }
            }
            // lo == hi: done if hi was verified during the search.
            if let Some((p, a)) = best.take() {
                if p == hi {
                    return (hi, a);
                }
                best = Some((p, a));
            }
            match self.check(hi) {
                Some(a) => return (hi, a),
                None => {
                    // The hint was short (integer-slot collisions); widen.
                    lo = hi + 1;
                    hi += expand.max(1);
                    guard += 1;
                    assert!(guard < 64, "Φ search bracket runaway");
                }
            }
        }
    }
}

/// Exact, simplex-free cover of the (tiny) residual demands with spare
/// slots: DFS over the active groups (most-constrained first), memoized
/// on the spare-capacity state, node-budgeted. `None` means "could not
/// certify" — the caller falls through to the full ILP, so a budget
/// exhaustion (astronomically unlikely at residual sizes < μ·p) only
/// costs time, never correctness.
fn residual_cover_dfs(
    active: &[usize],
    residual: &[u64],
    spare: &[u64],
    group_ids: &[usize],
    inst: &Instance,
    server_pos: &std::collections::HashMap<ServerId, usize>,
) -> Option<Vec<Vec<(usize, u64)>>> {
    const BUDGET: usize = 100_000;
    let g_n = residual.len();

    // Most-constrained group order: fewest available servers first, then
    // largest residual.
    let mut order: Vec<usize> = active.to_vec();
    order.sort_by_key(|&gi| {
        let g = &inst.groups[group_ids[gi]];
        (g.servers.len(), std::cmp::Reverse(residual[gi]))
    });

    // Per-server clamp for the memo key: spare beyond the total possible
    // remaining use is equivalent.
    let mut clamp = vec![0u64; spare.len()];
    for &gi in &order {
        let g = &inst.groups[group_ids[gi]];
        for &m in &g.servers {
            let si = server_pos[&m];
            clamp[si] += ceil_div(residual[gi], inst.mu[m].max(1));
        }
    }

    struct Ctx<'c> {
        order: Vec<usize>,
        residual: &'c [u64],
        group_ids: &'c [usize],
        inst: &'c Instance<'c>,
        server_pos: &'c std::collections::HashMap<ServerId, usize>,
        clamp: Vec<u64>,
        memo: std::collections::HashMap<(usize, Vec<u8>), bool>,
        nodes: usize,
        cover: Vec<Vec<(usize, u64)>>,
    }

    fn key(spare: &[u64], clamp: &[u64]) -> Vec<u8> {
        spare
            .iter()
            .zip(clamp)
            .map(|(&s, &c)| s.min(c).min(250) as u8)
            .collect()
    }

    /// Ok(true) = covered from this point; Err = budget exhausted.
    fn rec(ctx: &mut Ctx, oi: usize, spare: &mut Vec<u64>) -> Result<bool, ()> {
        if oi == ctx.order.len() {
            return Ok(true);
        }
        ctx.nodes += 1;
        if ctx.nodes > BUDGET {
            return Err(());
        }
        // Memo of *failed* states only: successes return immediately with
        // the cover intact (first success wins), so only exhaustive
        // failures repeat and need pruning.
        let k = (oi, key(spare, &ctx.clamp));
        if ctx.memo.contains_key(&k) {
            return Ok(false);
        }
        let gi = ctx.order[oi];
        let need = ctx.residual[gi];
        let g = &ctx.inst.groups[ctx.group_ids[gi]];
        // Server order: highest μ first (covers with fewest slots).
        let mut js: Vec<usize> = (0..g.servers.len()).collect();
        js.sort_by_key(|&j| std::cmp::Reverse(ctx.inst.mu[g.servers[j]]));

        fn assign(
            ctx: &mut Ctx,
            oi: usize,
            js: &[usize],
            ji: usize,
            need: u64,
            spare: &mut Vec<u64>,
            taken: &mut Vec<(usize, u64)>,
        ) -> Result<bool, ()> {
            if need == 0 {
                let gi = ctx.order[oi];
                ctx.cover[gi] = taken.clone();
                if rec(ctx, oi + 1, spare)? {
                    return Ok(true);
                }
                ctx.cover[gi].clear();
                return Ok(false);
            }
            if ji == js.len() {
                return Ok(false);
            }
            let gi = ctx.order[oi];
            let g = &ctx.inst.groups[ctx.group_ids[gi]];
            let j = js[ji];
            let m = g.servers[j];
            let si = ctx.server_pos[&m];
            let mu = ctx.inst.mu[m];
            let max_take = spare[si].min(ceil_div(need, mu));
            // Try the largest useful allocation first.
            for s in (0..=max_take).rev() {
                spare[si] -= s;
                let served = (s * mu).min(need);
                if s > 0 {
                    taken.push((j, s));
                }
                let ok = assign(ctx, oi, js, ji + 1, need - served, spare, taken);
                if s > 0 {
                    taken.pop();
                }
                spare[si] += s;
                // On success the full cover for this group was already
                // recorded (taken.clone() in the need == 0 branch).
                if ok? {
                    return Ok(true);
                }
            }
            Ok(false)
        }

        let mut taken = Vec::new();
        let result = assign(ctx, oi, &js, 0, need, spare, &mut taken)?;
        if !result {
            ctx.memo.insert(k, true);
        }
        Ok(result)
    }

    let mut ctx = Ctx {
        order,
        residual,
        group_ids,
        inst,
        server_pos,
        clamp,
        memo: Default::default(),
        nodes: 0,
        cover: vec![Vec::new(); g_n],
    };
    let mut spare_mut = spare.to_vec();
    match rec(&mut ctx, 0, &mut spare_mut) {
        Ok(true) => Some(ctx.cover),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::bounds::{phi_lower, phi_upper};
    use crate::job::TaskGroup;

    fn inst_fixture() -> (Vec<TaskGroup>, Vec<u64>, Vec<u64>) {
        (
            vec![
                TaskGroup::new(10, vec![0, 1]),
                TaskGroup::new(6, vec![1, 2]),
            ],
            vec![2, 2, 2],
            vec![0, 3, 1],
        )
    }

    #[test]
    fn feasible_at_upper_infeasible_below_lower() {
        let (groups, mu, busy) = inst_fixture();
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let lo = phi_lower(&inst);
        let hi = phi_upper(&inst);
        let mut oracle = Oracle::new(&inst);
        assert!(oracle.check(hi).is_some(), "Φ⁺ must be feasible");
        if lo > 0 {
            assert!(oracle.check(lo - 1).is_none(), "below Φ⁻ must be infeasible");
        }
    }

    #[test]
    fn returned_allocation_fits_slot_budgets() {
        let (groups, mu, busy) = inst_fixture();
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut oracle = Oracle::new(&inst);
        let hi = phi_upper(&inst);
        let (phi, alloc) = oracle.search_min_phi(phi_lower(&inst), hi, 4);
        // Assignment covers all tasks on available servers.
        for (k, g) in groups.iter().enumerate() {
            let total: u64 = alloc[k].iter().map(|&(_, n)| n).sum();
            assert_eq!(total, g.size);
            for &(m, _) in &alloc[k] {
                assert!(g.servers.contains(&m));
            }
        }
        // Slot budgets respected at phi.
        let mut slots = std::collections::BTreeMap::new();
        for g in &alloc {
            for &(m, n) in g {
                *slots.entry(m).or_insert(0u64) += ceil_div(n, mu[m]);
            }
        }
        for (&m, &s) in &slots {
            assert!(busy[m] + s <= phi, "server {m} exceeds Φ {phi}");
        }
    }

    #[test]
    fn slot_sharing_needs_ilp_tier() {
        // cap 1 slot at the only server; two groups of 2 tasks; μ = 4.
        // Flow relaxation says feasible; truth is infeasible at Φ = 1.
        let groups = vec![TaskGroup::new(2, vec![0]), TaskGroup::new(2, vec![0])];
        let mu = vec![4];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut oracle = Oracle::new(&inst);
        assert!(oracle.check(1).is_none(), "integer slots forbid Φ=1");
        assert!(oracle.stats.ilp_calls >= 1, "must have reached tier 3");
        let alloc = oracle.check(2).expect("Φ=2 feasible");
        let total: u64 = alloc.iter().flatten().map(|&(_, n)| n).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn empty_instance_feasible_at_zero() {
        let groups: Vec<TaskGroup> = vec![];
        let mu = vec![1];
        let busy = vec![5];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        let mut oracle = Oracle::new(&inst);
        assert!(oracle.check(0).is_some());
    }

    #[test]
    fn search_min_phi_matches_linear_scan() {
        use crate::assign::testutil::random_instance;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(88);
        for _ in 0..30 {
            let owned = random_instance(&mut rng, 5, 3, 20, 5);
            let inst = owned.view();
            let lo = phi_lower(&inst);
            // Φ⁺ can be short by up to K_c − 1 slots when groups collide
            // on one server (integer slots); bracket like OBTA does.
            let mut hi = phi_upper(&inst);
            let mut o1 = Oracle::new(&inst);
            while o1.check(hi).is_none() {
                hi += inst.groups.len() as u64 + 1;
            }
            let (phi, _) = o1.search_min_phi(lo, hi, 4);
            // Linear scan cross-check.
            let mut o2 = Oracle::new(&inst);
            let mut scan = lo;
            while o2.check(scan).is_none() {
                scan += 1;
            }
            assert_eq!(phi, scan, "instance {owned:?}");
        }
    }
}
