//! Exhaustive-search reference oracle for tiny assignment instances.
//!
//! [`brute_force_opt_phi`] computes the true optimum of program `P`
//! (eq. 4) by scanning Φ upward from the lower bound Φ⁻ and running an
//! exhaustive, memoized slot-partition search per candidate level —
//! feasible only for tiny instances (a handful of servers, groups and
//! tasks), which is exactly the regime where exhaustive ground truth is
//! worth its cost.
//!
//! This is the oracle behind the differential test harness
//! (`rust/tests/differential_assign.rs`): OBTA and NLIP must match it
//! exactly on every enumerated small instance, WF must stay within its
//! K_c factor of it, and every heuristic is lower-bounded by it. It was
//! promoted out of the crate-private test helpers so integration tests
//! (compiled as separate crates) can use it; it is **not** a production
//! assigner — its cost grows exponentially with the instance.

use std::collections::HashMap;

use crate::job::{ServerId, Slots, TaskGroup};

use super::{bounds, Instance};

/// The optimal program-P completion time Φ* of the instance, by upward
/// scan + exhaustive feasibility search. Panics if the scan runs away
/// (10 000 levels past Φ⁻), which cannot happen on well-formed instances
/// since Φ⁺ is always feasible.
pub fn brute_force_opt_phi(inst: &Instance) -> Slots {
    let lo = bounds::phi_lower(inst);
    let mut phi = lo;
    loop {
        if brute_feasible(inst, phi) {
            return phi;
        }
        phi += 1;
        assert!(phi < lo + 10_000, "brute force runaway");
    }
}

/// Can every group's tasks be placed so that each server finishes by
/// `phi` under the per-group integer-slot accounting of program `P`?
fn brute_feasible(inst: &Instance, phi: Slots) -> bool {
    let union = inst.union_servers();
    let mut cap: Vec<u64> = union
        .iter()
        .map(|&m| phi.saturating_sub(inst.busy[m]))
        .collect();
    let groups: Vec<&TaskGroup> = inst.groups.iter().filter(|g| g.size > 0).collect();
    // Memo on (group index, residual caps): residual capacity fully
    // determines feasibility of the remaining groups.
    let mut memo: HashMap<(usize, Vec<u64>), bool> = HashMap::new();

    fn rec(
        gi: usize,
        groups: &[&TaskGroup],
        union: &[ServerId],
        cap: &mut Vec<u64>,
        mu: &[u64],
        memo: &mut HashMap<(usize, Vec<u64>), bool>,
    ) -> bool {
        if gi == groups.len() {
            return true;
        }
        let key = (gi, cap.clone());
        if let Some(&v) = memo.get(&key) {
            return v;
        }
        let g = groups[gi];
        let result = alloc(0, g.size, g, gi, groups, union, cap, mu, memo);
        memo.insert(key, result);
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn alloc(
        si: usize,
        remaining: u64,
        g: &TaskGroup,
        gi: usize,
        groups: &[&TaskGroup],
        union: &[ServerId],
        cap: &mut Vec<u64>,
        mu: &[u64],
        memo: &mut HashMap<(usize, Vec<u64>), bool>,
    ) -> bool {
        if remaining == 0 {
            return rec(gi + 1, groups, union, cap, mu, memo);
        }
        if si == g.servers.len() {
            return false;
        }
        let m = g.servers[si];
        let ui = union.iter().position(|&x| x == m).unwrap();
        let max_slots = cap[ui].min(crate::util::ceil_div(remaining, mu[m]));
        for s in (0..=max_slots).rev() {
            cap[ui] -= s;
            let served = (s * mu[m]).min(remaining);
            if alloc(si + 1, remaining - served, g, gi, groups, union, cap, mu, memo) {
                cap[ui] += s;
                return true;
            }
            cap[ui] += s;
        }
        false
    }
    rec(0, &groups, &union, &mut cap, inst.mu, &mut memo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::TaskGroup;

    #[test]
    fn matches_hand_computed_optima() {
        // 12 tasks on 3 idle μ=2 servers: 2 slots each → Φ* = 2.
        let groups = vec![TaskGroup::new(12, vec![0, 1, 2])];
        let mu = vec![2, 2, 2];
        let busy = vec![0, 0, 0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(brute_force_opt_phi(&inst), 2);

        // Pinned group forces Φ* through the busy server.
        let groups = vec![TaskGroup::new(3, vec![0])];
        let mu = vec![1];
        let busy = vec![2];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(brute_force_opt_phi(&inst), 5);
    }

    #[test]
    fn per_group_slot_granularity_is_respected() {
        // Two groups of 1 task on one μ=3 server: each group still costs
        // a whole slot (program P charges ceil per group), so Φ* = 2 —
        // the case a merged-queue objective would get wrong.
        let groups = vec![TaskGroup::new(1, vec![0]), TaskGroup::new(1, vec![0])];
        let mu = vec![3];
        let busy = vec![0];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(brute_force_opt_phi(&inst), 2);
    }

    #[test]
    fn empty_groups_are_free() {
        let groups = vec![TaskGroup::new(0, vec![0])];
        let mu = vec![1];
        let busy = vec![7];
        let inst = Instance {
            groups: &groups,
            mu: &mu,
            busy: &busy,
        };
        assert_eq!(brute_force_opt_phi(&inst), 0);
    }
}
